"""While-aware HLO cost analyzer.

XLA's `compiled.cost_analysis()` counts a `while` body ONCE, so any scan
(over layers, attention chunks, microbatches) is undercounted by its trip
count. This analyzer re-walks the optimized HLO text: it parses every
computation, costs dots/collectives locally, and multiplies through the
call graph using each while op's `known_trip_count` backend config.

Costs extracted per device:
  flops            — 2 * prod(out_dims) * prod(contracting dims) per dot
  collective_bytes — output bytes of all-gather/all-reduce/reduce-scatter/
                     all-to-all/collective-permute (per-device program)
Validated in tests/test_hlo_cost.py against hand-computable scans.
"""
from __future__ import annotations

import json
import re
from typing import Dict

_DTB = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
        "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_dims(text):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _nbytes(text) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTB:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTB[dt]
    return total


class HloCost:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, list] = {}
        self.entry = None
        self._parse(hlo_text)
        self._memo: Dict[str, Dict[str, float]] = {}

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", line)
            if m and not line.startswith(" "):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if stripped == "}":
                continue
            if cur is not None and stripped:
                self.computations[cur].append(stripped)

    @staticmethod
    def _trip_count(line: str) -> int:
        m = re.search(r'known_trip_count[":{ n]*"?(\d+)"', line)
        return int(m.group(1)) if m else 1

    def _local_shapes(self, comp: str) -> Dict[str, str]:
        """Map value name -> its full definition line (for operand shapes)."""
        out = {}
        for line in self.computations.get(comp, []):
            m = _DEF_RE.match(line)
            if m:
                out[m.group(1)] = m.group(2)
        return out

    _SKIP_BYTES = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
                   "bitcast(", "while(", "conditional(", "after-all(",
                   "iota(", "partition-id(", "replica-id(")

    def cost(self, comp: str = None) -> Dict[str, float]:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        flops = 0.0
        bytes_w = 0.0   # bytes written by real instructions (HBM-traffic
        # proxy: every written value is read ~once, so traffic ~= 2x this)
        coll = {c: 0.0 for c in _COLLECTIVES}
        defs = self._local_shapes(comp)
        for line in self.computations.get(comp, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            if not any(s in rhs for s in self._SKIP_BYTES) \
                    and "fusion(" not in rhs and " call(" not in rhs:
                bytes_w += _nbytes(rhs.split(" ", 1)[0])
            # ---- dots ----
            dm = re.match(r"(\w+)\[([\d,]*)\][^ ]*\s+dot\(([^)]*)\)", rhs)
            if dm:
                out_dims = [int(d) for d in dm.group(2).split(",") if d]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                k = 1
                inner = dm.group(3).lstrip()
                sm = _SHAPE_RE.match(inner)
                if sm:
                    # operand carries its shape inline (newer XLA text)
                    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
                else:
                    ops = [o.strip().lstrip("%") for o in inner.split(",")]
                    lhs_def = defs.get(ops[0], "")
                    _, lhs_dims = _shape_dims(lhs_def)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if lhs_dims and cm:
                    for idx in cm.group(1).split(","):
                        if idx:
                            k *= lhs_dims[int(idx)]
                flops += 2.0 * out_n * k
                continue
            # ---- collectives ----
            cm = re.match(
                r"(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
                r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                r"collective-permute)(-start)?\(", rhs)
            if cm:
                op = cm.group(4)
                coll[op] += _nbytes(cm.group(1) or
                                    f"{cm.group(2)}[{cm.group(3)}]")
                continue
            # ---- control flow / calls ----
            wm = re.search(r"\bwhile\(", rhs)
            if wm:
                bm = re.search(r"body=%?([\w.\-]+)", rhs)
                if bm:
                    sub = self.cost(bm.group(1))
                    n = self._trip_count(rhs)
                    flops += n * sub["flops"]
                    bytes_w += n * sub["bytes_written"]
                    for c in _COLLECTIVES:
                        coll[c] += n * sub["collectives"][c]
                continue
            fm = re.search(r"(?:fusion|call)\(.*?calls=%?([\w.\-]+)", rhs) \
                or re.search(r"\bcall\([^)]*\),?.*to_apply=%?([\w.\-]+)", rhs)
            if fm:
                sub = self.cost(fm.group(1))
                flops += sub["flops"]
                # a fusion writes its root output once; internals stay in
                # registers — count the call site's output, not the body
                bytes_w += _nbytes(rhs.split(" ", 1)[0])
                for c in _COLLECTIVES:
                    coll[c] += sub["collectives"][c]
                continue
            cm2 = re.search(
                r"conditional\(.*?branch_computations=\{([^}]*)\}", rhs)
            if cm2:
                branches = [b.strip().lstrip("%")
                            for b in cm2.group(1).split(",")]
                if branches:  # upper bound: most expensive branch
                    subs = [self.cost(b) for b in branches]
                    best = max(subs, key=lambda s: s["flops"])
                    flops += best["flops"]
                    bytes_w += best["bytes_written"]
                    for c in _COLLECTIVES:
                        coll[c] += best["collectives"][c]
        out = {"flops": flops, "collectives": coll,
               "collective_bytes": sum(coll.values()),
               "bytes_written": bytes_w,
               "hbm_bytes_est": 2.0 * bytes_w}
        self._memo[comp] = out
        return out


    def collective_sites(self, comp: str = None, mult: float = 1.0,
                         out=None):
        """Every collective instance with trip-multiplied bytes and the
        source op_name metadata — the hillclimbing profile."""
        comp = comp or self.entry
        out = out if out is not None else []
        for line in self.computations.get(comp, []):
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            cm = re.search(
                r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                r"collective-permute)(?:-start)?\(", rhs)
            if cm:
                meta = re.search(r'op_name="([^"]*)"', rhs)
                nb = _nbytes(rhs.split(" dynamic", 1)[0].split("(", 1)[0])
                out.append((nb * mult, cm.group(1),
                            rhs.split(" ", 1)[0],
                            (meta.group(1) if meta else "")[-120:]))
                continue
            wm = re.search(r"\bwhile\(", rhs)
            if wm:
                bm = re.search(r"body=%?([\w.\-]+)", rhs)
                if bm:
                    self.collective_sites(bm.group(1),
                                          mult * self._trip_count(rhs), out)
                continue
            fm = re.search(r"(?:fusion|call)\(.*?calls=%?([\w.\-]+)", rhs)
            if fm:
                self.collective_sites(fm.group(1), mult, out)
        return out


def analyze(compiled) -> Dict[str, float]:
    """Cost of a jax compiled executable, while-loops expanded."""
    return HloCost(compiled.as_text()).cost()


def top_collectives(compiled, n=12):
    sites = HloCost(compiled.as_text()).collective_sites()
    return sorted(sites, key=lambda s: -s[0])[:n]
