"""Roofline post-processor (assignment §Roofline).

Reads the dry-run JSON (single-pod, per-cell while-aware HLO costs +
analytic traffic model) and emits the three-term roofline table:

  compute term    = HLO_FLOPs_per_dev / peak_FLOPs      (197 TFLOP/s bf16)
  memory term     = analytic_HBM_bytes_per_dev / HBM_bw (819 GB/s)
  collective term = collective_bytes_per_dev / link_bw  (50 GB/s/link)

plus MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serving), the
MODEL/HLO ratio (remat & masked-flash waste), the dominant term, and the
roofline fraction = ideal_compute_time / dominant_term (how close the step
is to the compute roofline if the dominant bound were hit exactly).

  PYTHONPATH=src:. python -m benchmarks.roofline dryrun_singlepod.json
"""
from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12      # TPU v5e bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link


def analyze_record(r, chips=256):
    hf = r.get("hlo_full") or {}
    ms = r.get("model_stats") or {}
    flops_dev = hf.get("flops", 0.0)
    coll_dev = hf.get("collective_bytes", 0.0)
    hbm_dev = ms.get("analytic_hbm_bytes", 0.0) / chips
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = hbm_dev / HBM_BW
    coll_s = coll_dev / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    model_flops = ms.get("model_flops", 0.0)
    ideal_s = model_flops / chips / PEAK_FLOPS
    bound_s = max(terms.values())
    return {
        "arch": r["arch"], "shape": r["shape"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": flops_dev * chips,
        "model_over_hlo": model_flops / max(flops_dev * chips, 1e-9),
        "roofline_fraction": ideal_s / max(bound_s, 1e-12),
        "temp_gib": r.get("memory", {}).get("temp_bytes", 0) / 2 ** 30,
    }


NOTES = {
    "compute": ("drop HLO/model FLOP waste: skip masked flash blocks, "
                "cut remat recompute on cheap ops, fuse quant chain"),
    "memory": ("cut HBM traffic: int8/SPARQ-packed weights & KV cache, "
               "larger per-step batch to amortize weight reads"),
    "collective": ("reshard: fewer boundary re-gathers (SP<->TP), "
                   "hierarchical pod-local reduce, gradient compression"),
}


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_singlepod.json"
    recs = [r for r in json.load(open(path)) if r["status"] == "ok"]
    rows = [analyze_record(r) for r in recs]
    hdr = (f"| {'arch x shape':40s} | {'compute s':>10s} | {'memory s':>10s} "
           f"| {'collect s':>10s} | {'bound':>10s} | {'MODEL/HLO':>9s} "
           f"| {'roofl.frac':>10s} |")
    print(hdr)
    print("|" + "-" * (len(hdr) - 2) + "|")
    for a in rows:
        print(f"| {a['arch'] + ' x ' + a['shape']:40s} "
              f"| {a['compute_s']:10.4f} | {a['memory_s']:10.4f} "
              f"| {a['collective_s']:10.4f} | {a['dominant']:>10s} "
              f"| {a['model_over_hlo']:9.3f} "
              f"| {a['roofline_fraction']:10.3f} |")
    print()
    for a in rows:
        print(f"- {a['arch']} x {a['shape']}: {a['dominant']}-bound -> "
              f"{NOTES[a['dominant']]}")
    return rows


if __name__ == "__main__":
    main()
