"""Table 5 analogue: hardware cost model of the SPARQ kernel on TPU.

The paper reports post-layout silicon area per PE; a TPU's MXU is fixed, so
the deployable analogue is the *kernel cost model*: HLO FLOPs and bytes of
the fused sparq_matmul vs a plain int8 matmul (same tiles), the VMEM
working set implied by the BlockSpecs, and the packed HBM bytes/value of
each configuration (the paper's §5.1 metadata-footprint discussion).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparq import SparqConfig
from repro.kernels.ops import bytes_per_value
from repro.kernels.sparq_matmul import sparq_matmul_pallas


def vmem_working_set(bm, bn, bk) -> int:
    """Bytes resident in VMEM per grid step: x tile (f32) + w tile (int8) +
    acc scratch (int32) + recon tile (int32)."""
    return bm * bk * 4 + bk * bn * 1 + bm * bn * 4 + bm * bk * 4


def kernel_cost(cfg: SparqConfig, m=256, k=1024, n=256,
                block=(128, 128, 512)):
    bm, bn, bk = block
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.int8)
    a = jax.ShapeDtypeStruct((), jnp.float32)
    c = jax.ShapeDtypeStruct((n,), jnp.float32)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled, bm=bm, bn=bn, bk=bk, interpret=True)
    lowered = jax.jit(
        lambda xx, ww, aa, cc: sparq_matmul_pallas(xx, ww, aa, cc, **kw)
    ).lower(x, w, a, c)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    return {
        "flops": float(cost.get("flops", -1)),
        "bytes": float(cost.get("bytes accessed", -1)),
        "vmem_bytes": vmem_working_set(bm, bn, bk),
        "packed_bits_per_act": round(bytes_per_value(cfg) * 8, 2),
    }


def table5_rows():
    rows = []
    configs = [
        ("8b8b_baseline", SparqConfig(enabled=False, signed=True)),
        ("7opt_2b", SparqConfig.opt7(signed=True)),
        ("6opt_3b", SparqConfig.opt6(signed=True)),
        ("5opt_4b", SparqConfig.opt5(signed=True)),
        ("3opt_4b", SparqConfig.opt3(signed=True)),
        ("2opt_4b", SparqConfig.opt2(signed=True)),
        ("5opt_noVS", SparqConfig.opt5(signed=True, vsparq=False)),
        ("3opt_noVS", SparqConfig.opt3(signed=True, vsparq=False)),
    ]
    base = None
    for name, cfg in configs:
        c = kernel_cost(cfg)
        if base is None:
            base = c
        rows.append((name, "hlo_flops_rel",
                     round(c["flops"] / max(base["flops"], 1), 3)))
        rows.append((name, "hlo_bytes_rel",
                     round(c["bytes"] / max(base["bytes"], 1), 3)))
        rows.append((name, "vmem_bytes", c["vmem_bytes"]))
        rows.append((name, "packed_bits_per_act", c["packed_bits_per_act"]))
    return rows
