"""Paper-table benchmarks (one function per table/figure) on the trained
mini-CNN. Each returns rows [(config, metric, value)] and asserts nothing —
assertions live in tests/test_paper_claims.py; run.py prints the CSV."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.sparq import SparqConfig
from repro.core.aciq import aciq_fake_quant


def _deltas(model, scales, configs, stc=False, fp32=None):
    fp32 = fp32 if fp32 is not None else common.cnn_accuracy(model)
    rows = [("fp32", "top1", round(fp32, 4))]
    for name, cfg in configs:
        acc = common.cnn_accuracy(model, common.quant_ctx(scales, cfg,
                                                          stc=stc))
        rows.append((name, "top1_delta", round(acc - fp32, 4)))
    return rows


def table1_precision_grid(model, scales):
    """Table 1: FP32 / A8W8 / A4W8 / A8W4 (uniform min-max, no SPARQ)."""
    configs = [
        ("a8w8", SparqConfig(enabled=False, act_bits=8, weight_bits=8)),
        ("a4w8", SparqConfig(enabled=False, act_bits=4, weight_bits=8)),
        ("a8w4", SparqConfig(enabled=False, act_bits=8, weight_bits=4)),
    ]
    return _deltas(model, scales, configs)


def table2_sparq_configs(model, scales):
    """Table 2: 5/3/2opt x {trim, +R, +R-vS}."""
    configs = []
    for opts in (5, 3, 2):
        configs += [
            (f"{opts}opt_trim", SparqConfig(bits=4, opts=opts,
                                            rounding=False, vsparq=True)),
            (f"{opts}opt_R", SparqConfig(bits=4, opts=opts,
                                         rounding=True, vsparq=True)),
            (f"{opts}opt_R_noVS", SparqConfig(bits=4, opts=opts,
                                              rounding=True, vsparq=False)),
        ]
    return _deltas(model, scales, configs)


def table3_baselines(model, scales):
    """Table 3: SPARQ vs other 4-bit PTQ schemes. SySMT == our 2opt; ACIQ ==
    analytic Laplace clip at 4 bits (per-tensor, dynamic); naive = min-max
    A4W8 (from Table 1)."""
    rows = _deltas(model, scales, [
        ("sparq_5opt", SparqConfig.opt5()),
        ("sparq_3opt", SparqConfig.opt3()),
        ("sparq_2opt_sysmt", SparqConfig.opt2()),
        ("minmax_a4w8", SparqConfig(enabled=False, act_bits=4)),
    ])
    # ACIQ baseline: clip-based 4-bit activations (dynamic per batch)
    import dataclasses
    import jax
    from repro.models import cnn
    fp32 = [r for r in rows if r[0] == "fp32"][0][2]
    cfg, params = model["cfg"], model["params"]
    accs = []
    for b in common.eval_batches(cfg):
        # fake-quant activations with ACIQ clip by monkey layer: easiest
        # honest proxy — quantize the *input image path* activations via
        # a quantized ctx whose scales are ACIQ clips from this batch.
        accs.append(float(cnn.accuracy(params, b, cfg, ctx=common.quant_ctx(
            {k: v for k, v in _aciq_scales(model, bits=4).items()},
            SparqConfig(enabled=False, act_bits=4)))))
    rows.append(("aciq_a4w8", "top1_delta", round(float(np.mean(accs)) - fp32, 4)))
    return rows


def _aciq_scales(model, bits):
    """Calibration pass that records ACIQ-Laplace clip values per site."""
    from repro.core.calibration import CalibBank
    from repro.core.quantizer import MinMaxObserver
    from repro.models import cnn
    from repro.models.common import QuantCtx
    import jax

    cfg, params = model["cfg"], model["params"]

    class ACIQBank(CalibBank):
        def observe(self, name, x):
            from repro.core.aciq import aciq_clip_laplace
            clip = float(aciq_clip_laplace(x, bits))
            obs = self.observers.get(name, MinMaxObserver())
            self.observers[name] = MinMaxObserver(
                max(obs.max_val, clip), 0.0, obs.count + 1)

    bank = ACIQBank()
    ctx = QuantCtx(mode="calibrate", collect=bank)
    for b in common.calib_batches(cfg, 128):
        cnn.forward(params, b["image"], cfg, ctx=ctx, train=False)
    return {k: o.max_val for k, o in bank.observers.items()}


def table4_low_bits(model, scales):
    """Table 4: 3-bit (6opt) and 2-bit (7opt), with and without vSPARQ."""
    configs = [
        ("3b_6opt", SparqConfig.opt6()),
        ("2b_7opt", SparqConfig.opt7()),
        ("3b_6opt_noVS", SparqConfig.opt6(vsparq=False)),
        ("2b_7opt_noVS", SparqConfig.opt7(vsparq=False)),
    ]
    return _deltas(model, scales, configs)


def table6_sparse_tc(pruned_model, scales):
    """Table 6: SPARQ on an STC with a 2:4-pruned model."""
    configs = [
        ("stc_a8w8", SparqConfig(enabled=False)),
        ("stc_4b_5opt", SparqConfig.opt5()),
        ("stc_4b_3opt", SparqConfig.opt3()),
        ("stc_4b_2opt", SparqConfig.opt2()),
        ("stc_3b_6opt", SparqConfig.opt6()),
        ("stc_2b_7opt", SparqConfig.opt7()),
    ]
    # the STC sim reconstructs per *output channel* (paper §5.3) — ~30x
    # the plain eval cost on CPU, so Table 6 uses one 256-sample batch
    fp32 = common.cnn_accuracy(pruned_model, n=256)
    rows = [("stc_fp32_pruned", "top1", round(fp32, 4))]
    for name, cfg in configs:
        stc = cfg.enabled  # A8W8 reference runs the plain path
        acc = common.cnn_accuracy(
            pruned_model, common.quant_ctx(scales, cfg, stc=stc), n=256)
        rows.append((name, "top1_delta", round(acc - fp32, 4)))
    return rows


def bit_stats(model):
    """§2/§5.1 analysis: per-bit toggle rates of non-zero activations and
    the MSB-window coverage statistic (67% claim analogue)."""
    from repro.core.calibration import CalibBank
    from repro.core.quantizer import MinMaxObserver, act_scale_from_stats, quantize
    from repro.models import cnn
    from repro.models.common import QuantCtx
    import jax

    cfg, params = model["cfg"], model["params"]
    acts = []

    class Tap(CalibBank):
        def observe(self, name, x):
            acts.append(np.asarray(x).ravel())

    ctx = QuantCtx(mode="calibrate", collect=Tap())
    b = common.eval_batches(cfg, n=256)[0]
    cnn.forward(params, b["image"], cfg, ctx=ctx, train=False)
    x = np.concatenate(acts)
    qs = act_scale_from_stats(float(x.max()), bits=8, signed=False)
    q = np.asarray(quantize(jnp.asarray(x), qs))
    nz = q[q > 0]
    rows = [("zero_fraction", "rate", round(float((q == 0).mean()), 4))]
    for bit in (7, 6, 5, 4):
        rows.append((f"bit{bit}_toggle_nonzero", "rate",
                     round(float(((nz >> bit) & 1).mean()), 4)))
    msb_high = float((nz >= 16).mean())  # any of bits [7:4] toggled
    rows.append(("msb_window_needed", "rate", round(msb_high, 4)))
    return rows
