"""Benchmark entry point: one function per paper table, CSV to stdout.

  PYTHONPATH=src python -m benchmarks.run [--tables 1,2,3,4,5,6,stats]

Output rows: table,config,metric,value
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="1,2,3,4,5,6,stats,serve")
    args = ap.parse_args()
    want = set(args.tables.split(","))

    from benchmarks import common, tables

    t0 = time.time()
    print("table,config,metric,value")
    model = common.train_cnn()
    scales = common.calibrate_cnn(model)

    if "1" in want:
        common.emit("table1", tables.table1_precision_grid(model, scales))
    if "2" in want:
        common.emit("table2", tables.table2_sparq_configs(model, scales))
    if "3" in want:
        common.emit("table3", tables.table3_baselines(model, scales))
    if "4" in want:
        common.emit("table4", tables.table4_low_bits(model, scales))
    if "5" in want:
        from benchmarks.table5_hw_cost import table5_rows
        common.emit("table5", table5_rows())
    if "6" in want:
        pruned = common.train_cnn(tag="cnn_2_4", prune_2_4=True)
        pscales = common.calibrate_cnn(pruned)
        common.emit("table6", tables.table6_sparse_tc(pruned, pscales))
    if "stats" in want:
        common.emit("bit_stats", tables.bit_stats(model))
    if "serve" in want:
        # end-to-end serving microbench on the tiny LM (tok/s, SPARQ on/off)
        from repro.launch import serve as serve_mod
        for preset in ("off", "a8w8", "5opt"):
            stats = serve_mod.main([
                "--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                "--prompt-len", "32", "--gen", "8", "--sparq", preset,
                "--calibrate", "1"])
            common.emit("serve", [
                (f"tinyllama_reduced_{preset}", "decode_tok_s",
                 round(stats["decode_tok_s"], 2)),
                (f"tinyllama_reduced_{preset}", "prefill_us",
                 round(stats["prefill_s"] * 1e6, 0))])
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
