"""Benchmark entry point: one function per paper table, CSV to stdout.

  PYTHONPATH=src python -m benchmarks.run [--tables 1,2,3,4,5,6,stats]

Output rows: table,config,metric,value. The decode_cache scenario also
writes BENCH_decode.json (decode tok/s + modeled cache bytes per KV-cache
layout) so the serving-perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def decode_cache_rows(out_json: str = "BENCH_decode.json",
                      impls: tuple = ("reference", "pallas")) -> list:
    """Decode-throughput x cache-layout sweep on the reduced tiny LM:
    fp32 / bf16 / sparq (§5.1 packed, fused decode kernel under each impl
    in `impls`) KV caches through the scan-based DecodeEngine.

    The engine runs a warmup pass first, so decode_tok_s is steady-state
    execution; the first (compiling) pass is reported as compile_s — the
    seed's bf16-slower-than-fp32 artifact was compile time, not decode."""
    from repro.launch import serve as serve_mod
    rows, blob = [], {}
    sweep = [("fp32", "reference"), ("bf16", "reference")] + \
        [("sparq", impl) for impl in impls]
    for layout, impl in sweep:
        tag = layout if layout != "sparq" else f"{layout}_{impl}"
        stats = serve_mod.main([
            "--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
            "--prompt-len", "32", "--gen", "16", "--sparq", "5opt",
            "--kv-cache", layout, "--impl", impl, "--calibrate", "1"])
        blob[tag] = {
            "decode_tok_s": round(stats["decode_tok_s"], 2),
            "prefill_s": round(stats["prefill_s"], 4),
            "compile_s": round(stats["compile_s"], 2),
            "cache_bytes_per_value": stats["cache_bytes_per_value"],
            "cache_ctrl_bytes_per_value":
                stats["cache_ctrl_bytes_per_value"],
            "cache_total_bytes": stats["cache_total_bytes"],
        }
        cfg_name = f"tinyllama_reduced_{tag}"
        rows += [(cfg_name, "decode_tok_s", blob[tag]["decode_tok_s"]),
                 (cfg_name, "cache_bytes_per_value",
                  blob[tag]["cache_bytes_per_value"]),
                 (cfg_name, "cache_total_bytes",
                  round(blob[tag]["cache_total_bytes"], 0))]
    with open(out_json, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
    print(f"# wrote {out_json}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="1,2,3,4,5,6,stats,serve,decode_cache")
    ap.add_argument("--decode-impls", default="reference,pallas",
                    help="fused-decode impls to sweep in decode_cache "
                         "(pallas runs in interpret mode off-TPU: exact "
                         "but slow — CI restricts to reference)")
    args = ap.parse_args()
    want = set(args.tables.split(","))

    from benchmarks import common, tables

    t0 = time.time()
    print("table,config,metric,value")
    model = common.train_cnn()
    scales = common.calibrate_cnn(model)

    if "1" in want:
        common.emit("table1", tables.table1_precision_grid(model, scales))
    if "2" in want:
        common.emit("table2", tables.table2_sparq_configs(model, scales))
    if "3" in want:
        common.emit("table3", tables.table3_baselines(model, scales))
    if "4" in want:
        common.emit("table4", tables.table4_low_bits(model, scales))
    if "5" in want:
        from benchmarks.table5_hw_cost import table5_rows
        common.emit("table5", table5_rows())
    if "6" in want:
        pruned = common.train_cnn(tag="cnn_2_4", prune_2_4=True)
        pscales = common.calibrate_cnn(pruned)
        common.emit("table6", tables.table6_sparse_tc(pruned, pscales))
    if "stats" in want:
        common.emit("bit_stats", tables.bit_stats(model))
    if "serve" in want:
        # end-to-end serving microbench on the tiny LM (tok/s, SPARQ on/off)
        from repro.launch import serve as serve_mod
        for preset in ("off", "a8w8", "5opt"):
            stats = serve_mod.main([
                "--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                "--prompt-len", "32", "--gen", "8", "--sparq", preset,
                "--calibrate", "1"])
            common.emit("serve", [
                (f"tinyllama_reduced_{preset}", "decode_tok_s",
                 round(stats["decode_tok_s"], 2)),
                (f"tinyllama_reduced_{preset}", "prefill_us",
                 round(stats["prefill_s"] * 1e6, 0))])
    if "decode_cache" in want:
        # KV-cache layout sweep (fp32 / bf16 / sparq) -> BENCH_decode.json
        common.emit("decode_cache", decode_cache_rows(
            impls=tuple(args.decode_impls.split(","))))
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
