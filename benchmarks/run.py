"""Benchmark entry point: one function per paper table, CSV to stdout.

  PYTHONPATH=src python -m benchmarks.run [--tables 1,2,3,4,5,6,stats]

Output rows: table,config,metric,value. The decode_cache scenario also
writes BENCH_decode.json (decode tok/s + modeled cache bytes per KV-cache
layout), paged_serving writes BENCH_paged.json (paged vs contiguous
engine tok/s + pool utilization under a ragged continuous-batching
workload), and oversubscribed_serving writes BENCH_preempt.json (tok/s +
preemption counts + swap traffic as the pool shrinks below the working
set, under both preemption policies), prefill_saturation writes
BENCH_prefill.json (sequential vs chunked admission throughput),
shared_prefix writes BENCH_prefix.json (prefix-cache off vs on under a
75%-shared-prefix workload), and latency_slo writes BENCH_slo.json
(p50/p99 TTFT + inter-token latency vs offered load through the async
streaming front-end, preemption-policy x arrival-process grid) so the
serving-perf trajectory accumulates across PRs. Every blob also carries a `compile_cache` section — the
jaxpr auditor's programs-traced / jaxprs-per-program tallies
(docs/analysis.md) — so a per-shape retrace regression is visible next
to the throughput numbers it would poison.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

_ANALYSIS_COUNTERS = None


def _analysis_counters() -> dict:
    """Jaxpr-auditor compile-cache tallies (programs traced, jaxprs per
    program), computed once per run via abstract tracing — no FLOPs.
    Folded into every BENCH blob so a per-shape retrace regression shows
    up next to the throughput numbers it would poison."""
    global _ANALYSIS_COUNTERS
    if _ANALYSIS_COUNTERS is None:
        from repro.analysis import analysis_counters
        _ANALYSIS_COUNTERS = analysis_counters()
    return _ANALYSIS_COUNTERS


def _dump(out_json: str, blob: dict, telemetry=None) -> None:
    blob = dict(blob, compile_cache=_analysis_counters())
    if telemetry is not None:
        # registry snapshot (counters/gauges + histogram p50/p99) from
        # the scenario's last measured engine, next to the numbers the
        # blob reports — one instrumentation path end to end
        blob["metrics"] = telemetry.snapshot()
    with open(out_json, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
    print(f"# wrote {out_json}", file=sys.stderr)


def decode_cache_rows(out_json: str = "BENCH_decode.json",
                      impls: tuple = ("reference", "pallas")) -> list:
    """Decode-throughput x cache-layout sweep on the reduced tiny LM:
    fp32 / bf16 / sparq (§5.1 packed, fused decode kernel under each impl
    in `impls`) KV caches through the scan-based DecodeEngine.

    The engine runs a warmup pass first, so decode_tok_s is steady-state
    execution; the first (compiling) pass is reported as compile_s — the
    seed's bf16-slower-than-fp32 artifact was compile time, not decode."""
    from repro.launch import serve as serve_mod
    rows, blob = [], {}
    sweep = [("fp32", "reference"), ("bf16", "reference")] + \
        [("sparq", impl) for impl in impls]
    for layout, impl in sweep:
        tag = layout if layout != "sparq" else f"{layout}_{impl}"
        stats = serve_mod.main([
            "--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
            "--prompt-len", "32", "--gen", "16", "--sparq", "5opt",
            "--kv-cache", layout, "--impl", impl, "--calibrate", "1"])
        blob[tag] = {
            "decode_tok_s": round(stats["decode_tok_s"], 2),
            "prefill_s": round(stats["prefill_s"], 4),
            "compile_s": round(stats["compile_s"], 2),
            "cache_bytes_per_value": stats["cache_bytes_per_value"],
            "cache_ctrl_bytes_per_value":
                stats["cache_ctrl_bytes_per_value"],
            "cache_total_bytes": stats["cache_total_bytes"],
        }
        cfg_name = f"tinyllama_reduced_{tag}"
        rows += [(cfg_name, "decode_tok_s", blob[tag]["decode_tok_s"]),
                 (cfg_name, "cache_bytes_per_value",
                  blob[tag]["cache_bytes_per_value"]),
                 (cfg_name, "cache_total_bytes",
                  round(blob[tag]["cache_total_bytes"], 0))]
    _dump(out_json, blob)
    return rows


def _ragged_workload():
    """The shared ragged continuous-batching workload: reduced tiny LM +
    8 requests whose summed lengths exceed the shared pool. Used by both
    paged_serving (BENCH_paged.json) and oversubscribed_serving
    (BENCH_preempt.json) so the two tables stay comparable across PRs.
    Returns (model, params, requests, lens, gens, page_size, slots,
    full_pool_pages)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import get_reduced_config
    from repro.launch import serve as serve_mod
    from repro.models.model import Model

    cfg_m = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg_m)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [48, 16, 64, 24, 40, 16, 56, 32]
    gens = [16, 32, 8, 24, 16, 28, 12, 20]
    reqs = [serve_mod.Request(rng.integers(0, cfg_m.vocab_size, (L,)), g)
            for L, g in zip(lens, gens)]
    return model, params, reqs, lens, gens, 16, 4, 22


def paged_serving_rows(out_json: str = "BENCH_paged.json",
                       impls: tuple = ("reference",)) -> list:
    """Paged continuous-batching benchmark -> BENCH_paged.json.

    Two comparisons on the reduced tiny LM with the sparq-5opt cache:

    equal-active-batch: the same uniform workload (B=4, prompt 32, gen 16)
    through the contiguous scan engine and the paged engine — isolates the
    cost of paging + per-step host scheduling at identical parallelism
    (acceptance: steady-state paged tok/s within ~10% of contiguous).

    ragged continuous batching: 8 requests with ragged prompts/gens over 4
    sequence slots. The page pool holds fewer slots than the requests'
    summed lengths *and* fewer than the contiguous engine's whole
    allocation for the same concurrency — short sequences no longer strand
    the capacity long ones need; eviction recycles pages mid-run.
    """
    from repro.launch import serve as serve_mod
    rows, blob = [], {}

    base = ["--arch", "tinyllama-1.1b", "--reduced", "--batch", "4",
            "--prompt-len", "32", "--gen", "16", "--sparq", "5opt",
            "--kv-cache", "sparq", "--calibrate", "1"]
    for impl in impls:
        cfg = f"tinyllama_reduced_sparq_{impl}"
        stats_c = serve_mod.main(base + ["--impl", impl])
        stats_p = serve_mod.main(base + ["--impl", impl, "--engine", "paged",
                                         "--page-size", "16",
                                         "--n-pages", "24"])
        ratio = stats_p["decode_tok_s"] / max(stats_c["decode_tok_s"], 1e-9)
        blob[f"uniform_{impl}"] = {
            "contiguous_tok_s": round(stats_c["decode_tok_s"], 2),
            "paged_tok_s": round(stats_p["decode_tok_s"], 2),
            "paged_over_contiguous": round(ratio, 3),
            "peak_pages_used": stats_p["peak_pages_used"],
            "pool_pages": stats_p["pool_pages"],
        }
        rows += [(cfg, "contiguous_tok_s", round(stats_c["decode_tok_s"], 2)),
                 (cfg, "paged_tok_s", round(stats_p["decode_tok_s"], 2)),
                 (cfg, "paged_over_contiguous", round(ratio, 3))]

    # ragged continuous batching: more requests than slots, multi-page
    # sequences, pool smaller than both the summed lengths and the
    # contiguous allocation at equal concurrency
    from repro.core.sparq import SparqConfig
    from repro.models.cache import CacheConfig
    model, params, reqs, lens, gens, ps, S, n_pages = _ragged_workload()
    ragged_impl = impls[0]      # one impl for the ragged run (recorded)
    engine = serve_mod.ContinuousBatchingEngine(
        model, CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                       impl=ragged_impl),
        page_size=ps, n_pages=n_pages, max_active=S, max_seq_len=80)
    engine.run(params, reqs)                    # compile pass, untimed
    _, stats = engine.run(params, reqs)
    summed = sum(L + g - 1 for L, g in zip(lens, gens))
    contig_equiv = S * (max(L + g - 1 for L, g in zip(lens, gens)) + 8)
    blob["ragged"] = {
        "impl": ragged_impl,
        "requests": len(reqs),
        "active_slots": S,
        "page_size": ps,
        "pool_slots": stats["pool_slots"],
        "summed_seq_lengths": summed,           # > pool_slots: pages recycle
        "contiguous_equiv_slots": contig_equiv,  # scan engine at B=4
        "decode_tok_s": round(stats["decode_tok_s"], 2),
        "peak_pages_used": stats["peak_pages_used"],
        "peak_pool_utilization": round(stats["peak_pool_utilization"], 3),
    }
    assert summed > stats["pool_slots"], "workload must overflow the pool"
    rows += [("tinyllama_reduced_ragged", k, v)
             for k, v in blob["ragged"].items()]
    _dump(out_json, blob, telemetry=engine.telemetry)
    return rows


def oversubscribed_serving_rows(out_json: str = "BENCH_preempt.json",
                                impls: tuple = ("reference",)) -> list:
    """Oversubscribed paged serving -> BENCH_preempt.json.

    The ragged continuous-batching workload is replayed through page
    pools swept from comfortable down to heavily oversubscribed, under
    both preemption policies. Per (pool, policy): steady-state decode
    tok/s, preemption/resume counts, requeue replay steps (recompute
    cost), and swap traffic (host-bandwidth cost — packed §5.1 bytes at
    0.9375 B/value modeled, ~4.3x less than swapping fp32 planes). Every
    oversubscribed run's
    greedy tokens are asserted identical to the uncontended run: the
    benchmark measures the *cost* of preemption, exactness is a given.
    """
    import numpy as np

    from repro.core.sparq import SparqConfig
    from repro.launch import serve as serve_mod
    from repro.models.cache import CacheConfig

    model, params, reqs, lens, gens, ps, S, full_pool = _ragged_workload()
    impl = impls[0]
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True), impl=impl)

    def engine(n_pages, policy):
        return serve_mod.ContinuousBatchingEngine(
            model, cc, page_size=ps, n_pages=n_pages, max_active=S,
            max_seq_len=80, policy=policy)

    base = engine(full_pool, None)
    base.run(params, reqs)                      # compile pass, untimed
    oracle, stats0 = base.run(params, reqs)
    rows, blob = [], {"impl": impl, "requests": len(reqs),
                      "page_size": ps, "active_slots": S}
    blob["uncontended"] = {
        "pool_pages": full_pool,
        "decode_tok_s": round(stats0["decode_tok_s"], 2),
        "peak_pages_used": stats0["peak_pages_used"],
    }
    for n_pages in (10, 7, 5):                  # ~0.45x / 0.32x / 0.23x
        for mode in ("requeue", "swap"):
            policy = serve_mod.SchedulerPolicy(preempt=mode,
                                               victim="last_joined")
            eng = engine(n_pages, policy)
            eng.run(params, reqs)               # compile pass, untimed
            results, stats = eng.run(params, reqs)
            for rid in oracle:                  # exactness is a given
                np.testing.assert_array_equal(results[rid], oracle[rid])
            tag = f"pool{n_pages}_{mode}"
            blob[tag] = {
                "pool_pages": n_pages,
                "policy": mode,
                "decode_tok_s": round(stats["decode_tok_s"], 2),
                "preemptions": stats["preemptions"],
                "resumes": stats["resumes"],
                "replay_steps": stats["replay_steps"],
                "resume_s": round(stats["resume_s"], 4),
                "swap_bytes_out": stats["swap_bytes_out"],
                "swap_peak_bytes": stats["swap_peak_bytes"],
                "peak_pages_used": stats["peak_pages_used"],
            }
            cfg_name = f"tinyllama_reduced_{tag}"
            rows += [(cfg_name, "decode_tok_s",
                      blob[tag]["decode_tok_s"]),
                     (cfg_name, "preemptions", stats["preemptions"]),
                     (cfg_name, "swap_bytes_out", stats["swap_bytes_out"])]
    _dump(out_json, blob, telemetry=eng.telemetry)
    return rows


def prefill_saturation_rows(out_json: str = "BENCH_prefill.json",
                            impls: tuple = ("reference",)) -> list:
    """Admission-throughput benchmark: sequential vs chunked prefill
    under a high join rate -> BENCH_prefill.json.

    The workload is an admission burst of requests with *all-distinct*
    prompt lengths arriving faster than decode drains them — the regime
    where sequential admission pays one shape-specialized XLA retrace
    per unique length and stalls the decode loop for each full prompt.
    Chunked prefill packs the ragged prompts into fixed-shape chunks
    through ONE jitted program (compile counts are reported straight
    from the jit caches).

    Two figures per mode: the *cold* run (includes compilation — the
    admission cost a serving process actually pays on a fresh length
    mix) and the *steady* re-run (programs warm). Greedy tokens are
    asserted identical between the modes; prompts fit one segment, so
    the equality is the guaranteed-exact regime.
    """
    import numpy as np

    from repro.core.sparq import SparqConfig
    from repro.launch import serve as serve_mod
    from repro.models.cache import CacheConfig

    model, params, _, _, _, ps, S, n_pages = _ragged_workload()
    impl = impls[0]
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True), impl=impl)
    rng = np.random.default_rng(1)
    # 12 requests, 12 distinct prompt lengths, short outputs, arrivals
    # every other decode step: admission-dominated
    lens = [17, 33, 46, 21, 60, 27, 38, 52, 24, 41, 19, 57]
    reqs = [serve_mod.Request(
        rng.integers(0, model.cfg.vocab_size, (L,)), 8, arrive_at=2 * i)
        for i, (L) in enumerate(lens)]
    prompt_tokens = sum(lens)

    def bench(prefill):
        kw = dict(page_size=ps, n_pages=n_pages * 2, max_active=S,
                  max_seq_len=80)
        if prefill == "chunked":
            kw.update(prefill="chunked", chunk_size=64, chunk_align=8)
        eng = serve_mod.ContinuousBatchingEngine(model, cc, **kw)
        t0 = time.perf_counter()
        results, stats = eng.run(params, reqs)       # cold: compiles
        cold_s = time.perf_counter() - t0
        _, stats2 = eng.run(params, reqs)            # steady: warm
        compiles = (stats["prefill_compile_count"]
                    if prefill == "chunked"
                    else eng._prefill._cache_size())
        return results, {
            "cold_run_s": round(cold_s, 3),
            "cold_prefill_s": round(stats["prefill_s"], 4),
            "cold_admit_tok_s": round(prompt_tokens / stats["prefill_s"],
                                      1),
            "steady_prefill_s": round(stats2["prefill_s"], 4),
            "steady_admit_tok_s": round(
                prompt_tokens / stats2["prefill_s"], 1),
            "decode_tok_s": round(stats2["decode_tok_s"], 2),
            "prefill_compiles": compiles,
            "prefill_chunks": stats2["prefill_chunks"],
        }

    res_seq, blob_seq = bench("sequential")
    res_ch, blob_ch = bench("chunked")
    for rid in res_seq:                              # exactness is a given
        np.testing.assert_array_equal(res_seq[rid], res_ch[rid])
    assert blob_ch["prefill_compiles"] == 1
    assert blob_ch["cold_admit_tok_s"] > blob_seq["cold_admit_tok_s"], \
        "chunked prefill must beat sequential admission throughput " \
        "under the distinct-length join burst"
    blob = {"impl": impl, "requests": len(reqs),
            "distinct_prompt_lengths": len(set(lens)),
            "prompt_tokens": prompt_tokens,
            "sequential": blob_seq, "chunked": blob_ch,
            "cold_admit_speedup": round(
                blob_ch["cold_admit_tok_s"] / blob_seq["cold_admit_tok_s"],
                2)}
    rows = []
    for mode, b in (("sequential", blob_seq), ("chunked", blob_ch)):
        cfg_name = f"tinyllama_reduced_prefill_{mode}"
        rows += [(cfg_name, "cold_admit_tok_s", b["cold_admit_tok_s"]),
                 (cfg_name, "steady_admit_tok_s", b["steady_admit_tok_s"]),
                 (cfg_name, "prefill_compiles", b["prefill_compiles"])]
    rows.append(("tinyllama_reduced_prefill", "cold_admit_speedup",
                 blob["cold_admit_speedup"]))
    _dump(out_json, blob)
    return rows


def shared_prefix_rows(out_json: str = "BENCH_prefix.json",
                       impls: tuple = ("reference",)) -> list:
    """Shared-prefix page reuse -> BENCH_prefix.json.

    The workload is the few-shot/system-prompt regime: 16 requests with
    64-token prompts sharing a common 48-token prefix (75%), distinct
    16-token tails, and two exact duplicates of the first prompt (the
    full-prompt-match path, whose segment-floored resume point lands
    mid-page and exercises copy-on-write). Arrivals are staggered every
    other decode step; chunked prefill with chunk_seg 8 < page_size 16
    (prefix quantum lcm = 16 tokens / 1 page).

    The trace runs twice per figure — cold (compiling) and steady — with
    the prefix cache off and on, same engine geometry otherwise. Greedy
    tokens are asserted identical: shared pages are byte-identical to
    what each sequence would have written (scheduling invariance +
    adopted frozen scales), so the cache changes cost, not output.
    Reported per mode: cold/steady admission tok/s over the *full*
    prompt token count (cache hits shrink prefill work, not the
    denominator), prefix hit rate, pages shared, CoW copies, and peak
    pool pages — admission cost and peak footprint should both drop
    roughly by the sharing factor.
    """
    import numpy as np

    from repro.core.sparq import SparqConfig
    from repro.launch import serve as serve_mod
    from repro.models.cache import CacheConfig
    from repro.models.model import Model

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_reduced_config

    cfg_m = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg_m)
    params = model.init_params(jax.random.PRNGKey(0))
    impl = impls[0]
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True), impl=impl)

    rng = np.random.default_rng(7)
    ps, S = 16, 4
    shared = rng.integers(0, cfg_m.vocab_size, (48,))   # 75% of 64
    prompts = [np.concatenate(
        [shared, rng.integers(0, cfg_m.vocab_size, (16,))])
        for _ in range(14)]
    # two exact duplicates of prompt 0, arriving while its donor is
    # still live: full-prompt matches -> mid-page resume -> CoW
    prompts = [prompts[0], prompts[0].copy(), prompts[0].copy()] + \
        prompts[1:]
    gens = [int(rng.integers(8, 17)) for _ in prompts]
    reqs = [serve_mod.Request(p, g, arrive_at=2 * i)
            for i, (p, g) in enumerate(zip(prompts, gens))]
    prompt_tokens = sum(len(p) for p in prompts)

    def bench(prefix):
        eng = serve_mod.ContinuousBatchingEngine(
            model, cc, page_size=ps, n_pages=26, max_active=S,
            max_seq_len=80, prefill="chunked", chunk_size=64,
            chunk_align=8, chunk_seg=8, prefix_cache=prefix)
        t0 = time.perf_counter()
        results, stats = eng.run(params, reqs)       # cold: compiles
        cold_s = time.perf_counter() - t0
        _, stats2 = eng.run(params, reqs)            # steady: warm
        blob = {
            "cold_run_s": round(cold_s, 3),
            "cold_prefill_s": round(stats["prefill_s"], 4),
            "cold_admit_tok_s": round(prompt_tokens / stats["prefill_s"],
                                      1),
            "steady_prefill_s": round(stats2["prefill_s"], 4),
            "steady_admit_tok_s": round(
                prompt_tokens / stats2["prefill_s"], 1),
            "decode_tok_s": round(stats2["decode_tok_s"], 2),
            "peak_pages_used": stats2["peak_pages_used"],
        }
        if prefix:
            blob.update({
                "prefix_hits": stats2["prefix_hits"],
                "prefix_misses": stats2["prefix_misses"],
                "prefix_hit_rate": round(stats2["prefix_hit_rate"], 3),
                "prefix_hit_tokens": stats2["prefix_hit_tokens"],
                "prefix_shared_pages": stats2["prefix_shared_pages"],
                "cow_copies": stats2["cow_copies"],
            })
        return results, blob

    res_off, blob_off = bench(False)
    res_on, blob_on = bench(True)
    for rid in res_off:                              # exactness is a given
        np.testing.assert_array_equal(res_off[rid], res_on[rid])
    assert blob_on["prefix_hits"] >= len(reqs) // 2, blob_on
    assert blob_on["cow_copies"] >= 1, \
        "duplicate prompts must exercise the copy-on-write path"
    assert blob_on["peak_pages_used"] < blob_off["peak_pages_used"], \
        "sharing must shrink the peak pool footprint"
    blob = {"impl": impl, "requests": len(reqs),
            "prompt_tokens": prompt_tokens,
            "shared_prefix_tokens": int(len(shared)),
            "shared_fraction": round(len(shared) / len(prompts[0]), 3),
            "off": blob_off, "on": blob_on,
            "steady_admit_speedup": round(
                blob_on["steady_admit_tok_s"] /
                blob_off["steady_admit_tok_s"], 2),
            "peak_pages_ratio": round(
                blob_on["peak_pages_used"] / blob_off["peak_pages_used"],
                3)}
    rows = []
    for mode, b in (("off", blob_off), ("on", blob_on)):
        cfg_name = f"tinyllama_reduced_prefix_{mode}"
        rows += [(cfg_name, "steady_admit_tok_s", b["steady_admit_tok_s"]),
                 (cfg_name, "peak_pages_used", b["peak_pages_used"])]
    rows += [("tinyllama_reduced_prefix", "hit_rate",
              blob_on["prefix_hit_rate"]),
             ("tinyllama_reduced_prefix", "steady_admit_speedup",
              blob["steady_admit_speedup"]),
             ("tinyllama_reduced_prefix", "peak_pages_ratio",
              blob["peak_pages_ratio"])]
    _dump(out_json, blob)
    return rows


def sharded_serving_rows(out_json: str = "BENCH_tp.json",
                         impls: tuple = ("reference",)) -> list:
    """Tensor-parallel paged serving -> BENCH_tp.json.

    Sweeps the TP degree over whatever devices are visible (CI forces 8
    CPU devices with XLA_FLAGS=--xla_force_host_platform_device_count=8;
    a bare single-device run still emits the tp=1 row) on the reduced
    tinyllama widened to 8 KV heads, chunked prefill + prefix cache on.
    Per degree: steady decode tok/s, modeled per-device pool bytes
    (packed data+ctrl shard 1/tp, bookkeeping replicated — see
    docs/sharding.md), and peak pool pages (a global scheduler figure:
    the host-side allocator does not know about tp). Greedy tokens are
    asserted bit-identical to tp=1 at every degree.
    """
    import numpy as np

    from repro.core.sparq import SparqConfig
    from repro.launch import serve as serve_mod
    from repro.launch.mesh import make_tp_mesh
    from repro.models.cache import CacheConfig
    from repro.models.model import Model

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_reduced_config

    cfg_m = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False, n_heads=16, n_kv_heads=8)
    model = Model(cfg_m)
    params = model.init_params(jax.random.PRNGKey(0))
    impl = impls[0]
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True), impl=impl)

    n_dev = len(jax.devices())
    degrees = [1] + [tp for tp in (2, 4, 8)
                     if tp <= n_dev and n_dev % tp == 0]
    if degrees == [1]:
        print("# sharded_serving: single device visible — tp=1 only "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)",
              file=sys.stderr)

    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg_m.vocab_size, (8,))
    reqs = []
    for i in range(6):
        tail = rng.integers(0, cfg_m.vocab_size, (int(rng.integers(2, 6)),))
        reqs.append(serve_mod.Request(
            np.concatenate([shared, tail]), int(rng.integers(8, 13)),
            arrive_at=2 * i))

    def bench(tp):
        eng = serve_mod.ContinuousBatchingEngine(
            model, cc, page_size=4, n_pages=24, max_active=3,
            max_seq_len=24, prefill="chunked", chunk_size=16,
            chunk_align=4, chunk_seg=2, prefix_cache=True,
            mesh=make_tp_mesh(tp) if tp > 1 else None)
        results, _ = eng.run(params, reqs)           # cold: compiles
        _, stats = eng.run(params, reqs)             # steady: warm
        assert stats["tp"] == tp
        blob = {
            "decode_tok_s": round(stats["decode_tok_s"], 2),
            "pool_bytes_per_device": int(stats["pool_bytes_per_device"]),
            "peak_pages_used": stats["peak_pages_used"],
            "prefix_hits": stats["prefix_hits"],
        }
        return results, blob

    base, per_tp = None, {}
    for tp in degrees:
        results, blob = bench(tp)
        per_tp[tp] = blob
        if base is None:
            base = results
        else:                                        # bit-identical to tp=1
            for rid in base:
                np.testing.assert_array_equal(results[rid], base[rid])
    for tp in degrees[1:]:
        # packed bytes shard 1/tp; only replicated bookkeeping remains
        assert per_tp[tp]["pool_bytes_per_device"] < \
            per_tp[1]["pool_bytes_per_device"], per_tp
        assert per_tp[tp]["peak_pages_used"] == \
            per_tp[1]["peak_pages_used"], "allocator is tp-independent"

    blob = {"impl": impl, "n_devices": n_dev, "degrees": degrees,
            "requests": len(reqs), "tokens_identical_to_tp1": True,
            "per_tp": {str(tp): per_tp[tp] for tp in degrees}}
    rows = []
    for tp in degrees:
        cfg_name = f"tinyllama_reduced_tp{tp}"
        rows += [(cfg_name, "decode_tok_s", per_tp[tp]["decode_tok_s"]),
                 (cfg_name, "pool_bytes_per_device",
                  per_tp[tp]["pool_bytes_per_device"]),
                 (cfg_name, "peak_pages_used",
                  per_tp[tp]["peak_pages_used"])]
    _dump(out_json, blob)
    return rows


def latency_slo_rows(out_json: str = "BENCH_slo.json",
                     impls: tuple = ("reference",)) -> list:
    """Latency-SLO harness over the async front-end -> BENCH_slo.json.

    The ragged workload is replayed as an open-loop timed arrival trace
    through `launch.frontend.play_trace`: requests arrive at wall-clock
    offsets (Poisson and bursty processes at the same offered load), the
    engine streams tokens per decode step, and each cell reports
    p50/p99 TTFT (first token minus *scheduled* arrival — queueing
    delay charged to the server) and pooled inter-token latency.

    The grid stresses the two scheduling knobs the engine exposes:

      * preemption policy x arrival process: {requeue, swap, auto} on a
        pool at ~0.45x the working set, under both traces — the cost
        model behind `--preempt auto` must hold up in tail latency, not
        just in replay-step/swap-byte counts (BENCH_preempt.json);
      * prefill admission: sequential vs chunked under Poisson arrivals
        (head-of-line blocking shows up directly in ITL p99), and the
        chunks-per-iteration `--prefill-priority` knob swept under
        bursty arrivals (throttling prefill trades TTFT for ITL).

    Every cell's warmup traffic runs through the same live engine loop
    and is erased at the measure boundary by `engine.reset_stats()`
    (play_trace does this), and every cell's streamed tokens are
    asserted bit-identical to a synchronous `engine.run` oracle —
    scheduling moves latency, never tokens.

    The SLO percentiles come from the shared `frontend_ttft_seconds` /
    `frontend_itl_seconds` histograms in the engine's metrics registry
    (repro.obs) — the same series the Prometheus exposition reports.
    Two extra cells exercise the telemetry layer itself: a fully traced
    Poisson/requeue run whose Prometheus dump and Perfetto trace are
    written next to the blob (BENCH_slo_metrics.prom /
    BENCH_slo_trace.json), and an instrumentation-overhead sweep
    reporting steady decode tok/s with tracing off vs metrics-only vs
    full span tracing (docs/observability.md).
    """
    import numpy as np

    from repro.core.sparq import SparqConfig
    from repro.launch import frontend
    from repro.launch import serve as serve_mod
    from repro.models.cache import CacheConfig

    model, params, reqs, lens, gens, ps, S, full_pool = _ragged_workload()
    impl = impls[0]
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True), impl=impl)
    n_pages = 10                        # ~0.45x working set: preempts
    n = len(reqs)
    rate = 24.0                         # req/s offered load
    rng = np.random.default_rng(7)
    traces = {k: frontend.arrival_times(k, n, rate, rng=rng)
              for k in ("poisson", "bursty")}

    def engine(preempt="requeue", prefill="chunked", priority=1.0,
               telemetry=None, pool=None):
        policy = serve_mod.SchedulerPolicy(preempt=preempt,
                                           victim="last_joined")
        kw = {}
        if prefill == "chunked":
            kw = dict(chunk_size=32, chunk_align=8,
                      prefill_priority=priority)
        return serve_mod.ContinuousBatchingEngine(
            model, cc, page_size=ps, n_pages=pool or n_pages,
            max_active=S, max_seq_len=80, policy=policy, prefill=prefill,
            telemetry=telemetry, **kw)

    warm = [(r.tokens, r.gen) for r in reqs]

    def cell(eng, trace_kind, *, warmup=warm, oracle=None):
        trace = [(r.tokens, r.gen, at)
                 for r, at in zip(reqs, traces[trace_kind])]
        out, slo, stats = frontend.play_trace(eng, params, trace,
                                              warmup=warmup)
        if oracle is not None:          # exactness is a given
            for i in range(n):
                np.testing.assert_array_equal(out[i], oracle[i])
        span = max(traces[trace_kind]) or 1.0
        return {
            "trace": trace_kind, "policy": eng.policy.preempt,
            "prefill": eng.prefill_mode,
            "prefill_priority": eng.prefill_priority,
            "offered_load_req_s": round(n / span, 2),
            "ttft_p50_ms": round(slo["ttft"]["p50_ms"], 2),
            "ttft_p99_ms": round(slo["ttft"]["p99_ms"], 2),
            "itl_p50_ms": round(slo["itl"]["p50_ms"], 3),
            "itl_p99_ms": round(slo["itl"]["p99_ms"], 3),
            "decode_tok_s": round(stats["decode_tok_s"], 2),
            "preemptions": stats["preemptions"],
            "resumes": stats["resumes"],
            "swap_bytes_out": stats["swap_bytes_out"],
        }

    # one synchronous oracle: greedy tokens are arrival/policy-invariant
    base = engine()
    oracle, _ = base.run(params, reqs)  # also compiles base's programs

    blob = {"impl": impl, "requests": n, "page_size": ps,
            "active_slots": S, "pool_pages": n_pages,
            "offered_rate_req_s": rate,
            "arrival_offsets_s": {k: [round(t, 4) for t in v]
                                  for k, v in traces.items()},
            "cells": {}}
    rows = []

    # policy x arrival-process grid (chunked prefill, priority 1.0)
    engines = {"requeue": base, "swap": engine("swap"),
               "auto": engine("auto")}
    for mode, eng in engines.items():
        for kind in ("poisson", "bursty"):
            tag = f"{kind}_{mode}"
            blob["cells"][tag] = cell(eng, kind, oracle=oracle)
    # admission comparison: sequential prefill under Poisson arrivals
    blob["cells"]["poisson_requeue_sequential"] = cell(
        engine(prefill="sequential"), "poisson", oracle=oracle)
    # prefill-priority sweep under bursty arrivals (1.0 is in the grid)
    for pr in (0.25, 4.0):
        blob["cells"][f"bursty_requeue_prio{pr}"] = cell(
            engine(priority=pr), "bursty", oracle=oracle)

    # fully instrumented cell: the same Poisson/requeue point with span
    # tracing on — streamed tokens still asserted against the oracle
    # (instrumentation must never move tokens), and the run's telemetry
    # is committed next to the blob: a Prometheus exposition that must
    # re-parse, and a Perfetto-loadable Chrome trace
    from repro.obs import Telemetry
    from repro.obs import export as obs_export
    tel = Telemetry.tracing()
    blob["cells"]["poisson_requeue_traced"] = cell(
        engine(telemetry=tel), "poisson", oracle=oracle)
    prom_path = out_json.replace(".json", "_metrics.prom")
    trace_path = out_json.replace(".json", "_trace.json")
    obs_export.write_prometheus(tel.registry, prom_path)
    obs_export.write_trace(tel.tracer, trace_path)
    parsed = obs_export.parse_prometheus(open(prom_path).read())
    assert parsed[("frontend_ttft_seconds_count", "")] == n
    with open(trace_path) as f:
        n_events = len(json.load(f)["traceEvents"])
    blob["trace_artifacts"] = {
        "prometheus": prom_path, "prometheus_series": len(parsed),
        "perfetto_trace": trace_path, "trace_events": n_events,
    }
    print(f"# wrote {prom_path}", file=sys.stderr)
    print(f"# wrote {trace_path}", file=sys.stderr)

    # instrumentation overhead: steady decode tok/s on an uncontended
    # pool (no preemption noise) under the three telemetry levels —
    # counters only (default), + step-phase histograms, + span tracing.
    # Best-of-3 measured runs per level; the tracing column is the
    # full cost of per-iteration stamps, span bookkeeping and per-token
    # instants on the host loop.
    levels = {"off": None, "metrics_only": Telemetry.metrics_only(),
              "tracing": Telemetry.tracing()}
    blob["instrumentation_overhead"] = {}
    tok_s = {}
    for name, lv_tel in levels.items():
        eng = engine(telemetry=lv_tel, pool=full_pool)
        eng.run(params, reqs)               # compile pass, untimed
        best = 0.0
        for _ in range(3):
            _, st = eng.run(params, reqs)
            best = max(best, st["decode_tok_s"])
        tok_s[name] = best
        blob["instrumentation_overhead"][name] = {
            "decode_tok_s": round(best, 2),
            "vs_off": round(best / max(tok_s["off"], 1e-9), 4),
        }
        rows.append((f"tinyllama_reduced_obs_{name}",
                     "decode_tok_s", round(best, 2)))
    # egregious-regression tripwire only — machine noise makes a tight
    # bound flaky in CI; the measured ratio is recorded in the blob
    assert tok_s["tracing"] >= 0.7 * tok_s["off"], (
        f"full tracing costs >30% decode throughput: {tok_s}")

    for tag, c in blob["cells"].items():
        cfg_name = f"tinyllama_reduced_slo_{tag}"
        rows += [(cfg_name, m, c[m])
                 for m in ("offered_load_req_s", "ttft_p50_ms",
                           "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms",
                           "preemptions")]
    _dump(out_json, blob, telemetry=tel)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables",
                    default="1,2,3,4,5,6,stats,serve,decode_cache,"
                            "paged_serving,oversubscribed_serving,"
                            "prefill_saturation,shared_prefix,"
                            "sharded_serving,latency_slo")
    ap.add_argument("--decode-impls", default="reference,pallas",
                    help="fused-decode impls to sweep in decode_cache "
                         "(pallas runs in interpret mode off-TPU: exact "
                         "but slow — CI restricts to reference)")
    args = ap.parse_args()
    want = set(args.tables.split(","))

    from benchmarks import common, tables

    t0 = time.perf_counter()
    print("table,config,metric,value")
    model = common.train_cnn()
    scales = common.calibrate_cnn(model)

    if "1" in want:
        common.emit("table1", tables.table1_precision_grid(model, scales))
    if "2" in want:
        common.emit("table2", tables.table2_sparq_configs(model, scales))
    if "3" in want:
        common.emit("table3", tables.table3_baselines(model, scales))
    if "4" in want:
        common.emit("table4", tables.table4_low_bits(model, scales))
    if "5" in want:
        from benchmarks.table5_hw_cost import table5_rows
        common.emit("table5", table5_rows())
    if "6" in want:
        pruned = common.train_cnn(tag="cnn_2_4", prune_2_4=True)
        pscales = common.calibrate_cnn(pruned)
        common.emit("table6", tables.table6_sparse_tc(pruned, pscales))
    if "stats" in want:
        common.emit("bit_stats", tables.bit_stats(model))
    if "serve" in want:
        # end-to-end serving microbench on the tiny LM (tok/s, SPARQ on/off)
        from repro.launch import serve as serve_mod
        for preset in ("off", "a8w8", "5opt"):
            stats = serve_mod.main([
                "--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                "--prompt-len", "32", "--gen", "8", "--sparq", preset,
                "--calibrate", "1"])
            common.emit("serve", [
                (f"tinyllama_reduced_{preset}", "decode_tok_s",
                 round(stats["decode_tok_s"], 2)),
                (f"tinyllama_reduced_{preset}", "prefill_us",
                 round(stats["prefill_s"] * 1e6, 0))])
    if "decode_cache" in want:
        # KV-cache layout sweep (fp32 / bf16 / sparq) -> BENCH_decode.json
        common.emit("decode_cache", decode_cache_rows(
            impls=tuple(args.decode_impls.split(","))))
    if "paged_serving" in want:
        # paged vs contiguous engines + ragged continuous batching
        common.emit("paged_serving", paged_serving_rows(
            impls=tuple(args.decode_impls.split(","))))
    if "oversubscribed_serving" in want:
        # preemption cost sweep: pool size x policy -> BENCH_preempt.json
        common.emit("oversubscribed_serving", oversubscribed_serving_rows(
            impls=tuple(args.decode_impls.split(","))))
    if "prefill_saturation" in want:
        # admission burst: sequential vs chunked prefill -> BENCH_prefill
        common.emit("prefill_saturation", prefill_saturation_rows(
            impls=tuple(args.decode_impls.split(","))))
    if "shared_prefix" in want:
        # shared-prefix page reuse: cache off vs on -> BENCH_prefix.json
        common.emit("shared_prefix", shared_prefix_rows(
            impls=tuple(args.decode_impls.split(","))))
    if "sharded_serving" in want:
        # tensor-parallel sweep: tok/s + per-device pool bytes vs tp
        common.emit("sharded_serving", sharded_serving_rows(
            impls=tuple(args.decode_impls.split(","))))
    if "latency_slo" in want:
        # async streaming front-end: TTFT/ITL percentiles vs offered
        # load, policy x arrival-process grid -> BENCH_slo.json
        common.emit("latency_slo", latency_slo_rows(
            impls=tuple(args.decode_impls.split(","))))
    print(f"# total {time.perf_counter()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
