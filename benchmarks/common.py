"""Shared benchmark harness: trains (and caches) the paper-faithful mini
CNN and a tiny LM on synthetic tasks, provides quantized-accuracy eval.

All tables report RELATIVE top-1 degradation vs the FP32 model, mirroring
the paper's presentation. Absolute numbers differ from ImageNet (synthetic
task, small model — DESIGN.md §7); the claims under test are the paper's
orderings: 5opt>=3opt>=2opt, +R>=-R, +vS>=-vS, 4b>3b>2b, SPARQ >> naive
A4W8 / plain trim baselines.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparq import SparqConfig
from repro.models import cnn
from repro.models.common import QuantCtx

CACHE = os.path.join(os.path.dirname(__file__), ".cache")
SEED = 42
N_EVAL = 3072
N_CALIB = 256          # "2K randomly picked images" scaled to task size
TRAIN_STEPS = 420
BATCH = 96


_RECIPE_V = 2   # bump when training/recalibration changes invalidate caches


def _cache_path(tag):
    os.makedirs(CACHE, exist_ok=True)
    return os.path.join(CACHE, f"{tag}_v{_RECIPE_V}.npz")


def train_cnn(cfg: Optional[cnn.CNNConfig] = None, tag="cnn",
              steps=None, prune_2_4: bool = False) -> Dict:
    """Train (or load cached) mini-ResNet; optionally with 2:4 pruning
    (paper §5.3: prune from pretrained, retrain)."""
    # 2:4 recovery needs a longer masked-retraining phase (paper: 90 ep)
    steps = steps or (3 * TRAIN_STEPS // 2 if prune_2_4 else TRAIN_STEPS)
    cfg = cfg or cnn.CNNConfig(width=24, stages=(1, 1, 1), num_classes=8,
                               img_size=24)
    path = _cache_path(tag)
    params = cnn.init_params(jax.random.PRNGKey(SEED), cfg)
    if os.path.exists(path):
        flat = dict(np.load(path))
        leaves, tdef = jax.tree_util.tree_flatten(params)
        params = jax.tree_util.tree_unflatten(
            tdef, [jnp.asarray(flat[str(i)]) for i in range(len(leaves))])
        return {"cfg": cfg, "params": params}

    from repro.core.pruning import prune_2_4 as prune_fn
    from repro.optim.adamw import AdamW, cosine_schedule
    opt = AdamW(lr=cosine_schedule(3e-3, 20, steps), weight_decay=1e-4)
    state = opt.init(params)

    def apply_prune(p):
        def prune_leaf(path, leaf):
            name = str(path[-1])
            if leaf.ndim == 4 and "stem" not in str(path):
                w2 = leaf.reshape(-1, leaf.shape[-1])
                return prune_fn(w2, axis=0).reshape(leaf.shape)
            return leaf
        return jax.tree_util.tree_map_with_path(prune_leaf, p)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: cnn.loss_fn(p, batch, cfg))(params)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    for i in range(steps):
        batch = cnn.synthetic_dataset(
            jax.random.fold_in(jax.random.PRNGKey(SEED + 1), i), cfg, BATCH)
        params, state, loss = step(params, state, batch)
        if prune_2_4 and i >= steps // 4:   # prune, then keep training
            params = apply_prune(params)
    # the train loop normalizes with batch stats and never maintains the BN
    # running stats — set them from the training distribution before eval
    # (calibrate_cnn recalibrates again on the calibration set, paper §5)
    params = cnn.recalibrate_bn(
        params, [cnn.synthetic_dataset(
            jax.random.fold_in(jax.random.PRNGKey(SEED + 2), i), cfg, BATCH)
            for i in range(16)], cfg)
    if prune_2_4:
        params = apply_prune(params)

    leaves = jax.tree_util.tree_flatten(params)[0]
    np.savez(path, **{str(i): np.asarray(l) for i, l in enumerate(leaves)})
    return {"cfg": cfg, "params": params}


def eval_batches(cfg, n=N_EVAL, batch=256, seed=SEED + 7):
    out = []
    for i in range(n // batch):
        out.append(cnn.synthetic_dataset(
            jax.random.fold_in(jax.random.PRNGKey(seed), i), cfg, batch))
    return out


def calib_batches(cfg, n=N_CALIB, batch=128, seed=SEED + 13):
    return eval_batches(cfg, n=n, batch=batch, seed=seed)


def calibrate_cnn(model: Dict) -> Dict[str, float]:
    """min-max activation calibration + BN recalibration (paper §5)."""
    from repro.core.calibration import CalibBank
    cfg, params = model["cfg"], model["params"]
    params = cnn.recalibrate_bn(params, calib_batches(cfg, 128), cfg)
    model["params"] = params
    bank = CalibBank()
    ctx = QuantCtx(mode="calibrate", collect=bank)
    for b in calib_batches(cfg, 128):
        cnn.forward(params, b["image"], cfg, ctx=ctx, train=False)
    return {k: float(o.max_val) for k, o in bank.observers.items()}


def cnn_accuracy(model: Dict, ctx: Optional[QuantCtx] = None,
                 n=N_EVAL, batch=256) -> float:
    cfg, params = model["cfg"], model["params"]
    fn = jax.jit(lambda p, b: cnn.accuracy(p, b, cfg, ctx=ctx))
    accs = [float(fn(params, b)) for b in eval_batches(cfg, n, batch=batch)]
    return float(np.mean(accs))


def quant_ctx(scales: Dict[str, float], cfg: SparqConfig,
              stc: bool = False) -> QuantCtx:
    return QuantCtx(mode="quantized", cfg=cfg,
                    scales={k: jnp.float32(v) for k, v in scales.items()},
                    impl="reference", stc=stc)


def timed(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def emit(table: str, rows):
    """CSV rows: table,config,metric,value."""
    for config, metric, value in rows:
        print(f"{table},{config},{metric},{value}")
