"""Distributed-optimization tricks (DESIGN.md §5, beyond paper):

1. SPARQ gradient compression with error feedback — the paper's own
   windowed-quantization idea re-applied to the gradient all-reduce:
   gradients are quantized to int8 then bSPARQ'd to 4 bits + 3-bit shift
   (7.5 effective bits incl. pair metadata -> ~4x reduce-scatter volume vs
   f32). Error feedback makes the compression unbiased over time (the
   residual is added back the next step), the standard convergence fix.

2. Hierarchical pod reduction for shard_map code paths: reduce within a
   pod's 'data' axis first, then across the 'pod' axis — two small hops on
   fast intra-pod ICI instead of one 512-way ring over the pod link.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.bsparq import bsparq_recon_signed, shifts_for


def sparq_compress(g: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Fake-quant SPARQ compression of one gradient tensor (per-tensor
    scale; signed windowed 4-bit). Returns the reconstruction (what the
    receiving side would decode)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
    r = bsparq_recon_signed(q, bits, shifts_for(bits, 8 - bits + 1),
                            rounding=True)
    return r.astype(g.dtype) * scale


@dataclasses.dataclass
class GradCompressor:
    """Error-feedback SPARQ gradient compression.

    state: residual pytree (same structure as grads, zeros at init).
    `compress(grads, state) -> (compressed_grads, new_state)`; the
    compressed grads are what crosses the wire (here: what the all-reduce
    sees), the residual carries the quantization error to the next step.
    """
    bits: int = 4
    min_size: int = 4096   # tiny tensors (norms, scalars) stay exact

    def init(self, grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def compress(self, grads: Any, state: Any) -> Tuple[Any, Any]:
        def one(g, e):
            if g.size < self.min_size:
                return g, jnp.zeros_like(e)
            target = g.astype(jnp.float32) + e
            c = sparq_compress(target, self.bits)
            return c.astype(g.dtype), target - c.astype(jnp.float32)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(state)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
                jax.tree.unflatten(tdef, [o[1] for o in outs]))


def hierarchical_psum(x: jnp.ndarray, pod_axis: str = "pod",
                      data_axis: str = "data") -> jnp.ndarray:
    """Two-stage all-reduce for shard_map bodies on the multi-pod mesh."""
    x = jax.lax.psum(x, data_axis)
    return jax.lax.psum(x, pod_axis)


def compressed_psum(x: jnp.ndarray, axis: str, bits: int = 4) -> jnp.ndarray:
    """shard_map building block: SPARQ-compress, then reduce. The quantize
    happens before the wire so the reduce moves 8-bit codes; the fake-quant
    emulation here preserves exact arithmetic of the decoded values."""
    return jax.lax.psum(sparq_compress(x, bits), axis)
