"""distributed subsystem."""
