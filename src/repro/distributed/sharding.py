"""Sharding rules: FSDP (+ZeRO) over the data axes x tensor/expert
parallelism over the model axis, with sequence-parallel residual streams.

`param_pspecs` pattern-matches parameter names to PartitionSpecs and then
*fits* each spec to the actual shape (a mesh axis that does not divide the
corresponding dimension is dropped, e.g. whisper's 51865 vocab over a
16-way model axis). The same machinery produces optimizer-state, cache and
batch specs, so everything the step functions touch is covered.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig

TP = "model"


def dp_axes(mesh: Mesh, tensor_parallel: bool = True):
    """Data-parallel axes: ('pod','data') on the multi-pod mesh; with
    tensor parallelism off, the model axis joins the DP/FSDP group
    (pure ZeRO layout for models too small to TP over 16)."""
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return dp if tensor_parallel else dp + (TP,)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def fit_spec(shape: tuple, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide their dimension. For tuple
    entries (merged DP groups) try suffixes first: a batch of 256 on the
    512-chip ('pod','data','model') group falls back to ('data','model')
    instead of replicating (§Perf iteration 16)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, entries):
        if isinstance(axis, (tuple, list)):
            fitted = None
            for i in range(len(axis)):
                cand = tuple(axis[i:])
                if dim > 0 and dim % _axis_size(mesh, cand) == 0:
                    fitted = cand if len(cand) > 1 else cand[0]
                    break
            out.append(fitted)
        elif axis is not None and dim > 0 and \
                dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# parameter-name -> base spec builders (dp = FSDP axes tuple)
def _rules(dp):
    col = P(dp, TP)        # column-parallel: [d_in, d_out-sharded]
    row = P(TP, dp)        # row-parallel:    [d_in-sharded, d_out]
    return {
        "embed": P(TP, dp),          # [vocab, d]
        "lm_head": col,              # [d, vocab]
        # attention
        "wq": col, "wk": col, "wv": col, "wo": row,
        # ffn
        "w_up": col, "w_gate": col, "w_down": row,
        # moe experts get 3-D handling below; router:
        "router": P(dp, None),
        "sh_up": P(None, dp, TP), "sh_gate": P(None, dp, TP),
        "sh_down": P(None, TP, dp),
        # mla
        "w_dkv": P(dp, None), "w_uk": P(None, TP), "w_uv": P(None, TP),
        # rwkv
        "w_r": col, "w_k": col, "w_v": col, "w_g": col, "w_o": row,
        "w_ck": col, "w_cr": col, "w_cv": row,
        "w_A": P(dp, None), "w_B": P(None, dp),
        # rg-lru
        "w_y": col, "w_x": col, "w_a": P(TP, None), "w_i": P(TP, None),
        "w_out": row, "conv_k": P(None, TP),
    }


_EXPERT_KEYS = ("w_up", "w_gate", "w_down")


def param_pspecs(params: Any, mesh: Mesh,
                 tensor_parallel: bool = True) -> Any:
    """PartitionSpec tree matching `params` (arrays or ShapeDtypeStructs)."""
    dp = dp_axes(mesh, tensor_parallel)
    rules = _rules(dp)
    if not tensor_parallel:  # ZeRO: shard first dim over everything
        rules = {k: P(dp) if len(v) and v[0] is not None else
                 (P(None, dp) if len(v) > 1 else P(dp))
                 for k, v in rules.items()}
        rules["embed"] = P(dp)
        rules["lm_head"] = P(dp)

    def spec_for(path, leaf) -> P:
        names = [k for k in (getattr(e, "key", getattr(e, "name", None))
                             for e in path) if isinstance(k, str)]
        name = names[-1] if names else None
        is_scale = False
        if name in ("q", "s") and len(names) >= 2:  # pre-quantized weight
            is_scale = name == "s"
            name = names[-2]
        shape = leaf.shape
        base = rules.get(name)
        if is_scale and base is not None:
            # per-output-channel scales [*, d_out]: keep only d_out's axis
            base = P(base[-1]) if len(base) else P()
        nd = len(shape)
        if base is None:
            base = P()          # norms, scalars, vectors: replicate
        elif name in _EXPERT_KEYS and nd == 4:
            # stacked MoE experts [L, E, din, dout]: EP over model +
            # FSDP over din (3-D w_up/w_gate/w_down are stacked *dense*
            # FFNs [L, din, dout] and take the layer rule below)
            base = P(None, TP, dp, None)
        elif nd == len(base) + 1:
            base = P(None, *base)        # stacked layers: leading L dim
        return fit_spec(shape, base, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_pspecs(batch: Any, mesh: Mesh,
                 tensor_parallel: bool = True) -> Any:
    """Batch dim over all data axes; sequence unsharded at input."""
    dp = dp_axes(mesh, tensor_parallel)

    def spec_for(leaf):
        return fit_spec(leaf.shape, P(dp), mesh)

    return jax.tree.map(spec_for, batch)


def cache_pspecs(caches: Any, model, mesh: Mesh,
                 tensor_parallel: bool = True) -> Any:
    """Decode-cache specs. Leading dim is the stacked layer axis; batch
    over dp. KV time axes (dim 2 of [L,B,T,KV,hd]) shard over the model
    axis — flash-decoding style: QK^T contracts hd (unsharded), scores and
    the PV partial sums reduce over the sequence with tiny [B,H] "
    all-reduces instead of hd-partial score reductions (§Perf iteration 3).
    Falls back to the last dim, then batch-only, when T doesn't divide."""
    dp = dp_axes(mesh, tensor_parallel)

    def spec_for(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd <= 1:           # per-layer scalars (pos counters)
            return P()
        entries = [None] * nd
        entries[1] = dp
        if tensor_parallel and nd >= 4:
            entries[2] = TP                      # sequence axis
            spec = fit_spec(shape, P(*entries), mesh)
            if spec[2] is not None:
                return spec
            entries[2] = None
        if tensor_parallel and nd >= 3:
            entries[-1] = TP                     # state width fallback
        return fit_spec(shape, P(*entries), mesh)

    return jax.tree.map(spec_for, caches)


def shardings_of(tree: Any, specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def activation_spec(mesh: Mesh, sp: bool = False,
                    tensor_parallel: bool = True) -> P:
    """Residual-stream constraint [B, T, D]: batch over dp (+ sequence over
    model when sequence parallelism is on)."""
    dp = dp_axes(mesh, tensor_parallel)
    return P(dp, TP if (sp and tensor_parallel) else None, None)


# ---------------------------------------------------------------------
# activation-constraint hooks: launch code pins the mesh context before
# tracing; model code calls constrain()/constrain_heads() at boundaries.
# ---------------------------------------------------------------------
_ACT_SPEC: Optional[P] = None
_MESH: Optional[Mesh] = None
_TP: bool = True


def set_activation_spec(spec: Optional[P], mesh: Optional[Mesh] = None,
                        tensor_parallel: bool = True) -> None:
    global _ACT_SPEC, _MESH, _TP
    _ACT_SPEC = spec
    _MESH = mesh
    _TP = tensor_parallel


def constrain(x: jnp.ndarray) -> jnp.ndarray:
    """Residual stream [B, T, D] constraint at layer boundaries."""
    if _ACT_SPEC is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _ACT_SPEC)


def constrain_axis(x: jnp.ndarray, candidates: tuple[int, ...]):
    """Pin batch (dim 0) over dp and the first *divisible* candidate axis
    over the model axis. Used to keep GSPMD from replicating big recurrent /
    blocked-attention intermediates across the model axis."""
    if _MESH is None:
        return x
    dp = dp_axes(_MESH, _TP)
    if not _TP:  # ZeRO mode: batch over everything, no model-axis use
        return jax.lax.with_sharding_constraint(
            x, fit_spec(x.shape, P(dp), _MESH))
    for ax in candidates:
        if ax >= x.ndim:
            continue
        entries = [None] * x.ndim
        entries[0] = dp
        entries[ax] = TP
        spec = fit_spec(x.shape, P(*entries), _MESH)
        if spec[ax] is not None:
            return jax.lax.with_sharding_constraint(x, spec)
    spec = fit_spec(x.shape, P(dp), _MESH)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_heads(x: jnp.ndarray) -> jnp.ndarray:
    """Pin [B, T, H, hd] attention tensors: batch over dp, heads over the
    model axis when divisible, else REPLICATED over model (batch-only).

    Never fall back to sharding head_dim: hd is the contraction dim of
    QK^T, and a contraction-sharded operand turns every flash score block
    into a partial-sum all-reduce (measured: 5.7 TB/device on
    starcoder2-3b prefill_32k — EXPERIMENTS.md §Perf iteration 1)."""
    if _MESH is None or x.ndim != 4:
        return x
    return constrain_axis(x, (2,))


def constrain_last(x: jnp.ndarray) -> jnp.ndarray:
    """Pin [B, T, W] width-major recurrent tensors (RG-LRU, token-shift)."""
    if _MESH is None or x.ndim != 3:
        return x
    return constrain_axis(x, (2,))


# ----------------------------------------------------------------------
# paged-serving pool specs (tensor-parallel ContinuousBatchingEngine)
# ----------------------------------------------------------------------

def pool_plane_pspec(ndim: int) -> P:
    """PartitionSpec for one packed §5.1 page-pool plane: the KV-head
    axis (always ndim-2: [..., P, ps, KV, hd]) shards over the model
    axis, everything else — pages, rows, head_dim, an optional leading
    layer-stack axis — is replicated. Head groups never split because
    the engine validates n_kv_heads % tp == 0 up front."""
    entries = [None] * ndim
    entries[ndim - 2] = TP
    return P(*entries)


def pool_plane_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    return NamedSharding(mesh, pool_plane_pspec(ndim))


def paged_pool_pspecs(store) -> Any:
    """A PagedCacheStore-shaped pytree of PartitionSpecs: packed data and
    meta pools shard by KV head, bookkeeping (per-sequence scales, block
    tables, positions) stays replicated — the host-side allocator/prefix
    index/scheduler are global, so every device sees the same tables."""
    import dataclasses as _dc
    pools = {"k_data", "k_meta", "v_data", "v_meta"}
    specs = {name: (pool_plane_pspec(getattr(store, name).ndim)
                    if name in pools else P())
             for name in ("k_data", "k_meta", "v_data", "v_meta",
                          "k_scale", "v_scale", "block_table", "seq_pos")}
    return _dc.replace(store, **specs)


def paged_pool_shardings(store, mesh: Mesh) -> Any:
    """Same tree with NamedShardings — ready for `jax.device_put`."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        paged_pool_pspecs(store))


def constrain_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Megatron-SP re-entry point: gather the sequence axis back (batch-only
    sharding) before the TP matmuls of a block. Without this, GSPMD keeps
    the sequence on the model axis and full-gathers the *weights* instead —
    catastrophically worse (weights >> activations per microbatch)."""
    if _MESH is None or x.ndim != 3:
        return x
    dp = dp_axes(_MESH, _TP)
    return jax.lax.with_sharding_constraint(
        x, fit_spec(x.shape, P(dp, None, None), _MESH))
