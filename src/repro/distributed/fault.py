"""Fault tolerance: heartbeats, straggler detection, elastic remesh plans.

On a real multi-pod deployment these hooks sit in the coordinator:
workers heartbeat every step; a worker silent past `timeout_s` is declared
dead and an elastic remesh plan is generated (largest usable device grid),
after which the job restores the latest checkpoint onto the new mesh
(checkpoint.manager restores are mesh-elastic by construction).
Stragglers are flagged by step-time z-score against the fleet EWMA —
the scheduler's cue to re-replicate input shards or demote the host.
This module is deliberately pure-python state (deterministic, unit-tested);
the simulated cluster in tests/test_fault.py drives it.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 60.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)
    last_step: Dict[int, int] = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, step: int, now: Optional[float] = None):
        self.last_seen[worker] = time.monotonic() if now is None else now
        self.last_step[worker] = step

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def alive(self, now: Optional[float] = None) -> List[int]:
        dead = set(self.dead_workers(now))
        return [w for w in self.last_seen if w not in dead]


@dataclasses.dataclass
class StragglerDetector:
    """Per-worker EWMA of step time; z-score against fleet distribution."""
    alpha: float = 0.2
    z_threshold: float = 3.0
    ewma: Dict[int, float] = dataclasses.field(default_factory=dict)

    def record(self, worker: int, step_time: float):
        prev = self.ewma.get(worker, step_time)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * step_time

    def stragglers(self) -> List[int]:
        if len(self.ewma) < 4:
            return []
        vals = list(self.ewma.values())
        mean = sum(vals) / len(vals)
        var = sum((v - mean) ** 2 for v in vals) / len(vals)
        std = math.sqrt(var) + 1e-9
        return [w for w, v in self.ewma.items()
                if (v - mean) / std > self.z_threshold]


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_workers: Tuple[int, ...]
    restore_step: Optional[int]


def plan_remesh(n_available: int, model_parallel: int = 16,
                dropped: Tuple[int, ...] = (),
                restore_step: Optional[int] = None) -> RemeshPlan:
    """Elastic scaling policy: keep the model axis fixed (TP degree is a
    property of the model's memory footprint), shrink the data axis to the
    largest multiple that fits, splitting off a pod axis when the grid
    spans >= 2 * 256 chips."""
    if n_available < model_parallel:
        raise ValueError(
            f"need >= {model_parallel} devices for TP, have {n_available}")
    data = n_available // model_parallel
    # power-of-two data axis keeps batch divisibility stable across remeshes
    data = 2 ** int(math.log2(data))
    if data * model_parallel >= 512 and data % 2 == 0:
        return RemeshPlan((2, data // 2, model_parallel),
                          ("pod", "data", "model"), tuple(dropped),
                          restore_step)
    return RemeshPlan((data, model_parallel), ("data", "model"),
                      tuple(dropped), restore_step)


@dataclasses.dataclass
class ElasticCoordinator:
    """Glue: heartbeats + stragglers -> remesh decision."""
    n_workers: int
    model_parallel: int = 16
    monitor: HeartbeatMonitor = dataclasses.field(
        default_factory=HeartbeatMonitor)
    detector: StragglerDetector = dataclasses.field(
        default_factory=StragglerDetector)

    def step_report(self, worker: int, step: int, step_time: float,
                    now: Optional[float] = None):
        self.monitor.beat(worker, step, now)
        self.detector.record(worker, step_time)

    def maybe_remesh(self, restore_step: Optional[int] = None,
                     now: Optional[float] = None) -> Optional[RemeshPlan]:
        dead = self.monitor.dead_workers(now)
        if not dead:
            return None
        alive = len(self.monitor.alive(now))
        return plan_remesh(alive, self.model_parallel, tuple(dead),
                           restore_step)
