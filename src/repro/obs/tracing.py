"""Request-lifecycle and scheduler-step tracing as Chrome trace events.

Two layers:

- ``Tracer`` — an append-only buffer of Chrome trace-event dicts
  (``ph`` in B/E/X/i/C/M), timestamps in microseconds relative to the
  first event.  ``obs.export.write_trace`` wraps the buffer in the
  ``{"traceEvents": [...]}`` envelope that Perfetto and
  ``chrome://tracing`` load directly.
- ``EngineSpans`` — the serving engine's view: a per-request span state
  machine (submitted -> queued -> prefill -> decode -> preempted/
  resumed -> finished/cancelled) plus per-iteration scheduler step
  spans with phase children (retire/admit/prefill/decode) and counter
  tracks fed from the engine's existing ``trace_hook`` snapshot point.
  Every method is a no-op when no tracer is attached, so the engine
  calls them unconditionally and pays one attribute test per site when
  tracing is off.

Track layout: pid 0, tid 0 is the scheduler; request ``rid`` gets
tid ``rid + 1``.  All timestamps are host ``time.perf_counter()``
floats — reading a token *value* for a trace event would force a
device sync, so span boundaries only ever use host-side stamps the
engine already takes (HL202: the one batched ``jax.device_get`` per
step remains the only transfer).
"""

from __future__ import annotations

import time

__analysis__ = {
    "traced": (),
    "host_loop": (),
    "device_returning": (),
    "device_params": (),
    "host_objects": ("tracer", "spans", "sp"),
}

SCHED_TID = 0


def _tid(rid):
    return int(rid) + 1


class Tracer:
    """Append-only Chrome trace-event buffer (host-side, one process)."""

    def __init__(self):
        self._events = []
        self._origin = None
        self._named_tids = set()

    # -- time base ---------------------------------------------------------
    def _ts(self, t):
        if t is None:
            t = time.perf_counter()
        if self._origin is None:
            self._origin = t
        return (t - self._origin) * 1e6  # us

    def reset(self):
        """Drop buffered events and the time origin (per-run tracing)."""
        self._events = []
        self._origin = None
        self._named_tids = set()

    # -- emitters ----------------------------------------------------------
    def thread_name(self, tid, name):
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._events.append(
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": name}}
        )

    def begin(self, tid, name, t=None, **args):
        ev = {"name": name, "ph": "B", "pid": 0, "tid": tid,
              "ts": self._ts(t)}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def end(self, tid, t=None):
        self._events.append(
            {"ph": "E", "pid": 0, "tid": tid, "ts": self._ts(t)}
        )

    def complete(self, tid, name, t0, t1, **args):
        ev = {"name": name, "ph": "X", "pid": 0, "tid": tid,
              "ts": self._ts(t0), "dur": max(0.0, (t1 - t0) * 1e6)}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, tid, name, t=None, **args):
        ev = {"name": name, "ph": "i", "pid": 0, "tid": tid,
              "ts": self._ts(t), "s": "t"}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def counter(self, tid, name, values, t=None):
        self._events.append(
            {"name": name, "ph": "C", "pid": 0, "tid": tid,
             "ts": self._ts(t), "args": dict(values)}
        )

    def events(self):
        return list(self._events)

    def __len__(self):
        return len(self._events)


class EngineSpans:
    """Span state machine the engine drives; no-op without a tracer.

    One open B/E span per request at any time (its lifecycle phase);
    sub-work inside a phase (a prefill chunk, a swap transfer, replay)
    is emitted as complete (X) events nested under it.  ``run_end``
    closes whatever is still open so the trace always balances.
    """

    PHASES = ("queued", "prefill", "decode", "preempted")

    def __init__(self, tracer=None):
        self._tr = tracer
        self._open = {}          # rid -> current phase name
        self._chunk_idx = {}     # rid -> prefill chunk ordinal
        self._step_idx = 0

    @property
    def on(self):
        return self._tr is not None

    # -- request lifecycle -------------------------------------------------
    def _enter(self, rid, phase, t, **args):
        tr = self._tr
        tid = _tid(rid)
        tr.thread_name(tid, f"request {rid}")
        cur = self._open.get(rid)
        if cur is not None:
            tr.end(tid, t)
        tr.begin(tid, phase, t, **args)
        self._open[rid] = phase

    def _leave(self, rid, t):
        if self._open.pop(rid, None) is not None:
            self._tr.end(_tid(rid), t)

    def submitted(self, rid, t=None):
        if self._tr is None:
            return
        self._enter(rid, "queued", t)

    def admitted(self, rid, t=None, mode=""):
        if self._tr is None:
            return
        self._enter(rid, "prefill", t, mode=mode)

    def chunk(self, rid, t0, t1, tokens=0):
        """One chunked-prefill slice of this request's prompt."""
        if self._tr is None:
            return
        i = self._chunk_idx.get(rid, 0)
        self._chunk_idx[rid] = i + 1
        self._tr.complete(_tid(rid), f"prefill_chunk[{i}]", t0, t1,
                          tokens=int(tokens))

    def first_token(self, rid, t=None):
        if self._tr is None:
            return
        self._tr.instant(_tid(rid), "first_token", t)
        self._enter(rid, "decode", t)

    def decoding(self, rid, t=None):
        if self._tr is None:
            return
        if self._open.get(rid) != "decode":
            self._enter(rid, "decode", t)

    def token(self, rid, t=None):
        if self._tr is None:
            return
        self._tr.instant(_tid(rid), "token", t)

    def preempted(self, rid, t=None, mode=""):
        if self._tr is None:
            return
        self._enter(rid, "preempted", t, mode=mode)

    def swap(self, rid, t0, t1, direction, nbytes=0):
        if self._tr is None:
            return
        self._tr.complete(_tid(rid), f"swap_{direction}", t0, t1,
                          bytes=int(nbytes))

    def resume_work(self, rid, t0, t1, mode=""):
        """The replay / swap-in work done to bring a victim back."""
        if self._tr is None:
            return
        self._tr.complete(_tid(rid), "resume", t0, t1, mode=mode)

    def resumed(self, rid, t=None, phase="decode"):
        if self._tr is None:
            return
        self._enter(rid, phase, t)

    def finished(self, rid, t=None):
        if self._tr is None:
            return
        self._leave(rid, t)
        self._tr.instant(_tid(rid), "finished", t)
        self._chunk_idx.pop(rid, None)

    def cancelled(self, rid, t=None):
        if self._tr is None:
            return
        self._leave(rid, t)
        self._tr.instant(_tid(rid), "cancelled", t)
        self._chunk_idx.pop(rid, None)

    # -- scheduler ---------------------------------------------------------
    def step(self, t0, t1, phases=(), **args):
        """One scheduler iteration: parent X span + phase X children.

        ``phases`` is ``[(name, p0, p1), ...]`` with host stamps taken
        around the retire/admit/prefill/decode regions of the loop.
        """
        if self._tr is None:
            return
        tr = self._tr
        tr.thread_name(SCHED_TID, "scheduler")
        i = self._step_idx
        self._step_idx += 1
        tr.complete(SCHED_TID, f"step[{i}]", t0, t1, **args)
        for name, p0, p1 in phases:
            tr.complete(SCHED_TID, name, p0, p1)

    def snapshot(self, snap, t=None):
        """Counter tracks from the engine's trace_hook snapshot dict."""
        if self._tr is None:
            return
        tr = self._tr
        tr.thread_name(SCHED_TID, "scheduler")
        tr.counter(SCHED_TID, "pool",
                   {"pages_in_use": snap.get("pages_in_use", 0),
                    "free_pages": snap.get("free_pages", 0)}, t)
        tr.counter(SCHED_TID, "load",
                   {"active": snap.get("active", 0),
                    "queued": snap.get("queued", 0),
                    "swapped": snap.get("swapped", 0)}, t)

    # -- run boundary ------------------------------------------------------
    def run_begin(self, t=None):
        if self._tr is None:
            return
        self._tr.reset()
        self._open = {}
        self._chunk_idx = {}
        self._step_idx = 0
        self._tr.instant(SCHED_TID, "run_begin", t)

    def run_end(self, t=None):
        if self._tr is None:
            return
        for rid in list(self._open):
            self._leave(rid, t)
        self._tr.instant(SCHED_TID, "run_end", t)
