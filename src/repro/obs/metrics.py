"""Typed host-side metrics registry: Counter / Gauge / Histogram.

This is the single namespace behind every number the serving stack
reports: the engine's scheduling counters (formerly the ad-hoc
``counters`` / ``pstats`` dicts in ``launch/serve.py``), the page-pool
gauges, ``SwapStore`` byte counters, ``PrefixIndex`` hit counters, and
the front-end's TTFT / inter-token latency distributions.  Benchmarks
and the Prometheus exposition read the same objects, so there is one
code path from instrumentation site to reported percentile.

Design constraints (see docs/observability.md):

- Host-only.  Metric values are plain Python floats/ints; nothing here
  may touch jax.  The host-discipline linter (HL201/HL202) runs over
  this module to keep it that way.
- Instrument-site cost is one dict lookup + one float add.  Callers on
  the decode hot loop pre-bind series handles (``counter(...).series()``)
  once and call ``inc()`` / ``observe()`` on them per event.
- ``reset()`` zeroes values but keeps every registered metric and
  label-series object alive, so handles held by the engine survive the
  warmup/measure boundary (``engine.reset_stats()`` purity contract).
- Histograms keep fixed log-spaced buckets for the Prometheus
  exposition *and* a raw-sample reservoir so benchmark percentiles are
  exact (``numpy.percentile`` over raw samples), not bucket-interpolated.
"""

from __future__ import annotations

import threading

import numpy as np

__analysis__ = {
    "traced": (),
    "host_loop": (),
    "device_returning": (),
    "device_params": (),
    "host_objects": ("registry", "reg", "metric", "series"),
}

# Default histogram buckets: log-spaced, 10us .. ~84s (doubling).  Wide
# enough for TTFT on a cold compile and tight enough for inter-token
# latencies in the hundreds of microseconds.
DEFAULT_TIME_BUCKETS = tuple(1e-5 * 2.0 ** i for i in range(24))

# Cap on raw samples kept per histogram series.  Every benchmark in
# this repo observes far fewer samples than this, so percentiles stay
# exact in practice; past the cap new samples still update buckets,
# count and sum but are not retained raw.
RESERVOIR_CAP = 100_000

_VALID_KINDS = ("counter", "gauge", "histogram")


def _labels_key(labelnames, labels):
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} do not match declared labelnames "
            f"{sorted(labelnames)}"
        )
    return tuple(str(labels[name]) for name in labelnames)


class _Metric:
    """Shared machinery: a family of label series under one name."""

    kind = "abstract"

    def __init__(self, name, help="", unit="", labelnames=()):
        self.name = name
        self.help = help
        self.unit = unit
        self.labelnames = tuple(labelnames)
        self._series = {}

    def series(self, **labels):
        """Get-or-create the series for a label combination.

        Series objects survive ``reset()``; hot paths bind them once.
        """
        key = _labels_key(self.labelnames, labels)
        s = self._series.get(key)
        if s is None:
            s = self._new_series()
            self._series[key] = s
        return s

    def _new_series(self):
        raise NotImplementedError

    def reset(self):
        for s in self._series.values():
            s.reset()

    def samples(self):
        """Yield ``(labels_dict, series)`` pairs in insertion order."""
        for key, s in self._series.items():
            yield dict(zip(self.labelnames, key)), s


class CounterSeries:
    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def inc(self, n=1.0):
        if n < 0:
            raise ValueError("counters can only increase")
        self._value += n

    def value(self):
        return self._value

    def reset(self):
        self._value = 0.0


class Counter(_Metric):
    kind = "counter"

    def _new_series(self):
        return CounterSeries()

    def inc(self, n=1.0, **labels):
        self.series(**labels).inc(n)

    def value(self, **labels):
        return self.series(**labels).value()

    def total(self):
        return sum(s.value() for s in self._series.values())


class GaugeSeries:
    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def set(self, v):
        self._value = float(v)

    def set_max(self, v):
        if v > self._value:
            self._value = float(v)

    def inc(self, n=1.0):
        self._value += n

    def dec(self, n=1.0):
        self._value -= n

    def value(self):
        return self._value

    def reset(self):
        self._value = 0.0


class Gauge(_Metric):
    kind = "gauge"

    def _new_series(self):
        return GaugeSeries()

    def set(self, v, **labels):
        self.series(**labels).set(v)

    def set_max(self, v, **labels):
        self.series(**labels).set_max(v)

    def value(self, **labels):
        return self.series(**labels).value()


class HistogramSeries:
    __slots__ = ("buckets", "counts", "count", "sum", "raw")

    def __init__(self, buckets):
        self.buckets = buckets          # upper bounds, ascending
        self.counts = [0] * (len(buckets) + 1)  # +1 for +Inf
        self.count = 0
        self.sum = 0.0
        self.raw = []

    def observe(self, v):
        v = float(v)
        self.count += 1
        self.sum += v
        lo, hi = 0, len(self.buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        if len(self.raw) < RESERVOIR_CAP:
            self.raw.append(v)

    def percentile(self, p):
        """Exact percentile over the raw reservoir (numpy linear interp).

        Matches the hand-rolled ``np.percentile`` math the benchmarks
        used before this module existed, so BENCH numbers are stable
        across the refactor.
        """
        if not self.raw:
            return float("nan")
        return float(np.percentile(np.asarray(self.raw), p))

    def mean(self):
        return self.sum / self.count if self.count else float("nan")

    def max(self):
        return max(self.raw) if self.raw else float("nan")

    def cumulative_counts(self):
        """Cumulative bucket counts as Prometheus expects (le semantics)."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def reset(self):
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.raw = []


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help="", unit="", labelnames=(), buckets=None):
        super().__init__(name, help=help, unit=unit, labelnames=labelnames)
        b = tuple(float(x) for x in (buckets or DEFAULT_TIME_BUCKETS))
        if list(b) != sorted(b):
            raise ValueError("histogram buckets must be ascending")
        self.buckets = b

    def _new_series(self):
        return HistogramSeries(self.buckets)

    def observe(self, v, **labels):
        self.series(**labels).observe(v)

    def percentile(self, p, **labels):
        return self.series(**labels).percentile(p)


def summary_ms(series):
    """p50/p99/mean/max of a :class:`HistogramSeries`, in milliseconds.

    Same keys and math as the latency-SLO summaries computed before this
    module existed (``np.percentile`` over the raw samples, scaled to
    ms), so BENCH_slo.json numbers are stable across the refactor.
    """
    if not series.raw:
        return {"p50_ms": None, "p99_ms": None,
                "mean_ms": None, "max_ms": None, "n": 0}
    a = np.asarray(series.raw, np.float64) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()), "max_ms": float(a.max()),
            "n": int(a.size)}


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Re-requesting a name returns the same object; requesting it with a
    different kind or label set is an error (one meaning per name).
    """

    def __init__(self):
        self._metrics = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name, help, unit, labelnames, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as {m.kind}"
                    )
                if m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered with labels "
                        f"{m.labelnames}, requested {tuple(labelnames)}"
                    )
                return m
            m = cls(name, help=help, unit=unit, labelnames=labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name, help="", unit="", labelnames=()):
        return self._get_or_create(Counter, name, help, unit, labelnames)

    def gauge(self, name, help="", unit="", labelnames=()):
        return self._get_or_create(Gauge, name, help, unit, labelnames)

    def histogram(self, name, help="", unit="", labelnames=(), buckets=None):
        return self._get_or_create(
            Histogram, name, help, unit, labelnames, buckets=buckets
        )

    def get(self, name):
        return self._metrics.get(name)

    def collect(self):
        """Metrics in registration order (export iterates this)."""
        return list(self._metrics.values())

    def reset(self):
        """Zero every value; registrations and series handles survive.

        This is the registry half of ``engine.reset_stats()``: the
        warmup/measure boundary must not leave warmup samples in any
        histogram or warmup increments in any counter.
        """
        for m in self._metrics.values():
            m.reset()

    def snapshot(self):
        """Plain-dict snapshot for embedding in BENCH_*.json blobs."""
        out = {}
        for m in self._metrics.values():
            series = {}
            for labels, s in m.samples():
                key = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                if m.kind == "histogram":
                    series[key] = {
                        "count": s.count,
                        "sum": s.sum,
                        "p50": s.percentile(50),
                        "p99": s.percentile(99),
                        "max": s.max(),
                    }
                else:
                    series[key] = s.value()
            out[m.name] = {"kind": m.kind, "series": series}
        return out
