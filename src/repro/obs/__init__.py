"""Unified telemetry for the serving stack.

``Telemetry`` bundles the three pillars the engine threads through its
call sites:

- ``registry`` — a :class:`~repro.obs.metrics.MetricsRegistry` holding
  every counter/gauge/histogram (always on; one float add per event).
- ``tracer`` — an optional :class:`~repro.obs.tracing.Tracer`; when
  attached, ``spans`` (an :class:`~repro.obs.tracing.EngineSpans`)
  records request-lifecycle and scheduler-step spans as Chrome trace
  events.  When absent, every ``spans`` method is a no-op.
- ``step_timing`` — when true, the engine also observes per-iteration
  phase durations (retire/admit/prefill/decode) into the
  ``engine_step_phase_seconds`` histogram.  Defaults to on exactly
  when a tracer is attached, giving three instrumentation levels used
  by the overhead benchmark: counters-only (default), metrics-only
  (``Telemetry.metrics_only()``), full span tracing
  (``Telemetry.tracing()``).

See docs/observability.md for the metric catalog and span hierarchy.
"""

from __future__ import annotations

from .metrics import (
    Counter,
    DEFAULT_TIME_BUCKETS,
    Gauge,
    Histogram,
    MetricsRegistry,
    summary_ms,
)
from .tracing import EngineSpans, Tracer
from . import export

__analysis__ = {
    "traced": (),
    "host_loop": (),
    "device_returning": (),
    "device_params": (),
    "host_objects": ("telemetry", "tel"),
}

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Tracer",
    "EngineSpans",
    "Telemetry",
    "DEFAULT_TIME_BUCKETS",
    "summary_ms",
    "export",
]


class Telemetry:
    def __init__(self, registry=None, tracer=None, step_timing=None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.spans = EngineSpans(tracer)
        if step_timing is None:
            step_timing = tracer is not None
        self.step_timing = bool(step_timing)

    @classmethod
    def metrics_only(cls):
        """Counters + per-step phase histograms, no span tracing."""
        return cls(step_timing=True)

    @classmethod
    def tracing(cls):
        """Full instrumentation: counters, phase timing, span tracing."""
        return cls(tracer=Tracer())

    def snapshot(self):
        return self.registry.snapshot()
