"""Export surfaces for the telemetry layer.

- ``prometheus_text(registry)`` — Prometheus text exposition (0.0.4):
  ``# HELP`` / ``# TYPE`` headers, labeled samples, and for histograms
  the cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count`` triplet.
- ``parse_prometheus(text)`` — minimal parser used by tests and the CI
  smoke step to assert the dump round-trips.
- ``write_trace(tracer, path)`` — Chrome trace-event JSON envelope
  (``{"traceEvents": [...]}``) loadable in Perfetto / chrome://tracing.
- ``write_events_jsonl`` / ``write_metrics_jsonl`` — one-JSON-object-
  per-line logs for offline processing.
- ``MetricsServer`` — a dependency-free asyncio HTTP listener serving
  ``GET /metrics`` from a live registry (attached to the async
  front-end's event loop; the engine thread never blocks on it).
"""

from __future__ import annotations

import asyncio
import json

__analysis__ = {
    "traced": (),
    "host_loop": (),
    "device_returning": (),
    "device_params": (),
    "host_objects": ("registry", "reg", "tracer", "server"),
}


def _fmt(v):
    f = float(v)
    if f != f:  # NaN
        return "NaN"
    if f == float("inf"):
        return "+Inf"
    if f == float("-inf"):
        return "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(labels, extra=None):
    items = list(labels.items())
    if extra:
        items += list(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def prometheus_text(registry):
    """Render every registered metric in Prometheus text exposition."""
    lines = []
    for m in registry.collect():
        if m.help:
            lines.append(f"# HELP {m.name} {m.help}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        for labels, s in m.samples():
            if m.kind == "histogram":
                cum = s.cumulative_counts()
                for ub, c in zip(m.buckets, cum[:-1]):
                    le = _label_str(labels, {"le": _fmt(ub)})
                    lines.append(f"{m.name}_bucket{le} {c}")
                inf = _label_str(labels, {"le": "+Inf"})
                lines.append(f"{m.name}_bucket{inf} {cum[-1]}")
                lines.append(
                    f"{m.name}_sum{_label_str(labels)} {_fmt(s.sum)}")
                lines.append(
                    f"{m.name}_count{_label_str(labels)} {s.count}")
            else:
                lines.append(
                    f"{m.name}{_label_str(labels)} {_fmt(s.value())}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text):
    """Parse exposition text back to ``{(name, labelstr): float}``.

    Not a general parser — exactly the subset ``prometheus_text``
    emits, so tests and the CI smoke step can assert round-tripping.
    """
    out = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, val = line.rpartition(" ")
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            labelstr = rest.rstrip("}")
        else:
            name, labelstr = name_part, ""
        v = float(val)
        out[(name, labelstr)] = v
    return out


def write_prometheus(registry, path):
    with open(path, "w") as f:
        f.write(prometheus_text(registry))


def trace_json(tracer):
    return {"traceEvents": tracer.events(), "displayTimeUnit": "ms"}


def write_trace(tracer, path):
    with open(path, "w") as f:
        json.dump(trace_json(tracer), f)


def write_events_jsonl(tracer, path):
    with open(path, "w") as f:
        for ev in tracer.events():
            f.write(json.dumps(ev) + "\n")


def write_metrics_jsonl(registry, path):
    with open(path, "w") as f:
        for m in registry.collect():
            for labels, s in m.samples():
                rec = {"name": m.name, "kind": m.kind, "labels": labels}
                if m.kind == "histogram":
                    rec.update(count=s.count, sum=s.sum,
                               p50=s.percentile(50), p99=s.percentile(99))
                else:
                    rec["value"] = s.value()
                f.write(json.dumps(rec) + "\n")


class MetricsServer:
    """``GET /metrics`` over a live registry, on the asyncio loop.

    Plain ``asyncio.start_server`` — no web framework.  Rendering the
    exposition reads host-side floats only, so a scrape never touches
    the engine thread or any device buffer.
    """

    def __init__(self, registry, host="127.0.0.1", port=0):
        self._registry = registry
        self._host = host
        self._port = port
        self._server = None

    async def start(self):
        self._server = await asyncio.start_server(
            self._handle, self._host, self._port)
        self._port = self._server.sockets[0].getsockname()[1]
        return self

    @property
    def port(self):
        return self._port

    async def _handle(self, reader, writer):
        try:
            request = await reader.readline()
            # drain headers until the blank line
            while True:
                line = await reader.readline()
                if not line or line in (b"\r\n", b"\n"):
                    break
            parts = request.decode("latin-1").split()
            path = parts[1] if len(parts) >= 2 else "/"
            if path == "/metrics":
                body = prometheus_text(self._registry).encode()
                head = (b"HTTP/1.1 200 OK\r\n"
                        b"Content-Type: text/plain; version=0.0.4\r\n")
            else:
                body = b"not found\n"
                head = b"HTTP/1.1 404 Not Found\r\nContent-Type: text/plain\r\n"
            writer.write(head
                         + f"Content-Length: {len(body)}\r\n".encode()
                         + b"Connection: close\r\n\r\n" + body)
            await writer.drain()
        finally:
            writer.close()

    async def stop(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
