"""repro: SPARQ (NeurIPS 2021) as a production multi-pod JAX framework."""
__version__ = "0.1.0"
