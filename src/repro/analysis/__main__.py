"""CLI: `python -m repro.analysis [--fail-on-findings] [--json out.json]`.

Prints every finding (suppressed ones tagged with their baseline
reason), writes the machine-readable report when asked, and — under
`--fail-on-findings` — exits 1 if any finding survives the baseline.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import (DEFAULT_VMEM_BUDGET, default_baseline_path,
                            run_all, write_json)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr invariant auditor + host-discipline linter")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the findings report as JSON")
    ap.add_argument("--baseline", metavar="PATH",
                    default=default_baseline_path(),
                    help="suppression file (default: the reviewed "
                         "analysis/baseline.toml; pass '' to disable)")
    ap.add_argument("--vmem-budget", type=int, default=DEFAULT_VMEM_BUDGET,
                    help="per-kernel VMEM budget in bytes for JX105 "
                         "(default: %(default)s)")
    args = ap.parse_args(argv)

    live, muted, counters = run_all(vmem_budget=args.vmem_budget,
                                    baseline_path=args.baseline)
    for f in live:
        print(f.format())
    for f in muted:
        print(f"{f.format()}  [suppressed]")
    if args.json:
        write_json(args.json, live, muted, counters)
    per_program = counters.get("jaxprs_per_program", {})
    print(f"analysis: {counters.get('programs_traced', 0)} programs "
          f"traced ({sum(per_program.values())} jaxprs), "
          f"{len(live)} finding(s), {len(muted)} suppressed")
    if args.fail_on_findings and live:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
