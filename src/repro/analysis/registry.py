"""The registered hot programs the jaxpr auditor traces.

Everything here is *abstract*: params and cache/store pytrees are built
with `jax.eval_shape` over the real constructors, and every traced
argument is a `jax.ShapeDtypeStruct` — registering a program costs a
trace, never a FLOP or a device buffer. The geometry mirrors the serving
benchmarks (reduced tinyllama, page_size 16, chunked prefill) so the
audited jaxprs are the ones the engines actually run, with
`impl="pallas"` so the fused kernels' `pallas_call`s (grid, block
shapes, VMEM footprint) are visible to the checks.

Programs:
  decode_step.scan     DecodeEngine's jitted `lax.scan` decode loop
                       (contiguous packed cache).
  decode_step.paged    ContinuousBatchingEngine's per-token step over
                       the paged store (page_size declared: JX104).
  prefill_chunk        the PrefillScheduler's single chunk program; its
                       shape set comes from *driving the real packer*
                       over a ragged prompt mix, so JX106 asserts what
                       the compile-count regression test asserts — one
                       signature for every join pattern.
  decode_replay        requeue-resume teacher-forced replay. Registered
                       with audit_cache=False: it legitimately retraces
                       per recorded-token count (cold path, once per
                       preemption) — but it still declares page_size so
                       JX104 pins `attn_bk == page_size` on its
                       contiguous planes (replay reads must tile exactly
                       like the paged reads that produced the tokens).
  ops.*                each kernels/ops.py dispatcher standalone, with
                       engine-shaped packed planes.
  decode_step.paged_tp2 / prefill_chunk_tp2
                       tensor-parallel (tp=2 shard_map over a
                       ("data","model") mesh) variants of the paged step
                       and chunk programs; registered only when >= 2
                       devices are visible (the multidevice CI job).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_audit import ProgramSpec
from repro.core.quantizer import QScale
from repro.core.sparq import SparqConfig
from repro.models.cache import CacheConfig
from repro.models.paging import ChunkMeta

# serving geometry (mirrors benchmarks/run.py's paged scenarios)
PAGE_SIZE = 16
N_PAGES = 24
MAX_ACTIVE = 4
MAX_SEQ_LEN = 80
CHUNK = 32
ALIGN = 8


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _codec() -> SparqConfig:
    return SparqConfig.opt5(signed=True)


@functools.lru_cache(maxsize=1)
def _model():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    return Model(cfg)


def _scan_engine_specs(model, params) -> List[ProgramSpec]:
    from repro.launch.serve import DecodeEngine
    cc = CacheConfig.sparq_cache(_codec(), impl="pallas")
    eng = DecodeEngine(model, cc)
    B, L = 2, 64
    caches = jax.eval_shape(
        functools.partial(model.init_cache, B, L, cache_cfg=cc))
    args = (params, _sds((B, 1), jnp.int32), caches, _sds((), jnp.int32))
    fn = functools.partial(eng._decode_fn, steps=4)
    return [ProgramSpec("decode_step.scan", fn, [args, args])]


def _paged_engine_specs(model, params) -> List[ProgramSpec]:
    from repro.launch.serve import ContinuousBatchingEngine
    cc = dataclasses.replace(
        CacheConfig.sparq_cache(_codec(), impl="pallas"),
        attn_bk=PAGE_SIZE)
    eng = ContinuousBatchingEngine(
        model, cc, page_size=PAGE_SIZE, n_pages=N_PAGES,
        max_active=MAX_ACTIVE, max_seq_len=MAX_SEQ_LEN,
        prefill="chunked", chunk_size=CHUNK, chunk_align=ALIGN)
    stores = jax.eval_shape(eng._init_stores)
    specs: List[ProgramSpec] = []

    step_args = (params, _sds((MAX_ACTIVE, 1), jnp.int32), stores,
                 _sds((MAX_ACTIVE,), jnp.int32))
    specs.append(ProgramSpec("decode_step.paged", eng._step_fn,
                             [step_args, step_args],
                             page_size=PAGE_SIZE))

    # chunk shape set: drive the real packer over a ragged prompt mix
    # (multi-chunk prompts, mid-chunk joins, a sub-segment stub) — every
    # planned chunk must map to the same jit signature
    sched = eng._sched
    n_blocks = MAX_SEQ_LEN // PAGE_SIZE
    host_bt = np.full((MAX_ACTIVE, n_blocks), -1, np.int64)
    next_page = [0]

    def grant(slot, blocks):
        for b in blocks:
            host_bt[slot, b] = next_page[0]
            next_page[0] += 1

    for slot, n_tok in enumerate([17, 33, 46, 9]):
        sched.add(slot, slot, np.arange(n_tok, dtype=np.int64) % 7)
    chunk_set = []
    while True:
        plan = sched.plan(lambda: N_PAGES, grant, host_bt)
        if plan is None:
            break
        meta = ChunkMeta(
            seq_id=_sds(plan.seq_id.shape, jnp.int32),
            pos=_sds(plan.pos.shape, jnp.int32),
            hist=_sds(plan.hist.shape, jnp.int32),
            tile_seq=_sds(plan.tile_seq.shape, jnp.int32),
            seq_pos_after=_sds((MAX_ACTIVE,), jnp.int32))
        chunk_set.append((params, _sds((1, CHUNK), jnp.int32), stores,
                          meta, _sds((MAX_ACTIVE,), jnp.int32)))
    assert chunk_set, "packer produced no chunks — registry bug"
    specs.append(ProgramSpec("prefill_chunk", sched._chunk_fn, chunk_set,
                             page_size=PAGE_SIZE))

    # replay: shape per recorded-token count — audit_cache=False, but
    # JX104 still pins the replay tile to the page size (_cc_replay)
    replay_caches = jax.eval_shape(functools.partial(
        model.init_cache, 1, 48, cache_cfg=eng._cc_replay))
    replay_set = [(params, _sds((1, n), jnp.int32), replay_caches,
                   _sds((), jnp.int32)) for n in (4, 7)]
    specs.append(ProgramSpec("decode_replay", eng._replay_fn, replay_set,
                             page_size=PAGE_SIZE, audit_cache=False))
    return specs


def _tp_engine_specs(model, params) -> List[ProgramSpec]:
    """Tensor-parallel variants of the paged hot programs (tp=2 over a
    ("data","model") host mesh) so JX101-JX106 gate the shard_map'd
    decode step and prefill chunk too — the auditor walks into the
    shard_map body (per-shard pools: KV/tp head groups). Registered only
    when the process actually has >= 2 devices (the multidevice CI job
    forces 8 on CPU); on a single-device run the sharded programs cannot
    even build a mesh, and the plain-jit programs above still audit the
    identical kernel bodies."""
    if len(jax.devices()) < 2:
        return []
    from repro.launch.mesh import make_tp_mesh
    from repro.launch.serve import ContinuousBatchingEngine
    cc = dataclasses.replace(
        CacheConfig.sparq_cache(_codec(), impl="pallas"),
        attn_bk=PAGE_SIZE)
    eng = ContinuousBatchingEngine(
        model, cc, page_size=PAGE_SIZE, n_pages=N_PAGES,
        max_active=MAX_ACTIVE, max_seq_len=MAX_SEQ_LEN,
        prefill="chunked", chunk_size=CHUNK, chunk_align=ALIGN,
        mesh=make_tp_mesh(2))
    stores = jax.eval_shape(eng._init_stores)
    specs: List[ProgramSpec] = []

    step_args = (params, _sds((MAX_ACTIVE, 1), jnp.int32), stores,
                 _sds((MAX_ACTIVE,), jnp.int32))
    specs.append(ProgramSpec("decode_step.paged_tp2", eng._step_fn,
                             [step_args, step_args],
                             page_size=PAGE_SIZE))

    meta = ChunkMeta(
        seq_id=_sds((CHUNK,), jnp.int32), pos=_sds((CHUNK,), jnp.int32),
        hist=_sds((CHUNK,), jnp.int32),
        tile_seq=_sds((CHUNK // ALIGN,), jnp.int32),
        seq_pos_after=_sds((MAX_ACTIVE,), jnp.int32))
    chunk_args = (params, _sds((1, CHUNK), jnp.int32), stores, meta,
                  _sds((MAX_ACTIVE,), jnp.int32))
    specs.append(ProgramSpec("prefill_chunk_tp2", eng._sched._chunk_fn,
                             [chunk_args, chunk_args],
                             page_size=PAGE_SIZE))
    return specs


def _dispatcher_specs(model) -> List[ProgramSpec]:
    from repro.kernels import ops
    cfg = model.cfg
    codec = _codec()
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    H = cfg.n_heads
    P, ps, NB, S = 8, PAGE_SIZE, MAX_SEQ_LEN // PAGE_SIZE, MAX_ACTIVE
    i8, i32, f32 = jnp.int8, jnp.int32, jnp.float32
    specs: List[ProgramSpec] = []

    def qm(x, w_codes, scale, chan_scale):
        return ops.quantized_matmul(
            x, w_codes, QScale(scale=scale, bits=codec.bits, signed=True),
            chan_scale, codec, impl="pallas")

    specs.append(ProgramSpec("ops.quantized_matmul", qm, [(
        _sds((8, 64), f32), _sds((64, 32), i8), _sds((), f32),
        _sds((32,), f32))]))

    def quant(x, scale):
        return ops.sparq_quantize(
            x, QScale(scale=scale, bits=codec.bits, signed=True), codec,
            impl="pallas", bm=16)

    specs.append(ProgramSpec("ops.sparq_quantize", quant,
                             [(_sds((32, 64), f32), _sds((), f32))]))

    dequant = functools.partial(ops.sparq_dequantize, impl="pallas", bm=16)
    specs.append(ProgramSpec("ops.sparq_dequantize", dequant,
                             [(_sds((32, 64), i8), _sds((32, 64), i8))]))

    decode = functools.partial(ops.sparq_decode_attention,
                               impl="pallas", bk=PAGE_SIZE)
    plane = _sds((2, 32, KV, hd), i8)
    specs.append(ProgramSpec("ops.sparq_decode_attention", decode, [(
        _sds((2, 1, H, hd), f32), plane, plane, _sds((), f32),
        plane, plane, _sds((), f32), _sds((2, 32), i32),
        _sds((), i32))]))

    chunked = functools.partial(ops.sparq_chunked_prefill_attention,
                                impl="pallas", bq=ALIGN)
    pool = _sds((P, ps, KV, hd), i8)
    specs.append(ProgramSpec(
        "ops.sparq_chunked_prefill_attention", chunked,
        [(_sds((CHUNK, H, hd), f32), _sds((CHUNK, KV, hd), f32),
          _sds((CHUNK, KV, hd), f32), pool, pool, _sds((S,), f32),
          pool, pool, _sds((S,), f32), _sds((S, NB), i32),
          _sds((CHUNK,), i32), _sds((CHUNK,), i32), _sds((CHUNK,), i32),
          _sds((CHUNK // ALIGN,), i32))],
        page_size=PAGE_SIZE))

    paged = functools.partial(ops.sparq_paged_decode_attention,
                              impl="pallas")
    specs.append(ProgramSpec(
        "ops.sparq_paged_decode_attention", paged,
        [(_sds((S, 1, H, hd), f32), pool, pool, _sds((S,), f32),
          pool, pool, _sds((S,), f32), _sds((S, NB), i32),
          _sds((S,), i32))],
        page_size=PAGE_SIZE))

    audited = {s.name.split(".", 1)[1] for s in specs}
    missing = set(ops.HOT_DISPATCHERS) - audited
    assert not missing, f"dispatchers registered but not audited: {missing}"
    return specs


def default_programs() -> List[ProgramSpec]:
    """Every registered hot program, traced abstractly."""
    model = _model()
    params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    specs: List[ProgramSpec] = []
    specs += _scan_engine_specs(model, params)
    specs += _paged_engine_specs(model, params)
    specs += _tp_engine_specs(model, params)
    specs += _dispatcher_specs(model)
    return specs
