"""AST host-discipline linter for the serving stack's host/device split.

The paged engine's contract (docs/serving.md) is that scheduling is host
work *between* traced steps: numpy state, explicit `jax.device_get` at
the few points a decision needs device bytes, allocator mutation only
from host code, and `PoolExhausted` raised before anything is traced.
This linter enforces that contract statically over `launch/serve.py`,
`launch/prefill.py`, and `models/paging.py`.

Modules declare their own topology in a module-level `__analysis__`
dict (parsed with `ast.literal_eval` — it must stay a pure literal):

    __analysis__ = {
        # functions that run under jit/scan (qualnames; entries with a
        # module prefix, e.g. "paging.adopt_prefill", document jit
        # targets defined in another module for HL205)
        "traced": ("Engine._step_fn", ...),
        # the per-step scheduler loop(s): HL201/HL202 scope
        "host_loop": ("Engine.run", ...),
        # call-chain suffixes whose results are device arrays
        "device_returning": ("_sched.run", ...),
        # "Qualname.param" names that arrive as device arrays
        "device_params": ("SwapStore._to_host.groups", ...),
        # host-side scheduling objects: never device values, so taint
        # cannot attach to these names (their methods may still be
        # declared device_returning)
        "host_objects": ("sched", "index", "allocator", "swap"),
    }

Checks:

HL201  `jnp.*`/`jax.*` call in a host-loop function that is not pure
       data movement (asarray/zeros/concatenate/.../device_get/
       device_put/block_until_ready/jax.tree.*). Math belongs inside
       the traced program; host-side jnp launches a device computation
       per scheduler iteration.
HL202  implicit device sync in a host-loop function: `int()`, `float()`,
       `bool()`, `np.asarray`/`np.array`, `.item()` on a value tainted
       as a device array, or branching (`if`/`while`/`assert`) on one.
       The blessed read is explicit `jax.device_get` (its result is
       host data and clears the taint).
HL203  `PageAllocator`/`PrefixIndex`/`SwapStore` mutation reachable from
       a traced function — allocator state must only change on the host
       between steps.
HL204  `raise PoolExhausted` inside a traced function — the pool-dry
       signal must fire before tracing (a traced raise is a concrete
       error at trace time, not a schedulable event).
HL205  a `jax.jit`/`lax.scan`/`lax.while_loop`/`lax.cond` target that is
       not in the module's `traced` annotation (and not nested inside a
       traced function) — every traced entry point must be declared so
       the other checks know the host/device boundary. A module without
       `__analysis__` fails wholesale.

Taint for HL202 is a per-function fixpoint over simple names and
attribute chains: seeds are `jnp.*`/`jax.*` call results (minus
`device_get`/`block_until_ready`), calls through jitted attributes
(`self.X` where `self.X = jax.jit(...)` anywhere in the module),
annotated `device_returning` call chains, and annotated `device_params`;
taint flows through assignment, tuple unpacking, containers, subscript
*reads* (of the container — a tainted index into a host array is not a
sync), comprehensions (generator targets bound from their iterables),
accessor methods (`TAINT_METHODS`), and `append`-style mutation. Other
method calls are assumed host-returning, and bare-name truthiness tests
are host `len()` checks — both deliberate precision-over-recall calls
(docs/analysis.md). Nested `def`s are analyzed inside their parent's
environment (closures share the loop's variables).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import (Finding, HL_LOOP_NUMERIC, HL_LOOP_SYNC,
                                     HL_TRACED_MUT, HL_TRACED_RAISE,
                                     HL_UNANNOTATED)

#: the serving-stack host modules the CLI lints by default (repo-relative)
DEFAULT_TARGETS = (
    "src/repro/launch/serve.py",
    "src/repro/launch/prefill.py",
    "src/repro/launch/frontend.py",
    "src/repro/models/paging.py",
    "src/repro/obs/__init__.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/tracing.py",
    "src/repro/obs/export.py",
)

ALLOWED_HOST_CALLS = frozenset({
    "asarray", "array", "zeros", "ones", "full", "arange", "concatenate",
    "stack", "broadcast_to", "device_get", "device_put",
    "block_until_ready", "int32", "int64", "float32", "int8", "uint8",
    "bool_",
})
UNTAINTING = frozenset({"device_get", "block_until_ready"})
PASSTHROUGH = frozenset({"list", "tuple", "dict", "set", "sorted",
                         "reversed", "zip", "enumerate", "min", "max"})
MUTATING_METHODS = frozenset({"append", "extend", "add", "insert",
                              "update", "setdefault"})
#: methods whose result carries the receiver's taint (container
#: accessors and functional array updates). Any *other* method call is
#: assumed host-returning — the linter trades recall for precision here:
#: a host object that internally stores device arrays (e.g. the prefix
#: index) returns mostly host metadata, and tainting every method result
#: floods the whole loop (see docs/analysis.md, HL202 limitations).
TAINT_METHODS = frozenset({"items", "values", "get", "pop", "popitem",
                           "copy", "set", "astype", "reshape"})
SYNC_BUILTINS = frozenset({"int", "float", "bool"})
NP_SINKS = frozenset({"np.asarray", "np.array", "numpy.asarray",
                      "numpy.array"})
ALLOC_TYPES = frozenset({"PageAllocator", "PrefixIndex", "SwapStore"})
ALLOC_MUTATORS = frozenset({"alloc", "share", "release", "free", "insert",
                            "invalidate", "put", "pop", "cancel"})


def _chain(node) -> Optional[str]:
    """Dotted-name string of a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _own_nodes(fn: ast.AST):
    """Walk a function body without descending into nested function or
    class definitions (those are attributed to their own qualnames)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


class _Module:
    """Parsed module index: qualnamed functions, nesting, jitted attrs,
    allocator-typed bindings, every call site with its context."""

    def __init__(self, path: str, rel: str):
        with open(path) as fh:
            self.tree = ast.parse(fh.read(), filename=path)
        self.rel = rel
        self.funcs: Dict[str, ast.AST] = {}
        self.parents: Dict[str, Optional[str]] = {}
        self.owner: Dict[str, Optional[str]] = {}
        self.jit_attrs: Set[str] = set()
        self.alloc_refs: Set[str] = set()       # names/attrs of allocators
        self.calls: List[Tuple[ast.Call, Optional[str],
                               Optional[str]]] = []
        self.ann: Optional[dict] = None
        for node in self.tree.body:
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "__analysis__"
                    for t in node.targets):
                self.ann = ast.literal_eval(node.value)
        self._walk(self.tree, None, None)

    def _record_assign(self, node: ast.Assign) -> None:
        if not isinstance(node.value, ast.Call):
            return
        ch = _chain(node.value.func)
        if ch is None:
            return
        final = ch.split(".")[-1]
        for tgt in node.targets:
            tch = _chain(tgt)
            if tch is None:
                continue
            attr = tch.split(".")[-1]
            if final == "jit" and ch.startswith("jax"):
                self.jit_attrs.add(attr)
            if final in ALLOC_TYPES:
                self.alloc_refs.add(attr)

    def _walk(self, node, cls: Optional[str], fnq: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                self._walk(child, child.name, None)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if fnq is not None:
                    q = f"{fnq}.{child.name}"
                elif cls is not None:
                    q = f"{cls}.{child.name}"
                else:
                    q = child.name
                self.funcs[q] = child
                self.parents[q] = fnq
                self.owner[q] = cls
                self._walk(child, cls, q)
            else:
                if isinstance(child, ast.Assign):
                    self._record_assign(child)
                if isinstance(child, ast.Call):
                    self.calls.append((child, cls, fnq))
                self._walk(child, cls, fnq)


# ----------------------------------------------------------------- scopes

def _traced_scope(m: _Module, traced: Sequence[str]) -> Set[str]:
    """Local traced functions closed over nesting and simple-name calls."""
    scope = {q for q in traced if q in m.funcs}
    changed = True
    while changed:
        changed = False
        for q in m.funcs:
            if q in scope:
                continue
            if m.parents[q] in scope:        # nested def under a traced fn
                scope.add(q)
                changed = True
        for q in list(scope):
            for node in _own_nodes(m.funcs[q]):
                if not isinstance(node, ast.Call):
                    continue
                ch = _chain(node.func)
                if ch is None:
                    continue
                cand = None
                if ch in m.funcs:
                    cand = ch
                elif ch.startswith("self.") and ch.count(".") == 1 \
                        and m.owner.get(q):
                    qual = f"{m.owner[q]}.{ch[5:]}"
                    if qual in m.funcs:
                        cand = qual
                if cand and cand not in scope:
                    scope.add(cand)
                    changed = True
    return scope


# ------------------------------------------------------- HL203 / HL204

def _check_traced(m: _Module, scope: Set[str]) -> List[Finding]:
    out = []
    for q in sorted(scope):
        for node in _own_nodes(m.funcs[q]):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ALLOC_MUTATORS:
                base = _chain(node.func.value)
                if base and base.split(".")[-1] in m.alloc_refs:
                    out.append(Finding(
                        HL_TRACED_MUT, m.rel, node.lineno, q,
                        f"`{base}.{node.func.attr}(...)` mutates "
                        f"allocator state from a traced function — "
                        f"allocator updates belong on the host between "
                        f"steps"))
            if isinstance(node, ast.Raise) and node.exc is not None:
                exc = node.exc.func if isinstance(node.exc, ast.Call) \
                    else node.exc
                ech = _chain(exc)
                if ech and ech.split(".")[-1] == "PoolExhausted":
                    out.append(Finding(
                        HL_TRACED_RAISE, m.rel, node.lineno, q,
                        "`raise PoolExhausted` inside a traced function "
                        "— the pool-dry signal must fire host-side "
                        "before tracing"))
    return out


# --------------------------------------------------------------- HL205

_TRACE_ENTRY = {
    "jit": (0, 1), "scan": (0, 1), "while_loop": (0, 2), "cond": (1, 3),
}


def _check_entry_points(m: _Module, traced: Sequence[str],
                        scope: Set[str]) -> List[Finding]:
    out = []
    for call, cls, fnq in m.calls:
        ch = _chain(call.func)
        if ch is None or not (ch.startswith("jax") or
                              ch.startswith("lax.")):
            continue
        final = ch.split(".")[-1]
        if final not in _TRACE_ENTRY:
            continue
        if final != "jit" and ".lax." not in ch and not \
                ch.startswith("lax."):
            continue
        lo, hi = _TRACE_ENTRY[final]
        for tgt in call.args[lo:hi]:
            if fnq in scope:
                break                # jit/scan inside already-traced code
            tch = _chain(tgt)
            ok = False
            if tch:
                if tch in traced:
                    ok = True
                elif tch.startswith("self.") and cls \
                        and f"{cls}.{tch[5:]}" in traced:
                    ok = True
                elif fnq and f"{fnq}.{tch}" in scope:
                    ok = True        # nested def of a traced parent
            elif isinstance(tgt, ast.Lambda):
                ok = fnq in scope
            if not ok:
                out.append(Finding(
                    HL_UNANNOTATED, m.rel, call.lineno, fnq or m.rel,
                    f"`{ch}` target `{tch or '<dynamic>'}` is not listed "
                    f"in this module's __analysis__ 'traced' annotation"))
    return out


# ------------------------------------------------------ HL201 / HL202

class _HostFnLint:
    def __init__(self, m: _Module, q: str):
        self.m = m
        self.q = q
        self.fn = m.funcs[q]
        ann = m.ann or {}
        self.dev_returning = tuple(ann.get("device_returning", ()))
        self.host_objects = frozenset(ann.get("host_objects", ()))
        self.taint: Set[str] = set()
        prefix = f"{q}."
        for entry in ann.get("device_params", ()):
            if entry.startswith(prefix):
                self.taint.add(entry[len(prefix):])

    # ------------------------------------------------------- expressions
    def _call_tainted(self, e: ast.Call) -> bool:
        ch = _chain(e.func)
        if ch:
            parts = ch.split(".")
            root, final = parts[0], parts[-1]
            if root in ("jax", "jnp"):
                return final not in UNTAINTING
            if ch.startswith("self.") and len(parts) == 2 \
                    and parts[1] in self.m.jit_attrs:
                return True
            if any(ch == d or ch.endswith("." + d)
                   for d in self.dev_returning):
                return True
            if ch in PASSTHROUGH:
                return any(self._tainted(a) for a in e.args)
            if ch in NP_SINKS or ch in SYNC_BUILTINS or final == "item":
                return False         # sinks produce host values
        if isinstance(e.func, ast.Attribute) \
                and e.func.attr in TAINT_METHODS \
                and self._tainted(e.func.value):
            return True              # accessor on a tainted object
        return False

    def _tainted(self, e) -> bool:
        if e is None:
            return False
        if isinstance(e, ast.Name):
            return e.id in self.taint
        if isinstance(e, ast.Attribute):
            ch = _chain(e)
            return (ch in self.taint) or self._tainted(e.value)
        if isinstance(e, ast.Subscript):
            return self._tainted(e.value)
        if isinstance(e, ast.Call):
            return self._call_tainted(e)
        if isinstance(e, ast.BinOp):
            return self._tainted(e.left) or self._tainted(e.right)
        if isinstance(e, ast.BoolOp):
            return any(self._tainted(v) for v in e.values)
        if isinstance(e, ast.Compare):
            return self._tainted(e.left) or any(
                self._tainted(c) for c in e.comparators)
        if isinstance(e, ast.UnaryOp):
            return self._tainted(e.operand)
        if isinstance(e, ast.IfExp):
            return any(self._tainted(x) for x in (e.body, e.orelse))
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any(self._tainted(x) for x in e.elts)
        if isinstance(e, ast.Dict):
            return any(self._tainted(x) for x in e.values if x) or any(
                self._tainted(x) for x in e.keys if x)
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            return self._comp_tainted(e)
        if isinstance(e, ast.Starred):
            return self._tainted(e.value)
        if isinstance(e, ast.NamedExpr):
            return self._tainted(e.value)
        return False

    def _comp_tainted(self, e) -> bool:
        """A comprehension is tainted iff its *element* is — with the
        generator targets bound from their iterables first, so
        `[t for _, t in history]` (device tokens) is tainted while
        `[(i, s) for i, (a, _) in enumerate(history)]` (host indices
        into a tainted container) is not."""
        added: List[str] = []
        try:
            for g in e.generators:       # in order: later iters may use
                if not self._tainted(g.iter):    # earlier targets
                    continue
                for n in ast.walk(g.target):
                    if isinstance(n, ast.Name) and n.id not in self.taint \
                            and n.id not in self.host_objects:
                        self.taint.add(n.id)
                        added.append(n.id)
            if isinstance(e, ast.DictComp):
                return self._tainted(e.key) or self._tainted(e.value)
            return self._tainted(e.elt)
        finally:
            for name in added:
                self.taint.discard(name)

    # -------------------------------------------------------- statements
    def _bind(self, target) -> None:
        if isinstance(target, ast.Name):
            if target.id not in self.host_objects:
                self.taint.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self._bind(t)
        elif isinstance(target, ast.Starred):
            self._bind(target.value)
        elif isinstance(target, ast.Attribute):
            ch = _chain(target)
            if ch:
                self.taint.add(ch)
        elif isinstance(target, ast.Subscript):
            self._bind(target.value)     # writing into a container taints it

    def _propagate_once(self) -> int:
        before = len(self.taint)
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign):
                val_t = self._tainted(node.value)
                if len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Tuple) \
                        and isinstance(node.value, ast.Tuple) \
                        and len(node.targets[0].elts) == \
                        len(node.value.elts):
                    for t, v in zip(node.targets[0].elts,
                                    node.value.elts):
                        if self._tainted(v):
                            self._bind(t)
                elif val_t:
                    for t in node.targets:
                        self._bind(t)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if self._tainted(node.value):
                    self._bind(node.target)
            elif isinstance(node, ast.AugAssign):
                if self._tainted(node.value) or self._tainted(node.target):
                    self._bind(node.target)
            elif isinstance(node, ast.For):
                if self._tainted(node.iter):
                    self._bind(node.target)
            elif isinstance(node, ast.NamedExpr):
                if self._tainted(node.value):
                    self._bind(node.target)
            elif isinstance(node, ast.withitem):
                if node.optional_vars is not None \
                        and self._tainted(node.context_expr):
                    self._bind(node.optional_vars)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATING_METHODS:
                if any(self._tainted(a) for a in node.args):
                    self._bind(node.func.value)
        return len(self.taint) - before

    # -------------------------------------------------------------- emit
    def run(self) -> List[Finding]:
        for _ in range(16):              # fixpoint (loops carry taint back)
            if self._propagate_once() == 0:
                break
        out = []
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Call):
                ch = _chain(node.func)
                if ch:
                    parts = ch.split(".")
                    if parts[0] in ("jax", "jnp"):
                        if parts[-1] not in ALLOWED_HOST_CALLS \
                                and "tree" not in parts:
                            out.append(Finding(
                                HL_LOOP_NUMERIC, self.m.rel, node.lineno,
                                self.q,
                                f"`{ch}` in the host scheduler loop — "
                                f"device math belongs inside the traced "
                                f"step, not per host iteration"))
                    if ch in NP_SINKS and any(self._tainted(a)
                                              for a in node.args):
                        out.append(self._sync(node, f"`{ch}`"))
                    if ch in SYNC_BUILTINS and any(self._tainted(a)
                                                   for a in node.args):
                        out.append(self._sync(node, f"`{ch}()`"))
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" \
                        and self._tainted(node.func.value):
                    out.append(self._sync(node, "`.item()`"))
            elif isinstance(node, (ast.If, ast.While)) \
                    and self._test_syncs(node.test):
                out.append(self._sync(node, "branching"))
            elif isinstance(node, ast.Assert) \
                    and self._test_syncs(node.test):
                out.append(self._sync(node, "asserting"))
        return out

    def _test_syncs(self, test) -> bool:
        """Does this branch condition read device bytes? Truthiness of a
        bare (possibly tainted) name is a host `len()` check on a
        container that merely *holds* device arrays — only comparisons,
        subscript reads, calls and arithmetic over tainted values force
        a device round trip."""
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._test_syncs(test.operand)
        if isinstance(test, ast.BoolOp):
            return any(self._test_syncs(v) for v in test.values)
        if isinstance(test, (ast.Name, ast.Attribute)):
            return False
        if isinstance(test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return False             # identity test — never reads bytes
        return self._tainted(test)

    def _sync(self, node, what: str) -> Finding:
        return Finding(
            HL_LOOP_SYNC, self.m.rel, node.lineno, self.q,
            f"{what} on a device array in the host scheduler loop is an "
            f"implicit sync — read it explicitly with jax.device_get "
            f"(batched, off the per-step path)")


# ----------------------------------------------------------------- entry

def _repo_root() -> str:
    return os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "..", ".."))


def lint_file(path: str, rel: Optional[str] = None) -> List[Finding]:
    rel = rel or path
    m = _Module(path, rel)
    if m.ann is None:
        return [Finding(HL_UNANNOTATED, rel, 1, rel,
                        "module has no __analysis__ annotation — declare "
                        "its traced / host-loop topology (docs/analysis.md)")]
    traced = tuple(m.ann.get("traced", ()))
    scope = _traced_scope(m, traced)
    out = _check_entry_points(m, traced, scope)
    out += _check_traced(m, scope)
    for q in m.ann.get("host_loop", ()):
        if q not in m.funcs:
            out.append(Finding(
                HL_UNANNOTATED, rel, 1, rel,
                f"__analysis__ host_loop entry {q!r} names no function "
                f"in this module"))
            continue
        out += _HostFnLint(m, q).run()
    return sorted(out, key=lambda f: (f.file, f.line, f.check))


def lint_all(targets: Sequence[str] = DEFAULT_TARGETS,
             root: Optional[str] = None) -> List[Finding]:
    root = root or _repo_root()
    out = []
    for rel in targets:
        out += lint_file(os.path.join(root, rel), rel)
    return out
