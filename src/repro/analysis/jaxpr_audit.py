"""Jaxpr-level invariant auditor for the registered hot programs.

Each hot program (decode step, paged step, prefill chunk, replay, the
`kernels/ops.py` dispatchers) is traced to a closed jaxpr with
`jax.make_jaxpr` over `ShapeDtypeStruct` arguments — zero compute, no
device state — and the jaxpr is walked recursively to enforce the
invariants the dynamic spy tests only probe at single call sites:

JX101  no host callbacks (`pure_callback` / `io_callback` /
       `debug_callback`) or explicit device<->host transfers inside a
       hot program — a callback serializes every step on a host round
       trip.
JX102  packed int8/uint8 planes are never `convert_element_type`'d to
       float outside a `pallas_call` or the registered meta-decode
       sources (`kernels.ops.META_DECODE_SOURCES`) — the static form of
       the `CachedTensor.read()` spy: decode must stream packed bytes,
       not materialize a float cache.
JX103  every Pallas block shape divides its operand's array shape —
       ragged tails would silently read OOB-masked garbage or force
       masking the kernels don't do.
JX104  in a program that declares a page size, any rank-4 packed-plane
       block must tile the page axis exactly (`block[1] == page_size`) —
       the paged kernels gather whole pages via the block table, and a
       mismatched tile (e.g. replay forgetting `attn_bk = page_size`)
       reads across page boundaries.
JX105  the summed block footprint of a `pallas_call` stays under the
       VMEM budget — all operand tiles are resident per grid step.
JX106  re-tracing a program under the engine's real shape set yields
       ONE jit signature — the static generalization of the
       compile-count regression guard.

Taint rule (JX102): any int8/uint8 value — input leaf or produced
in-trace — is treated as a packed plane, and taint flows through
*integer* ops, so laundering through an int32 widen before the float
cast is still caught. Integer→float conversions inside `pallas_call` or
in code whose source file lives under a registered meta-decode path are
the blessed decode and clear the taint. Sub-jaxprs (`pjit`, `scan`,
`while`, `cond`, custom-derivative wrappers) are entered with exact
positional taint mapping so an untainted int32 (e.g. a rotary position
index) does not false-positive when cast to float.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src import source_info_util

from repro.analysis.findings import (Finding, JX_COMPILE_CACHE, JX_HOSTCALL,
                                     JX_PACKED_CAST, JX_PAGE_TILE,
                                     JX_TILE_DIVIDE, JX_VMEM)

#: default per-kernel operand-tile budget. TPU cores carry ~16 MiB of
#: VMEM shared between operand tiles, scratch, and double-buffering;
#: capping visible tiles at a quarter of that leaves headroom for both.
DEFAULT_VMEM_BUDGET = 4 * 1024 * 1024

_HOSTCALL_PRIMS = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"})
_TRANSFER_PRIMS = frozenset({"device_put"})
_PACKED_DTYPES = frozenset({"int8", "uint8"})


@dataclasses.dataclass
class ProgramSpec:
    """One registered hot program.

    `shape_set` is a list of abstract argument tuples (pytrees of
    `jax.ShapeDtypeStruct` leaves plus static values): the first entry
    drives the jaxpr walk, the full list drives the JX106 compile-cache
    audit — it should mirror the shapes the live engine actually feeds
    the program. `audit_cache=False` opts a program out of JX106 (the
    replay program legitimately retraces per recorded-token count; it is
    a cold path run once per preemption)."""
    name: str
    fn: Callable
    shape_set: Sequence[tuple]
    page_size: Optional[int] = None
    audit_cache: bool = True


def _frame(eqn) -> Tuple[str, int]:
    fr = source_info_util.user_frame(eqn.source_info)
    if fr is None:
        return "", 0
    return fr.file_name, fr.start_line


def _dtype_of(v) -> Optional[str]:
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    return None if dt is None else str(dt)


def _is_packed(v) -> bool:
    return _dtype_of(v) in _PACKED_DTYPES


def _is_int(v) -> bool:
    dt = _dtype_of(v)
    return dt is not None and ("int" in dt or "bool" in dt)


class _Taint:
    """Per-var taint keyed by object identity (jaxpr Vars are unique
    objects; Literals are always looked up by dtype)."""

    def __init__(self):
        self._m: Dict[int, bool] = {}

    def get(self, v) -> bool:
        if _is_packed(v):
            return True
        return self._m.get(id(v), False)

    def set(self, v, t: bool) -> None:
        self._m[id(v)] = bool(t) or _is_packed(v)


def call_signature(args: tuple, kwargs: Optional[dict] = None) -> tuple:
    """The jit-cache identity of a call: pytree structure plus (shape,
    dtype) per array leaf and `repr` per static leaf. Two calls with
    equal signatures share one traced program."""
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs or {}))
    sig = []
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            sig.append(("arr", tuple(leaf.shape), str(leaf.dtype)))
        else:
            sig.append(("static", repr(leaf)))
    return (str(treedef), tuple(sig))


class _Auditor:
    def __init__(self, program: str, page_size: Optional[int],
                 vmem_budget: int, meta_decode_sources: Tuple[str, ...]):
        self.program = program
        self.page_size = page_size
        self.vmem_budget = vmem_budget
        self.meta_sources = tuple(s.replace("\\", "/")
                                  for s in meta_decode_sources)
        self.findings: List[Finding] = []

    # ------------------------------------------------------------ helpers
    def _emit(self, check: str, eqn, message: str) -> None:
        file, line = _frame(eqn)
        self.findings.append(Finding(check=check, file=file, line=line,
                                     program=self.program, message=message))

    def _in_meta_decode(self, eqn) -> bool:
        file, _ = _frame(eqn)
        file = file.replace("\\", "/")
        return any(s in file for s in self.meta_sources)

    # ------------------------------------------------------------- pallas
    def _block_dims(self, bm) -> List[Optional[int]]:
        dims: List[Optional[int]] = []
        for d in getattr(bm, "block_shape", ()) or ():
            try:
                dims.append(int(d))
            except (TypeError, ValueError):
                dims.append(None)      # squeezed / symbolic dim: skip
        return dims

    def _check_pallas(self, eqn) -> None:
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            return
        total_bytes = 0
        for bm in getattr(gm, "block_mappings", ()) or ():
            sds = getattr(bm, "array_shape_dtype", None)
            if sds is None:
                continue
            shape, dtype = tuple(sds.shape), str(sds.dtype)
            dims = self._block_dims(bm)
            itemsize = jnp.dtype(dtype).itemsize
            total_bytes += math.prod(d for d in dims
                                     if isinstance(d, int)) * itemsize
            bad = [(i, b, s) for i, (b, s) in enumerate(zip(dims, shape))
                   if isinstance(b, int) and b > 0 and s % b]
            if bad:
                i, b, s = bad[0]
                self._emit(JX_TILE_DIVIDE, eqn,
                           f"block shape {tuple(dims)} does not divide "
                           f"operand shape {shape} (dim {i}: {s} % {b} "
                           f"!= 0)")
            if (self.page_size is not None and dtype in _PACKED_DTYPES
                    and len(shape) == 4 and len(dims) >= 2
                    and isinstance(dims[1], int)
                    and dims[1] != self.page_size):
                self._emit(JX_PAGE_TILE, eqn,
                           f"packed plane {shape} {dtype} tiled with "
                           f"block[1]={dims[1]} but program page_size="
                           f"{self.page_size} — paged kernels must tile "
                           f"whole pages (attn_bk == page_size)")
        if total_bytes > self.vmem_budget:
            self._emit(JX_VMEM, eqn,
                       f"estimated operand-tile footprint {total_bytes} B "
                       f"exceeds VMEM budget {self.vmem_budget} B")

    # --------------------------------------------------------------- walk
    def walk(self, jaxpr, taint_in: Sequence[bool],
             const_taint: Sequence[bool], inside_pallas: bool = False
             ) -> List[bool]:
        """Walk one (open) jaxpr; returns the taint of its outvars."""
        taint = _Taint()
        for v, t in zip(jaxpr.invars, taint_in):
            taint.set(v, t)
        for v, t in zip(jaxpr.constvars, const_taint):
            taint.set(v, t)

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_taint = [taint.get(v) for v in eqn.invars]

            if name in _HOSTCALL_PRIMS:
                self._emit(JX_HOSTCALL, eqn,
                           f"host callback `{name}` inside a hot program "
                           f"— every step would block on a host round "
                           f"trip")
                for v in eqn.outvars:
                    taint.set(v, False)
                continue
            if name in _TRANSFER_PRIMS and not inside_pallas:
                self._emit(JX_HOSTCALL, eqn,
                           f"device transfer `{name}` inside a hot "
                           f"program — placement belongs on the host "
                           f"side of the jit boundary")
                for v, t in zip(eqn.outvars, in_taint):
                    taint.set(v, t)
                continue

            if name == "convert_element_type":
                out = eqn.outvars[0]
                out_dt = _dtype_of(out)
                to_float = out_dt is not None and jnp.issubdtype(
                    jnp.dtype(out_dt), jnp.floating)
                if any(in_taint) and to_float:
                    if inside_pallas or self._in_meta_decode(eqn):
                        taint.set(out, False)   # blessed decode
                    else:
                        self._emit(
                            JX_PACKED_CAST, eqn,
                            f"packed plane cast "
                            f"{_dtype_of(eqn.invars[0])}->"
                            f"{_dtype_of(out)} outside pallas/meta-decode"
                            f" — decode must stream packed bytes, not "
                            f"materialize a float cache")
                        taint.set(out, False)
                else:
                    taint.set(out, any(in_taint) and _is_int(out))
                continue

            if name == "pallas_call":
                if not inside_pallas:
                    self._check_pallas(eqn)
                inner = eqn.params.get("jaxpr")
                if inner is not None:
                    n = len(inner.invars)
                    self.walk(inner, ([False] * n),
                              [False] * len(inner.constvars),
                              inside_pallas=True)
                for v in eqn.outvars:
                    taint.set(v, _is_packed(v))
                continue

            out_taint = self._sub_jaxpr(name, eqn, in_taint, inside_pallas)
            if out_taint is None:
                # generic primitive: integer outputs inherit taint so
                # int8 -> int32 -> float laundering is still caught
                out_taint = [any(in_taint) and _is_int(v)
                             for v in eqn.outvars]
            for v, t in zip(eqn.outvars, out_taint):
                taint.set(v, t)

        return [taint.get(v) for v in jaxpr.outvars]

    def _closed(self, closed, taint_in, inside_pallas) -> List[bool]:
        consts = getattr(closed, "consts", ())
        const_taint = [hasattr(c, "dtype") and str(c.dtype) in _PACKED_DTYPES
                       for c in consts]
        return self.walk(closed.jaxpr, taint_in, const_taint,
                         inside_pallas=inside_pallas)

    def _sub_jaxpr(self, name: str, eqn, in_taint: List[bool],
                   inside_pallas: bool) -> Optional[List[bool]]:
        """Recurse into call-like primitives with exact positional taint
        mapping. Returns outvar taint, or None for generic primitives."""
        p = eqn.params
        if name in ("pjit", "closed_call", "core_call", "xla_call"):
            return self._closed(p["jaxpr"], in_taint, inside_pallas)
        if name == "shard_map":
            # tensor-parallel body (jax.experimental.shard_map): the
            # inner jaxpr sees per-shard shapes but identical positional
            # structure, so taint maps through unchanged. The param is an
            # open Jaxpr on current jax; handle ClosedJaxpr too.
            j = p["jaxpr"]
            if hasattr(j, "jaxpr"):
                return self._closed(j, in_taint, inside_pallas)
            return self.walk(j, in_taint, [False] * len(j.constvars),
                             inside_pallas=inside_pallas)
        if name == "scan":
            # invars = consts ++ carry ++ xs; inner sees xs minus the
            # leading scan axis — positions are unchanged
            out = self._closed(p["jaxpr"], in_taint, inside_pallas)
            return out
        if name == "while":
            nc, nb = p["cond_nconsts"], p["body_nconsts"]
            carry = in_taint[nc + nb:]
            self._closed(p["cond_jaxpr"], in_taint[:nc] + carry,
                         inside_pallas)
            return self._closed(p["body_jaxpr"],
                                in_taint[nc:nc + nb] + carry,
                                inside_pallas)
        if name == "cond":
            ops = in_taint[1:]          # invars = [branch index] ++ operands
            outs = [self._closed(br, ops, inside_pallas)
                    for br in p["branches"]]
            return [any(ts) for ts in zip(*outs)] if outs else []
        if name in ("custom_jvp_call", "custom_vjp_call",
                    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"):
            inner = p.get("call_jaxpr") or p.get("fun_jaxpr")
            if inner is not None:
                return self._closed(inner, in_taint, inside_pallas)
        if name in ("remat", "remat2", "checkpoint"):
            return self._closed(p["jaxpr"], in_taint, inside_pallas) \
                if hasattr(p.get("jaxpr"), "jaxpr") else \
                self.walk(p["jaxpr"], in_taint, [], inside_pallas)
        return None


def audit_program(spec: ProgramSpec, *,
                  vmem_budget: int = DEFAULT_VMEM_BUDGET,
                  meta_decode_sources: Optional[Tuple[str, ...]] = None
                  ) -> Tuple[List[Finding], int]:
    """Audit one hot program: trace, walk, and (optionally) count the
    jit signatures its real shape set produces. Returns (findings,
    n_signatures)."""
    if meta_decode_sources is None:
        from repro.kernels.ops import META_DECODE_SOURCES
        meta_decode_sources = META_DECODE_SOURCES
    if not spec.shape_set:
        raise ValueError(f"program {spec.name}: empty shape_set")

    aud = _Auditor(spec.name, spec.page_size, vmem_budget,
                   meta_decode_sources)
    closed = jax.make_jaxpr(spec.fn)(*spec.shape_set[0])
    leaves, _ = jax.tree_util.tree_flatten(spec.shape_set[0])
    taint_in = [hasattr(l, "dtype") and str(l.dtype) in _PACKED_DTYPES
                for l in leaves]
    aud._closed(closed, taint_in, inside_pallas=False)

    sigs = {call_signature(args) for args in spec.shape_set}
    if spec.audit_cache and len(sigs) > 1:
        aud.findings.append(Finding(
            check=JX_COMPILE_CACHE, file="", line=0, program=spec.name,
            message=f"{len(sigs)} distinct jit signatures across the "
                    f"engine's shape set ({len(spec.shape_set)} calls) — "
                    f"a hot program must trace exactly once"))
    return aud.findings, len(sigs)


def audit_all(specs: Sequence[ProgramSpec], *,
              vmem_budget: int = DEFAULT_VMEM_BUDGET
              ) -> Tuple[List[Finding], dict]:
    """Audit every registered program. Returns (findings, counters) where
    counters carries the compile-cache stats surfaced in BENCH blobs:
    {"programs_traced": N, "jaxprs_per_program": {name: n_sigs}}."""
    findings: List[Finding] = []
    per_program: Dict[str, int] = {}
    for spec in specs:
        fs, nsig = audit_program(spec, vmem_budget=vmem_budget)
        findings.extend(fs)
        per_program[spec.name] = nsig
    return findings, {"programs_traced": len(specs),
                      "jaxprs_per_program": per_program}
