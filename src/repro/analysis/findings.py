"""Finding records, the reviewed baseline file, and machine-readable output.

Every analyzer check reports `Finding`s: a stable check ID, a file:line
anchor, the program or lint scope the violation lives in, and a message.
Intentional exceptions are not silenced in code — they go through
`baseline.toml`, a reviewed suppression list whose entries must carry a
`reason`. The CLI (`python -m repro.analysis`) loads the baseline, splits
findings into unsuppressed/suppressed, and exits non-zero on any
unsuppressed finding under `--fail-on-findings`.

The baseline parser is deliberately tiny: the CI image runs Python 3.10
(no stdlib `tomllib`), and the file only ever holds `[[suppress]]` tables
of string keys — a full TOML implementation would be a dependency for
nothing.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Sequence, Tuple

#: jaxpr-auditor check IDs (repro.analysis.jaxpr_audit)
JX_HOSTCALL = "JX101"       # host callback / device<->host transfer in a
                            # hot program
JX_PACKED_CAST = "JX102"    # packed int8 plane cast to float outside
                            # pallas / the registered meta-decode
JX_TILE_DIVIDE = "JX103"    # pallas block shape does not divide the
                            # operand shape
JX_PAGE_TILE = "JX104"      # packed-plane tile != page size in a paged /
                            # replay program
JX_VMEM = "JX105"           # estimated per-kernel VMEM footprint over
                            # budget
JX_COMPILE_CACHE = "JX106"  # more than one jaxpr signature under the
                            # engine's real shape set

#: host-discipline linter check IDs (repro.analysis.host_lint)
HL_LOOP_NUMERIC = "HL201"   # jnp/jax numeric op inside the per-step host
                            # scheduler loop
HL_LOOP_SYNC = "HL202"      # implicit device sync (int()/np.asarray/...)
                            # on an engine array in the host loop
HL_TRACED_MUT = "HL203"     # PageAllocator/PrefixIndex/SwapStore mutation
                            # reachable from a traced context
HL_TRACED_RAISE = "HL204"   # PoolExhausted raise site inside a traced
                            # context (must precede tracing)
HL_UNANNOTATED = "HL205"    # jax.jit / lax.scan target missing from the
                            # module's __analysis__ traced list

ALL_CHECKS = (JX_HOSTCALL, JX_PACKED_CAST, JX_TILE_DIVIDE, JX_PAGE_TILE,
              JX_VMEM, JX_COMPILE_CACHE, HL_LOOP_NUMERIC, HL_LOOP_SYNC,
              HL_TRACED_MUT, HL_TRACED_RAISE, HL_UNANNOTATED)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: check ID + file:line anchor + scope + message."""
    check: str              # one of ALL_CHECKS
    file: str               # path of the violating code ("" = program-level)
    line: int               # 1-based source line (0 = whole file/program)
    program: str            # hot program name or lint scope qualname
    message: str

    def format(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else (self.file or "-")
        return f"{self.check} {loc} [{self.program}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One reviewed baseline entry. Matching is by check ID plus optional
    file-path and message/program substrings; `reason` is mandatory — an
    unexplained suppression is a config error, not a review artifact."""
    check: str
    file: str = ""
    contains: str = ""
    reason: str = ""

    def matches(self, f: Finding) -> bool:
        if self.check and self.check != f.check:
            return False
        if self.file and self.file not in f.file.replace(os.sep, "/"):
            return False
        if self.contains and self.contains not in f.message \
                and self.contains not in f.program:
            return False
        return True


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.toml")


def load_baseline(path: str) -> List[Suppression]:
    """Parse the `[[suppress]]` tables of a baseline file.

    Accepts the subset of TOML the baseline actually uses: `[[suppress]]`
    section headers, `key = "string"` pairs, comments, blank lines.
    Anything else is a hard error — a malformed baseline must never
    silently suppress nothing (or everything)."""
    sups: List[Suppression] = []
    current: Dict[str, str] = {}
    in_table = False

    def flush():
        nonlocal current
        if not in_table:
            return
        if "check" not in current:
            raise ValueError(f"{path}: [[suppress]] entry missing 'check'")
        if not current.get("reason"):
            raise ValueError(
                f"{path}: suppression of {current['check']} has no "
                f"'reason' — baseline entries must be justified")
        unknown = set(current) - {"check", "file", "contains", "reason"}
        if unknown:
            raise ValueError(f"{path}: unknown suppression keys {unknown}")
        sups.append(Suppression(**current))
        current = {}

    with open(path) as fh:
        for lineno, raw in enumerate(fh, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if line == "[[suppress]]":
                flush()
                in_table = True
                continue
            if "=" in line and in_table:
                key, _, val = line.partition("=")
                key, val = key.strip(), val.strip()
                if val.startswith('"') and val.endswith('"') and len(val) >= 2:
                    val = val[1:-1]
                else:
                    raise ValueError(
                        f"{path}:{lineno}: values must be double-quoted "
                        f"strings, got {val!r}")
                current[key] = val
                continue
            raise ValueError(f"{path}:{lineno}: unparseable line {line!r}")
    flush()
    return sups


def split_suppressed(findings: Iterable[Finding],
                     suppressions: Sequence[Suppression]
                     ) -> Tuple[List[Finding], List[Finding]]:
    """-> (unsuppressed, suppressed)."""
    live, muted = [], []
    for f in findings:
        (muted if any(s.matches(f) for s in suppressions) else live).append(f)
    return live, muted


def report_json(unsuppressed: Sequence[Finding],
                suppressed: Sequence[Finding],
                counters: dict) -> dict:
    """Machine-readable report (the CI artifact payload)."""
    return {
        "findings": [f.as_dict() for f in unsuppressed],
        "suppressed": [f.as_dict() for f in suppressed],
        "counts": {
            "unsuppressed": len(unsuppressed),
            "suppressed": len(suppressed),
        },
        "compile_cache": counters,
    }


def write_json(path: str, unsuppressed: Sequence[Finding],
               suppressed: Sequence[Finding], counters: dict) -> None:
    with open(path, "w") as fh:
        json.dump(report_json(unsuppressed, suppressed, counters), fh,
                  indent=2, sort_keys=True)
        fh.write("\n")
