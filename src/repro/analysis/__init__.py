"""Static analysis for the repro serving stack.

Two engines over one Finding/baseline vocabulary (docs/analysis.md):

- `jaxpr_audit` traces the registered hot programs (`registry`) into
  closed jaxprs and enforces device-side invariants: no host callbacks
  or transfers (JX101), packed planes never decoded outside a kernel
  (JX102), Pallas tile divisibility (JX103), page-sized tiles in paged
  paths (JX104), VMEM budget (JX105), one jaxpr per program under the
  engine's real shape set (JX106).
- `host_lint` walks the scheduler modules' ASTs and enforces the host
  side of the contract: no per-step device math (HL201) or implicit
  syncs (HL202), no allocator mutation from traced code (HL203),
  `PoolExhausted` raised before tracing (HL204), every trace entry
  point declared in `__analysis__` (HL205).

`run_all()` is the programmatic entry; `python -m repro.analysis` the
CLI; the CI `analysis` job runs it with `--fail-on-findings` and
uploads the JSON report as a build artifact.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.analysis.findings import (ALL_CHECKS, Finding, Suppression,
                                     default_baseline_path, load_baseline,
                                     report_json, split_suppressed,
                                     write_json)
from repro.analysis.host_lint import DEFAULT_TARGETS, lint_all
from repro.analysis.jaxpr_audit import (DEFAULT_VMEM_BUDGET, ProgramSpec,
                                        audit_all)

__all__ = [
    "ALL_CHECKS", "DEFAULT_TARGETS", "DEFAULT_VMEM_BUDGET", "Finding",
    "ProgramSpec", "Suppression", "audit_all", "analysis_counters",
    "default_baseline_path", "lint_all", "load_baseline", "report_json",
    "run_all", "split_suppressed", "write_json",
]


def run_all(*, vmem_budget: int = DEFAULT_VMEM_BUDGET,
            baseline_path: Optional[str] = None,
            targets: Sequence[str] = DEFAULT_TARGETS,
            ) -> Tuple[List[Finding], List[Finding], dict]:
    """Run both engines and apply the baseline.

    Returns (unsuppressed, suppressed, counters); `counters` carries the
    jaxpr auditor's compile-cache tallies (programs traced, jaxprs per
    program). Pass `baseline_path=""` to skip suppression entirely."""
    from repro.analysis.registry import default_programs
    findings, counters = audit_all(default_programs(),
                                   vmem_budget=vmem_budget)
    findings += lint_all(targets)
    if baseline_path is None:
        baseline_path = default_baseline_path()
    sups = load_baseline(baseline_path) if baseline_path else []
    live, muted = split_suppressed(findings, sups)
    return live, muted, counters


def analysis_counters(*, vmem_budget: int = DEFAULT_VMEM_BUDGET) -> dict:
    """Just the jaxpr auditor's compile-cache counters (no lint pass) —
    benchmarks fold these into their BENCH output so a signature
    explosion shows up next to the numbers it would poison."""
    from repro.analysis.registry import default_programs
    _, counters = audit_all(default_programs(), vmem_budget=vmem_budget)
    return counters
