"""Deterministic synthetic data pipeline, sharded and restart-exact.

Every batch is a pure function of (seed, step, shard), so training restarts
replay the exact token stream with no data-loader state to checkpoint —
the fault-tolerance contract (DESIGN.md §5). The synthetic LM task is a
structured Markov-ish stream (not uniform noise) so models actually learn
and PTQ accuracy deltas are measurable.

Host sharding: `Batcher.local_batch(step)` materializes only this host's
shard; `global_batch` builds the full array (single-host runs / tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_states: int = 64          # markov states for the synthetic stream
    frontend: str = "none"      # vlm/audio stub inputs
    frontend_len: int = 0
    d_model: int = 0


def _markov_tokens(key, cfg: DataConfig, batch: int) -> jnp.ndarray:
    """Structured stream: tokens follow a sparse per-state transition table
    derived from the seed (low entropy -> learnable)."""
    V, S = cfg.vocab_size, cfg.seq_len
    table_key = jax.random.PRNGKey(cfg.seed)  # fixed task, not per-batch
    # each state maps to 8 candidate next-tokens
    cand = jax.random.randint(table_key, (cfg.n_states, 8), 0, V)

    def step(state, k):
        choice = jax.random.randint(k, state.shape, 0, 8)
        tok = jnp.take_along_axis(cand[state % cfg.n_states],
                                  choice[:, None], 1)[:, 0]
        return tok % cfg.n_states, tok

    keys = jax.random.split(key, S)
    state0 = jax.random.randint(key, (batch,), 0, cfg.n_states)
    _, toks = jax.lax.scan(step, state0, keys)
    return toks.T  # [B, S]


@dataclasses.dataclass
class Batcher:
    cfg: DataConfig
    host_id: int = 0
    n_hosts: int = 1

    def _batch(self, step: int, batch: int, offset: int) -> Dict:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step),
            offset)
        toks = _markov_tokens(key, self.cfg, batch)
        out = {"tokens": toks,
               "labels": jnp.concatenate(
                   [toks[:, 1:], jnp.full((batch, 1), -1, toks.dtype)], 1)}
        if self.cfg.frontend == "vision":
            out["image_embeds"] = jax.random.normal(
                jax.random.fold_in(key, 7),
                (batch, self.cfg.frontend_len, self.cfg.d_model),
                jnp.float32) * 0.02
        elif self.cfg.frontend == "audio":
            out["frames"] = jax.random.normal(
                jax.random.fold_in(key, 8),
                (batch, self.cfg.seq_len, self.cfg.d_model),
                jnp.float32) * 0.02
        return out

    def global_batch(self, step: int) -> Dict:
        return self._batch(step, self.cfg.global_batch, 0)

    def local_batch(self, step: int) -> Dict:
        per = self.cfg.global_batch // self.n_hosts
        return self._batch(step, per, self.host_id * 1009)

    def calib_batches(self, n: int, batch: Optional[int] = None):
        """Calibration set (paper: 2K random training samples)."""
        b = batch or min(self.cfg.global_batch, 8)
        return [self._batch(10_000_000 + i, b, 0) for i in range(n)]
