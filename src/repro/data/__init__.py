"""data subsystem."""
