"""Checkpointing: atomic, per-leaf files, elastic restore.

Layout:  <dir>/step_<N>/  manifest.json + one .npy per leaf (paths are
flattened pytree key-paths). Writes go to a tmp dir renamed into place
(atomic on POSIX), so a crash mid-save never corrupts the latest
checkpoint. Restore is *elastic*: arrays are stored unsharded and
device_put against whatever mesh/shardings the restoring job provides —
a 256-chip checkpoint restores onto 512 chips (or 8) unchanged.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest `keep` steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, arr in flat.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template: Any,
            shardings: Any = None) -> Any:
    """Rebuild `template`'s pytree from disk. `shardings` (optional pytree
    of jax.sharding.Sharding) enables elastic placement onto any mesh.
    Leaves missing on disk keep the template's value (forward-compatible
    restores after adding new state)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (kp, leaf), sh in zip(leaves_p, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in kp)
        meta = manifest["leaves"].get(key)
        if meta is None:
            out.append(leaf)
            continue
        arr = np.load(os.path.join(path, meta["file"]))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
