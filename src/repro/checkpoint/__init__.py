"""checkpoint subsystem."""
