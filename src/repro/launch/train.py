"""Training driver: sharded pjit train loop with checkpoint/restart,
straggler monitoring, optional SPARQ gradient compression.

Local (CPU) runs use reduced configs:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --checkpoint-dir /tmp/ckpt

On a real cluster the same entry point runs the full config on the
production mesh (--mesh production [--multi-pod]).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.configs.base import get_config, get_reduced_config
from repro.data.pipeline import Batcher, DataConfig
from repro.distributed import sharding as shd
from repro.distributed.collectives import GradCompressor
from repro.distributed.fault import ElasticCoordinator
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamW, cosine_schedule


def build_train_step(model: Model, opt: AdamW,
                     compressor: GradCompressor | None = None,
                     accum: int | None = None):
    """Gradient-accumulating train step. `accum` microbatches (default from
    cfg.train_microbatches) bound activation memory: each microbatch's
    activations are freed before the next starts; only the f32 grad
    accumulator (params-sized, params-sharded) persists."""
    accum = accum or model.cfg.train_microbatches

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb)
        return loss, metrics

    def train_step(params, opt_state, comp_state, batch):
        if accum > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum,
                                    *x.shape[1:]), batch)

            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(
                micro, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {"lm_loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if compressor is not None:
            grads, comp_state = compressor.compress(grads, comp_state)
        new_params, new_state, om = opt.update(grads, opt_state, params)
        return new_params, new_state, comp_state, {
            "loss": loss, **{k: v for k, v in metrics.items()}, **om}
    return train_step


def shard_tree(tree, mesh, specs):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--lr-total", type=int, default=None,
                    help="schedule horizon (default: --steps); set it\n                    explicitly when a run will be resumed/extended")
    ap.add_argument("--mesh", choices=["host", "production"], default="host")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    model = Model(cfg)
    total = args.lr_total or args.steps
    opt = AdamW(lr=cosine_schedule(args.lr, max(total // 20, 1),
                                   total))
    compressor = GradCompressor() if args.compress_grads else None

    mesh = make_production_mesh(multi_pod=args.multi_pod) \
        if args.mesh == "production" else \
        make_host_mesh(args.model_parallel)
    shd.set_activation_spec(shd.activation_spec(mesh, sp=False), mesh=mesh)

    data = Batcher(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        frontend=cfg.frontend, frontend_len=cfg.frontend_len,
        d_model=cfg.d_model))

    with mesh:
        params = model.init_params(jax.random.PRNGKey(args.seed))
        p_specs = shd.param_pspecs(params, mesh)
        params = shard_tree(params, mesh, p_specs)
        opt_state = opt.init(params)
        comp_state = compressor.init(params) if compressor else None

        start_step = 0
        if args.checkpoint_dir and args.restore:
            step = ckpt.latest_step(args.checkpoint_dir)
            if step is not None:
                shardings = jax.tree.map(
                    lambda s: NamedSharding(mesh, s), p_specs,
                    is_leaf=lambda x: isinstance(x, P))
                state = ckpt.restore(
                    args.checkpoint_dir, step,
                    {"params": params, "m": opt_state.m, "v": opt_state.v},
                    {"params": shardings, "m": shardings, "v": shardings})
                params = state["params"]
                opt_state = opt_state._replace(
                    m=state["m"], v=state["v"],
                    count=jnp.asarray(step, jnp.int32))
                start_step = step
                print(f"restored step {step} from {args.checkpoint_dir}")

        step_fn = jax.jit(build_train_step(model, opt, compressor),
                          donate_argnums=(0, 1, 2))
        coord = ElasticCoordinator(n_workers=jax.process_count())

        losses = []
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = data.global_batch(step)
            params, opt_state, comp_state, metrics = step_fn(
                params, opt_state, comp_state, batch)
            dt = time.perf_counter() - t0
            coord.step_report(jax.process_index(), step, dt)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt*1000:.0f} ms)", flush=True)
            if args.checkpoint_dir and \
                    (step + 1) % args.checkpoint_every == 0:
                ckpt.save(args.checkpoint_dir, step + 1,
                          {"params": params, "m": opt_state.m,
                           "v": opt_state.v})
        if args.checkpoint_dir:
            ckpt.save(args.checkpoint_dir, args.steps,
                      {"params": params, "m": opt_state.m, "v": opt_state.v})
    shd.set_activation_spec(None, None)
    return losses


if __name__ == "__main__":
    main()
