"""Serving drivers: scan-based batch decode and paged continuous batching,
both with SPARQ quantization at the matmuls (the paper's compute path) and
the KV cache (the §5.1 packed storage path — the memory-bound workload).

Two engines share the model and the fused packed-cache decode kernels:

  DecodeEngine (`--engine scan`, default)
      Uniform batch, contiguous per-sequence cache. Generation is one
      traced `jax.lax.scan` inside one jitted program — no per-step Python
      dispatch — so tok/s measures the model, not the host loop.

  ContinuousBatchingEngine (`--engine paged`)
      Ragged requests over a *paged* cache (models.paging): one global pool
      of fixed-size packed pages per layer, per-sequence block tables, a
      host-side free-list allocator. The host loop only schedules —
      admission (prefill + page adoption), page allocation on write, and
      page free on eviction happen *between* steps; the inner decode step
      stays a single traced function over all sequence slots, reading
      pages through the block-table variant of the fused kernel. With a
      `SchedulerPolicy` (`--preempt requeue|swap`) the pool may be
      oversubscribed: decode-time exhaustion preempts victim sequences
      (requeue-and-replay, or packed-page swap to a host `SwapStore`) and
      resumes them bit-exactly ahead of new admissions. The loop also
      accepts live traffic: `submit()`/`cancel()` mailboxes drained once
      per iteration, per-token `emit` streaming, and a wall-clock mode
      (`clock_mode="wall"`, `drain=False`) that `launch.frontend`'s
      asyncio front-end drives for latency-SLO serving.

`--kv-cache {fp32,bf16,sparq}` selects the cache layout (the paged engine
requires sparq — packed pages are its point); `--impl` picks the kernel
implementation (reference / Pallas / auto) for the quantized matmuls, the
cache codec, and the fused decode-attention kernels.

Local demos:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 64 --gen 32 --sparq 5opt \
      --kv-cache sparq
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --engine paged --batch 4 --prompt-len 64 --gen 32 \
      --sparq 5opt --kv-cache sparq --page-size 16 --n-pages 64
"""
from __future__ import annotations

import argparse
import dataclasses
import heapq
import math
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced_config
from repro.core.sparq import SparqConfig
from repro.data.pipeline import Batcher, DataConfig
from repro.models import cache as cache_mod
from repro.models import paging
from repro.models.cache import CacheConfig
from repro.models.common import QuantCtx
from repro.models.model import Model
from repro.obs import Telemetry

# host/device topology for the static analyzer (repro.analysis.host_lint;
# see docs/analysis.md). Pure literal — parsed with ast.literal_eval.
__analysis__ = {
    "traced": (
        "DecodeEngine._prefill_fn",
        "DecodeEngine._decode_fn",
        "ContinuousBatchingEngine._prefill_fn",
        "ContinuousBatchingEngine._step_fn",
        "ContinuousBatchingEngine._replay_fn",
        "paging.adopt_prefill",
        "paging.evict_slot",
        "paging.gather_slot_pages",
        "paging.restore_slot_pages",
        "paging.copy_page",
        "paging.adopt_prefix_scales",
    ),
    "host_loop": ("ContinuousBatchingEngine.run",
                  "ContinuousBatchingEngine._run_impl"),
    # both spellings: the loop aliases `sched = self._sched` up front
    "device_returning": ("sched.run", "_sched.run"),
    "device_params": (),
    # host scheduling objects — taint never attaches to these names
    # (tel/reg/sp are the repro.obs telemetry handles: pure host-side
    # counters and span buffers, never device values — see
    # docs/observability.md)
    "host_objects": ("sched", "index", "allocator", "swap",
                     "tel", "reg", "sp", "telemetry"),
}

SPARQ_PRESETS = {
    "off": None,
    "a8w8": SparqConfig(enabled=False, signed=True),
    "5opt": SparqConfig.opt5(signed=True),
    "3opt": SparqConfig.opt3(signed=True),
    "2opt": SparqConfig.opt2(signed=True),
    "6opt": SparqConfig.opt6(signed=True),
    "7opt": SparqConfig.opt7(signed=True),
}


def make_cache_config(layout: str, sparq: Optional[SparqConfig],
                      impl: str = "auto") -> CacheConfig:
    """`--kv-cache` flag -> CacheConfig. The sparq layout reuses the active
    SPARQ preset as its codec (signed; falls back to plain int8 when the
    preset is off/a8w8)."""
    if layout == "fp32":
        return CacheConfig.fp32()
    if layout == "bf16":
        return CacheConfig.bf16()
    if layout == "sparq":
        if sparq is None:   # preset off -> plain int8 storage, no trimming
            return CacheConfig(layout="sparq", impl=impl)
        return CacheConfig.sparq_cache(sparq, impl=impl)
    raise ValueError(layout)


class DecodeEngine:
    """Greedy batched generation as one traced program per phase:
    a jitted prefill and a jitted `lax.scan` over decode steps (the scan
    carries (token, caches, pos)). With the sparq layout the traced step
    quantizes on write and attends through the fused packed-cache decode
    kernel on read — the packed planes are streamed directly; no full-plane
    dequantize inside the decode loop."""

    def __init__(self, model: Model, cache_cfg: Optional[CacheConfig] = None,
                 ctx: Optional[QuantCtx] = None, scales_groups=None):
        self.model = model
        self.cache_cfg = cache_cfg or CacheConfig.fp32()
        self.ctx = ctx
        self.scales_groups = scales_groups
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn, static_argnames=("steps",))

    # ------------------------------------------------------------ traced
    def _prefill_fn(self, params, batch, caches):
        logits, caches = self.model.prefill(
            params, batch, caches, ctx=self.ctx,
            scales_groups=self.scales_groups)
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32), caches

    def _decode_fn(self, params, tok0, caches, pos0, *, steps: int):
        def step(carry, _):
            tok, caches, pos = carry
            logits, caches = self.model.decode_step(
                params, tok, caches, pos, ctx=self.ctx,
                scales_groups=self.scales_groups)
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            return (nxt, caches, pos + 1), nxt[:, 0]

        (_, caches, _), toks = jax.lax.scan(
            step, (tok0, caches, jnp.asarray(pos0, jnp.int32)), None,
            length=steps)
        return toks.swapaxes(0, 1), caches  # [B, steps]

    # ------------------------------------------------------------ public
    def init_cache(self, batch: int, max_len: int):
        return self.model.init_cache(batch, max_len,
                                     cache_cfg=self.cache_cfg)

    def generate(self, params, batch, gen: int, pad: int = 8,
                 max_len: Optional[int] = None, warmup: bool = True):
        """Returns (tokens [B, gen], stats).

        `max_len` caps the cache capacity (default: prompt + gen + pad
        slots). The capacity check runs host-side *before* tracing: the
        traced write path (`dynamic_update_slice_in_dim`) silently clamps
        its start index, so an overflowing decode would quietly overwrite
        the newest cache slots instead of erroring.

        `warmup` runs prefill + decode once untimed first, so prefill_s /
        decode_tok_s measure steady-state execution rather than XLA
        compilation; the first (compiling) pass is reported as compile_s.
        """
        B, prompt_len = batch["tokens"].shape
        pos0 = prompt_len + (self.model.cfg.frontend_len
                             if self.model.cfg.family == "vlm" else 0)
        max_len = max_len if max_len is not None else pos0 + gen + pad
        if pos0 + gen > max_len:
            raise ValueError(
                f"KV-cache overflow: prompt ({pos0} slots) + generation "
                f"({gen}) needs {pos0 + gen} cache slots but capacity is "
                f"{max_len}; the traced write path would silently clamp "
                f"and overwrite the newest entries")
        caches = self.init_cache(B, max_len)

        compile_s = 0.0
        if warmup:
            t0 = time.perf_counter()
            tok_w, caches_w = self._prefill(params, batch, caches)
            if gen > 1:
                rest_w, _ = self._decode(params, tok_w, caches_w, pos0,
                                         steps=gen - 1)
                jax.block_until_ready(rest_w)
            else:
                jax.block_until_ready(tok_w)
            compile_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        tok0, caches = self._prefill(params, batch, caches)
        jax.block_until_ready(tok0)
        t_prefill = time.perf_counter() - t0

        t0 = time.perf_counter()
        if gen > 1:
            rest, caches = self._decode(params, tok0, caches, pos0,
                                        steps=gen - 1)
            jax.block_until_ready(rest)
            toks = jnp.concatenate([tok0, rest], axis=1)
        else:
            toks = tok0
        t_decode = time.perf_counter() - t0

        tally = cache_mod.modeled_cache_bytes(caches)
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "compile_s": compile_s,
            "decode_tok_s": (B * (gen - 1) / max(t_decode, 1e-9))
                            if gen > 1 else 0.0,
            "cache_bytes_per_value":
                cache_mod.bytes_per_value(self.cache_cfg),
            "cache_ctrl_bytes_per_value":
                cache_mod.ctrl_bytes_per_value(self.cache_cfg),
            "cache_data_bytes": tally["data_bytes"],
            "cache_total_bytes": tally["total_bytes"],
        }
        return toks, stats


def serve(model: Model, params, batch, gen: int,
          ctx: QuantCtx | None, scales_groups=None,
          cache_cfg: Optional[CacheConfig] = None, warmup: bool = True):
    """Greedy batched generation. Returns (tokens [B, gen], stats)."""
    engine = DecodeEngine(model, cache_cfg, ctx, scales_groups)
    return engine.generate(params, batch, gen, warmup=warmup)


# ----------------------------------------------------------------------
# continuous batching over the paged cache
# ----------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request: a prompt and a total token budget.

    `gen` counts like DecodeEngine's: total greedy tokens to return,
    including the one the prefill emits. `arrive_at` delays admission
    until the engine clock reaches it (0 = available at start): under
    the default `clock_mode="step"` the clock counts decode steps (plus
    idle fast-forwards); under `clock_mode="wall"` it is monotonic
    seconds since the run started (`time.perf_counter` based), so an
    arrival trace replays at real wall times. Either way it changes
    *when* a request is served, never its tokens."""
    tokens: np.ndarray          # [L] int prompt token ids
    gen: int
    arrive_at: float = 0.0      # engine-clock time at which it arrives

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens)
        assert self.tokens.ndim == 1 and self.tokens.size >= 1
        assert self.gen >= 1
        assert self.arrive_at >= 0


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """What to do when decode-time page allocation finds the pool dry.

    preempt  "requeue": drop the victim's pages and rebuild its cache
             later by re-running prefill plus a teacher-forced replay of
             its already-emitted tokens through the decode path — zero
             host traffic, recompute cost on resume. Exact because both
             passes are the deterministic programs that produced the
             original bytes.
             "swap": copy the victim's packed pages verbatim to a host
             SwapStore (§5.1 bytes: 0.9375 B/value modeled, ~4.3x less
             traffic than fp32 planes) and scatter them back when pages
             free up — no recompute, host bandwidth cost. Bit-exact by
             construction.
             "auto": pick per victim from the cost model below.
    victim   "last_joined": preempt the most recently admitted sequence
             first (oldest work is closest to completion).
             "fewest_pages": preempt the sequence owning the fewest pages
             (cheapest to rebuild/swap); ties broken last-joined-first.

    Either way resumed sequences take strict priority over new admissions
    (resume-before-admit), so preempted work cannot starve.

    Cost model (`--preempt auto`, `estimate_cost`): a requeue pays
    recompute — the prompt re-prefills in parallel (cheap per token) but
    every already-emitted token replays through the *sequential* decode
    path (one latency-bound step each), so its cost grows with decode
    progress. A swap pays bytes — the §5.1 packed pages cross the host
    link twice (out + in), so its cost grows with resident pages but is
    flat in decode progress. Early-life victims requeue, long-running
    victims swap; the crossover is pinned by a unit test. The knobs are
    modeled microseconds, not measurements — tune per deployment.
    """
    preempt: str = "requeue"        # requeue | swap | auto
    victim: str = "last_joined"     # last_joined | fewest_pages
    prefill_tok_us: float = 2.0     # re-prefill, parallel over the prompt
    replay_tok_us: float = 60.0     # teacher-forced decode replay, per step
    swap_gb_s: float = 8.0          # host<->device link bandwidth

    def __post_init__(self):
        if self.preempt not in ("requeue", "swap", "auto"):
            raise ValueError(f"unknown preempt mode {self.preempt!r}")
        if self.victim not in ("last_joined", "fewest_pages"):
            raise ValueError(f"unknown victim rule {self.victim!r}")

    def estimate_cost(self, prompt_len: int, generated: int,
                      swap_bytes: int) -> Tuple[float, float]:
        """Modeled (requeue_us, swap_us) for evicting + resuming one
        victim with `prompt_len` prompt tokens, `generated` tokens
        emitted so far, and `swap_bytes` §5.1 bytes resident in its
        pages (both directions are charged — gather out, scatter in)."""
        requeue = self.prefill_tok_us * prompt_len \
            + self.replay_tok_us * max(generated - 1, 0)
        swap = 2.0 * swap_bytes / (self.swap_gb_s * 1e3)   # bytes -> us
        return requeue, swap

    def resolve(self, prompt_len: int, generated: int,
                swap_bytes: int) -> str:
        """The concrete mode for one victim ("requeue" or "swap")."""
        if self.preempt != "auto":
            return self.preempt
        requeue, swap = self.estimate_cost(prompt_len, generated,
                                           swap_bytes)
        return "requeue" if requeue <= swap else "swap"


@dataclasses.dataclass
class _Slot:
    """Host-side state of one active sequence slot."""
    rid: int                    # request index
    target: int                 # total tokens to emit (== Request.gen)
    generated: int              # tokens emitted so far (tok0 counts)
    pages: List[int]            # physical pages owned by this sequence
    joined: int = 0             # admission sequence number (victim order)
    replay: List[int] = dataclasses.field(default_factory=list)
    # ^ chunked-mode requeue resume: already-emitted tokens still to be
    #   fed (teacher-forced) through the regular decode steps once the
    #   chunked re-prefill completes; outputs of those steps are
    #   discarded (the tokens are already recorded), their cache writes
    #   are the point. Empty for every other slot.


@dataclasses.dataclass
class _Preempted:
    """A preempted request waiting on the resume queue."""
    rid: int
    req: Request
    toks: List[int]             # greedy tokens emitted before preemption
    swapped: bool               # True: packed pages parked in the SwapStore


class ContinuousBatchingEngine:
    """Greedy generation over ragged requests with a paged SPARQ cache.

    The engine owns `max_active` sequence slots and one page pool
    (`n_pages` pages of `page_size` slots, shared page ids across layers).
    Requests queue for admission; a free slot admits the next request by
    prefilling it alone through the ordinary contiguous path (which also
    calibrates its per-sequence scales), then adopting the packed planes
    into freshly allocated pages — bit-identical bytes, no requantization.
    Every decode step is one jitted call over all S slots (inactive slots
    are masked inside the kernel); between steps the host only does
    scheduling: evict finished sequences (pages back to the free list),
    resume preempted sequences then admit from the queue, and allocate a
    page when a sequence's next token crosses into an unallocated block.

    With `policy=None` decode-time pool exhaustion raises `PoolExhausted`
    host-side, before any tracing. With a `SchedulerPolicy` the pool may
    be *oversubscribed*: exhaustion instead preempts victim sequences —
    requeueing them (drop pages, rebuild by prefill + teacher-forced
    replay on resume) or swapping their packed pages to a host
    `SwapStore` — and resumes them bit-exactly, ahead of new admissions,
    once pages free up. Greedy tokens are identical with and without
    preemption (tested for the int8 grid and the 4-bit 5opt codec under
    both policies); `PoolExhausted` then only fires when no victim
    remains to preempt.

    Restrictions: standard-KV attention families only (dense / MoE-GQA);
    MLA latent caches, recurrent state, and encoder-decoder cross caches
    keep the contiguous engine. The cache layout must be sparq.
    """

    def __init__(self, model: Model, cache_cfg: CacheConfig,
                 ctx: Optional[QuantCtx] = None, scales_groups=None, *,
                 page_size: int = 16, n_pages: int = 64,
                 max_active: int = 4, max_seq_len: int = 512,
                 policy: Optional[SchedulerPolicy] = None,
                 prefill: str = "sequential", chunk_size: int = 32,
                 chunk_align: int = 8, chunk_seg: Optional[int] = None,
                 prefix_cache: bool = False, prefix_min_pages: int = 1,
                 prefill_priority: float = 1.0, mesh=None,
                 telemetry: Optional[Telemetry] = None):
        if cache_cfg.layout != "sparq":
            raise ValueError("the paged engine stores packed §5.1 pages; "
                             "use --kv-cache sparq")
        bad = [k for k in model.kinds if k not in ("dense", "moe")]
        if bad or model.cfg.family == "vlm":
            raise ValueError(
                f"paged serving supports standard-KV attention stacks only "
                f"(got kinds {sorted(set(bad))or model.cfg.family}); use the "
                f"scan engine for MLA/recurrent/enc-dec/VLM architectures")
        if max_seq_len % page_size:
            raise ValueError(f"max_seq_len {max_seq_len} must be a multiple "
                             f"of page_size {page_size}")
        if prefill not in ("sequential", "chunked"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill_priority <= 0:
            raise ValueError("--prefill-priority must be > 0: it is the "
                             "mean prefill chunks run per scheduler "
                             "iteration (1.0 = one chunk per decode step)")
        if prefill_priority != 1.0 and prefill != "chunked":
            raise ValueError("--prefill-priority only meters the chunked "
                             "prefill stream; add --prefill chunked")
        if prefix_cache and prefill != "chunked":
            raise ValueError(
                "--prefix-cache requires --prefill chunked: only the "
                "chunked path's segment-granular scale freezing makes "
                "packed prefill bytes a pure function of (prompt, seg) — "
                "sequential admission freezes scales from the whole "
                "prompt's range, so equal prefixes of different prompts "
                "would not share bytes")
        # tensor parallelism: a ("data","model") jax Mesh shards the page
        # pools and attention heads over the "model" axis (head groups
        # never split, so n_kv_heads must divide). The host-side
        # allocator / prefix index / scheduler stay global — every device
        # sees the same block tables, and swap/requeue move each device's
        # local planes. See docs/sharding.md.
        from repro.kernels.ops import tp_size
        self.mesh = mesh
        self.tp = tp_size(mesh)
        self._rep_sharding = None if mesh is None else \
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        if self.tp > 1 and model.cfg.n_kv_heads % self.tp:
            raise ValueError(
                f"--tp {self.tp} must divide n_kv_heads="
                f"{model.cfg.n_kv_heads}: the packed (data, meta) planes "
                f"shard by whole GQA head groups")
        self.model = model
        self.cc = cache_cfg
        self.ctx = ctx
        self.scales_groups = scales_groups
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_active = max_active
        self.n_blocks = max_seq_len // page_size
        self.policy = policy
        self.prefill_mode = prefill
        # host bytes one resident page actually moves on a swap round
        # trip (for SchedulerPolicy "auto"): four int8 planes per layer
        # (K/V x data/meta) — the same figure SwapStore's bytes_out/in
        # counters measure, so the cost model and the reported stats
        # agree. (On §5.1 hardware the packed planes would move
        # kernels.ops.bytes_per_value instead, ~2.1x less for 5opt —
        # fold that into swap_gb_s when modeling such a link.)
        cfgm = model.cfg
        n_layers = sum(count for _, count in model.groups_meta)
        self._page_bytes = int(4 * n_layers * page_size * cfgm.n_kv_heads
                               * cfgm.head_dim)
        # telemetry: always-on metrics registry (one float add per
        # event); span tracing and per-step phase histograms only when
        # the caller attaches them (Telemetry.tracing() /
        # .metrics_only()). Every stats-dict entry is sourced from this
        # registry — see docs/observability.md for the catalog.
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._sched = None
        if prefill == "chunked":
            from repro.launch.prefill import PrefillScheduler
            self._sched = PrefillScheduler(
                model, ctx, scales_groups, chunk_size=chunk_size,
                align=chunk_align, page_size=page_size,
                n_slots=max_active, seg=chunk_seg, mesh=mesh,
                telemetry=self.telemetry)
        self.prefix_cache = prefix_cache
        self.prefix_min_pages = max(1, prefix_min_pages)
        # prefix-match granularity: whole pages (only fully-written,
        # never-rewritten pages are shareable) AND whole prefill segments
        # (the tail job must resume at a segment boundary, and the
        # adopted scale is only the borrower's own would-be scale when
        # the shared prefix covers the first segment)
        self._quantum = math.lcm(page_size, self._sched.seg) \
            if prefix_cache else 0
        # requeue resume replays decode steps through a temporary
        # *contiguous* cache; pinning its fused-kernel tile to the page
        # size makes the replay reads bit-identical to the paged reads
        # that produced the original tokens (one page == one Tk tile)
        self._cc_replay = dataclasses.replace(cache_cfg, attn_bk=page_size)
        self._debug_state: dict = {}     # last run's allocator/slots (tests)
        self.prefill_priority = float(prefill_priority)
        # live-traffic mailboxes: submit()/cancel() may be called from any
        # thread while run() is looping; the loop drains both under the
        # lock exactly once per iteration, so everything inside the loop
        # stays single-threaded. `_wake` shortens idle sleeps when traffic
        # lands; `_run_live` gates submissions to a running loop.
        self._mbox_lock = threading.Lock()
        self._inbox: List[Tuple[int, Request, Optional[float]]] = []
        self._cancel_box: set = set()
        self._wake = threading.Event()
        self._run_live = threading.Event()
        self._stop_flag = False
        self._next_rid = 0
        self._t_origin: Optional[float] = None   # wall t0 of the live run
        self._live: Optional[dict] = None        # reset_stats() target
        self._prefill = jax.jit(self._prefill_fn)
        self._replay = jax.jit(self._replay_fn)
        # donate the cache buffers: the pools are the dominant state and
        # every step rewrites them in place — without donation XLA would
        # copy all packed planes each token, doubling the traffic the
        # packed format exists to shrink. run() rebinds `caches` on every
        # update and derives pos_dev as a fresh slice, so donation is
        # safe; `tok` is NOT donated (history keeps each step's tokens
        # alive until final assembly).
        self._step = jax.jit(self._step_fn, donate_argnums=(2,))
        self._adopt = jax.jit(paging.adopt_prefill, donate_argnums=(0,))
        self._evict = jax.jit(paging.evict_slot, donate_argnums=(0,))
        # swap-out gathers copy out of the pool (no donation); swap-in
        # scatters rewrite it in place (donated like adoption)
        self._gather = jax.jit(paging.gather_slot_pages)
        self._restore = jax.jit(paging.restore_slot_pages,
                                donate_argnums=(0,))
        # shared-prefix admission: copy-on-write page duplication and
        # donor-scale adoption (both rewrite the store in place)
        self._copy_page = jax.jit(paging.copy_page, donate_argnums=(0,))
        self._adopt_scales = jax.jit(paging.adopt_prefix_scales,
                                     donate_argnums=(0,))

    # ------------------------------------------------------------ traced
    def _prefill_fn(self, params, batch, caches):
        logits, caches = self.model.prefill(
            params, batch, caches, ctx=self.ctx,
            scales_groups=self.scales_groups)
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32), caches

    def _step_fn(self, params, tok, caches, pos):
        logits, caches = self.model.decode_step(
            params, tok, caches, pos, ctx=self.ctx,
            scales_groups=self.scales_groups)
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32), caches

    def _replay_fn(self, params, toks, caches, pos0):
        """Teacher-forced decode replay for requeue resume: feed the
        recorded greedy tokens `toks` [1, n] through the contiguous decode
        path, writing their K/V at positions pos0..pos0+n-1. The logits
        are discarded (the tokens are already known), so XLA drops the
        head matmul; what remains is exactly the cache-write path that
        produced the original bytes — replayed bytes are bit-identical."""
        def step(carry, tok_t):
            caches, pos = carry
            _, caches = self.model.decode_step(
                params, tok_t[:, None], caches, pos, ctx=self.ctx,
                scales_groups=self.scales_groups)
            return (caches, pos + 1), ()

        (caches, _), _ = jax.lax.scan(
            step, (caches, jnp.asarray(pos0, jnp.int32)),
            toks.swapaxes(0, 1))
        return caches

    # ------------------------------------------------------------ device
    def _init_stores(self) -> list:
        cfg = self.model.cfg
        stores = []
        for kind, count in self.model.groups_meta:
            one = paging.PagedCacheStore.init(
                self.max_active, self.n_pages, self.page_size,
                self.n_blocks, cfg.n_kv_heads, cfg.head_dim, self.cc,
                mesh=self.mesh)
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape).copy(),
                one)
            if self.mesh is not None:
                # place the pools physically: packed planes sharded along
                # the KV-head axis, bookkeeping replicated (the host
                # scheduler is global, so every device needs the tables)
                from repro.distributed.sharding import paged_pool_shardings
                stacked = jax.device_put(
                    stacked, paged_pool_shardings(stacked, self.mesh))
            stores.append(stacked)
        return stores

    def _replicated(self, x):
        """Host->device placement for per-step scalars/tables under TP:
        one explicit replicated device_put (the blessed transfer) instead
        of letting the jitted step reshard a single-device array."""
        if self._rep_sharding is None:
            return x
        return jax.device_put(x, self._rep_sharding)

    # ------------------------------------------------------------ trace
    @staticmethod
    def _snapshot(n_steps, allocator, slots, host_bt, host_pos, caches,
                  queue, resume_q, swap, prefilling=(),
                  replaying=(), prefix=None) -> dict:
        """Scheduler-state snapshot handed to `run(trace_hook=...)` before
        each traced decode step. Host fields are copies (safe to keep);
        `caches` is the live device state for deep cross-checks.
        `prefilling` lists slots mid-chunked-prefill (their device
        seq_pos is the -1 inactive sentinel while host `pos` counts the
        prompt tokens already written); `replaying` lists slots replaying
        recorded tokens after a chunked requeue resume."""
        return {
            "step": n_steps,
            "n_pages": allocator.n_pages,
            "free_pages": allocator.free_pages,
            "peak_pages": allocator.peak_used,
            "slots": {s: {"rid": st.rid, "pages": list(st.pages),
                          "pos": int(host_pos[s]),
                          "generated": st.generated, "target": st.target,
                          "joined": st.joined}
                      for s, st in enumerate(slots) if st is not None},
            "host_bt": host_bt.copy(),
            "queued": [rid for _, rid, _ in sorted(queue)],
            "resume_rids": [rec.rid for rec in resume_q],
            "swapped_rids": sorted(
                rec.rid for rec in resume_q if rec.swapped),
            "swap_resident_bytes": swap.resident_bytes,
            "prefilling": tuple(prefilling),
            "replaying": tuple(replaying),
            "page_refcounts": allocator.refcounts,
            "prefix": dict(prefix) if prefix is not None else None,
            "caches": caches,
        }

    # ------------------------------------------------------ live traffic
    def _validate_request(self, req: Request, label="request") -> None:
        need = len(req.tokens) + req.gen - 1
        ps = self.page_size
        if need > self.n_blocks * ps or math.ceil(need / ps) > self.n_pages:
            raise ValueError(
                f"{label} needs {need} slots "
                f"({math.ceil(need / ps)} pages) but the engine serves "
                f"at most {self.n_blocks * ps} slots/sequence from "
                f"{self.n_pages} pages — raise max_seq_len/n_pages")

    def submit(self, req, at: Optional[float] = None) -> int:
        """Hand a new request to a *running* `run()` loop; thread-safe.

        Returns the request id the results/stream will use. `at` is the
        engine-clock arrival time (see Request.arrive_at); None stamps
        the request with the clock value at mailbox drain — i.e. "it
        arrived now". The request's own `arrive_at` field is ignored on
        this path (`at` is authoritative). Raises RuntimeError when no
        run loop is live to serve it."""
        # duck-typed: `python -m repro.launch.serve` loads this module as
        # __main__, so an isinstance against Request would reject Request
        # objects built by importers of repro.launch.serve
        req = req if hasattr(req, "tokens") else Request(*req)
        self._validate_request(req, label="submitted request")
        if not self._run_live.wait(timeout=5.0):
            raise RuntimeError(
                "submit() requires a live run() loop — start the engine "
                "(e.g. through launch.frontend.AsyncFrontend) first")
        with self._mbox_lock:
            rid = self._next_rid
            self._next_rid += 1
            self._inbox.append((rid, req, None if at is None else float(at)))
        self._wake.set()
        return rid

    def cancel(self, rid: int) -> None:
        """Cancel a request by id; thread-safe, idempotent, best-effort
        (a request that already finished is left untouched). Queued
        requests are dropped; a mid-prefill request drops its
        PrefillScheduler job and grants; an active or preempted one is
        evicted — shared prefix pages refcount-released, swapped planes
        discarded without a swap-in. Partial tokens stay in the result."""
        with self._mbox_lock:
            self._cancel_box.add(rid)
        self._wake.set()

    def request_stop(self) -> None:
        """Ask a `drain=False` (serve-forever) run loop to exit at the
        next iteration; thread-safe. In-flight requests are abandoned
        with partial results. Draining runs ignore it."""
        self._stop_flag = True
        self._wake.set()

    def reset_stats(self) -> None:
        """Zero the live run's measurement counters in place — the
        warmup/measure boundary. After a warmup workload has compiled
        every program and warmed the PrefixIndex, calling this makes the
        subsequently reported stats (prefix hits, preemptions, peak
        pages/swap watermarks, timings, tok/s) reflect only the traffic
        that follows, instead of inheriting the warmup's. Call it from
        the engine thread (a trace_hook) or while the loop is idle."""
        lv = self._live
        if lv is None:
            return
        reg = self.telemetry.registry
        reg.reset()
        # gauges restart from the *current* occupancy, exactly as the
        # old acc["peak_pages"] restarted from allocator.used_count
        reg.gauge("pool_pages_in_use").set(lv["allocator"].used_count)
        reg.gauge("pool_pages_peak").set(lv["allocator"].used_count)
        lv["acc"].update(t0=time.perf_counter())
        lv["allocator"].reset_peak()
        lv["swap"].reset_counters()
        if lv["sched"] is not None:
            lv["sched"].chunks_run = 0

    # ------------------------------------------------------------ public
    def run(self, params, requests: Sequence[Request],
            progress: bool = False, trace_hook=None, emit=None,
            clock_mode: str = "step", drain: bool = True
            ) -> Tuple[Dict[int, np.ndarray], dict]:
        """Serve every request to completion; greedy tokens per request.

        Returns ({request_index: int32 [gen] tokens}, stats). Each run
        starts from a fresh pool and fresh (uncalibrated) scales, so a run
        is reproducible and re-entrant; jitted programs are reused across
        runs (call once to warm up, again to time steady state).

        `trace_hook`, if given, is called with a scheduler-state snapshot
        dict immediately before every traced decode step (see `_snapshot`)
        — the randomized-trace test harness asserts per-step invariants
        there. Page accounting invariants (free-list conservation, no
        double-use, block-table/position consistency) are additionally
        asserted internally every iteration regardless of the hook.

        `emit`, if given, streams tokens: `emit(rid, token, final, t)` is
        called from the engine thread with each host-int greedy token the
        moment its step's device fetch lands (`t` = perf_counter stamp;
        one batched `jax.device_get` per decode step, never per token).
        Streaming runs skip the device-side history (results come from
        the emitted host ints), so a serve-forever loop holds no
        per-token device garbage.

        `clock_mode` selects the arrival clock `Request.arrive_at` is
        compared against: "step" (default) counts decode steps and
        fast-forwards over idle gaps — deterministic, for tests and
        throughput benchmarks; "wall" reads monotonic seconds since run
        start, and idle waits sleep in real time — the latency-SLO mode.

        `drain=False` (requires "wall") keeps the loop alive when queue
        and slots are empty, serving `submit()` traffic until
        `request_stop()` — the asyncio front-end's serve-forever mode.
        Completion asserts are skipped for requests still in flight at
        stop; their partial token streams are returned as-is.
        """
        if clock_mode not in ("step", "wall"):
            raise ValueError(f"unknown clock_mode {clock_mode!r}")
        if not drain and clock_mode != "wall":
            raise ValueError("drain=False (serve-forever) needs "
                             "clock_mode='wall': a step clock cannot "
                             "sleep for traffic")
        try:
            return self._run_impl(params, requests, progress, trace_hook,
                                  emit, clock_mode, drain)
        finally:
            # a finished (or dead) loop must stop accepting traffic:
            # late submit()/reset_stats() calls fail fast / no-op instead
            # of landing in state nobody is serving
            self._run_live.clear()
            self._live = None

    def _run_impl(self, params, requests, progress, trace_hook,
                  emit, clock_mode, drain):
        wall = clock_mode == "wall"
        requests = {i: (r if hasattr(r, "tokens") else Request(*r))
                    for i, r in enumerate(requests)}
        ps, NB = self.page_size, self.n_blocks
        sched = self._sched
        if sched is not None:
            sched.reset()
        for i, r in requests.items():
            self._validate_request(r, label=f"request {i}")

        # ---- telemetry: the registry is the single store for every
        # scheduling counter and timing this run reports (the stats dict
        # below is assembled from registry reads). Series handles are
        # pre-bound here so the hot loop pays one float add per event.
        # reg.reset() gives each run fresh stats, matching the fresh
        # pool/scales semantics of run() itself.
        tel = self.telemetry
        reg = tel.registry
        sp = tel.spans
        reg.reset()
        c_preempt = reg.counter("engine_preemptions_total",
                                "sequences preempted, by resolved mode",
                                labelnames=("mode",))
        c_pre_req = c_preempt.series(mode="requeue")
        c_pre_swap = c_preempt.series(mode="swap")
        c_resumes = reg.counter("engine_resumes_total",
                                "preempted sequences rebuilt").series()
        c_replay = reg.counter("engine_replay_steps_total",
                               "teacher-forced replay decode steps"
                               ).series()
        c_cancel = reg.counter("engine_cancelled_total",
                               "requests cancelled mid-flight").series()
        c_steps = reg.counter("engine_decode_steps_total",
                              "jitted decode steps executed").series()
        c_tokens = reg.counter("engine_decode_tokens_total",
                               "greedy tokens emitted by decode steps"
                               ).series()
        c_chunks = reg.counter("engine_prefill_chunks_total",
                               "chunked-prefill chunk programs run"
                               ).series()
        c_t_prefill = reg.counter("engine_prefill_seconds_total",
                                  "time admitting prompts (prefill)",
                                  unit="seconds").series()
        c_t_resume = reg.counter("engine_resume_seconds_total",
                                 "time rebuilding preempted sequences",
                                 unit="seconds").series()
        c_phit = reg.counter("prefix_cache_hits_total",
                             "admissions adopting cached prefix pages"
                             ).series()
        c_pmiss = reg.counter("prefix_cache_misses_total",
                              "admissions with no usable cached prefix"
                              ).series()
        c_ptok = reg.counter("prefix_cache_hit_tokens_total",
                             "prompt tokens served from cached pages"
                             ).series()
        c_pshared = reg.counter("prefix_cache_shared_pages_total",
                                "whole pages adopted from the cache"
                                ).series()
        c_cow = reg.counter("prefix_cache_cow_copies_total",
                            "copy-on-write boundary-page duplications"
                            ).series()
        c_refuse = reg.counter("engine_swap_refusals_total",
                               "swap preemptions demoted to requeue "
                               "(victim held shared pages)").series()
        g_pages = reg.gauge("pool_pages_in_use",
                            "pages currently allocated", unit="pages"
                            ).series()
        g_peak = reg.gauge("pool_pages_peak",
                           "high-water allocated pages", unit="pages"
                           ).series()
        g_active = reg.gauge("engine_active_slots",
                             "slots decoding this step").series()
        g_queued = reg.gauge("engine_queue_depth",
                             "requests waiting for admission").series()
        h_phase = reg.histogram("engine_step_phase_seconds",
                                "scheduler-iteration phase durations",
                                unit="seconds", labelnames=("phase",))
        h_retire = h_phase.series(phase="retire")
        h_admit = h_phase.series(phase="admit")
        h_prefill = h_phase.series(phase="prefill")
        h_decode = h_phase.series(phase="decode")
        timed = tel.step_timing or sp.on

        def prefix_stats():
            """pstats-shaped dict from registry reads (trace snapshots
            and the stats assembly below)."""
            return {"prefix_hits": int(c_phit.value()),
                    "prefix_misses": int(c_pmiss.value()),
                    "prefix_hit_tokens": int(c_ptok.value()),
                    "prefix_shared_pages": int(c_pshared.value()),
                    "cow_copies": int(c_cow.value()),
                    "swap_refusals": int(c_refuse.value())}

        allocator = paging.PageAllocator(self.n_pages)
        # fresh prefix index per run (the pool is fresh too): non-owning,
        # invalidated page-by-page as refcounts fall to zero
        index = paging.PrefixIndex(self._quantum, ps) \
            if self.prefix_cache else None
        caches = self._init_stores()
        S = self.max_active
        # under TP, pin params and the token vector replicated over the
        # mesh once, up front — every jitted program then sees committed,
        # consistently-placed inputs (no per-step implicit resharding)
        params = self._replicated(params)
        tok = self._replicated(jnp.zeros((S, 1), jnp.int32))
        slots: List[Optional[_Slot]] = [None] * S
        host_bt = np.full((S, NB), -1, np.int64)
        host_pos = np.full((S,), -1, np.int64)
        # admission order: arrival time, then request id (FIFO) — a heap,
        # because submit() pushes mid-run and the idle fast-forward must
        # always see the *earliest* pending arrival at queue[0]
        queue = [(float(r.arrive_at), rid, r) for rid, r in requests.items()]
        heapq.heapify(queue)
        cancelled: set = set()      # rids cancelled; heap entries lazy-skip
        resume_q: List[_Preempted] = []
        swap = paging.SwapStore(registry=reg)
        first_tok: Dict[int, jnp.ndarray] = {}
        emitted: Dict[int, List[int]] = {}   # emit mode: host token copies
        history: List[Tuple[tuple, jnp.ndarray]] = []
        # replay-divergence self-checks, verified after the loop in one
        # batched fetch — reading each scalar inline would sync the
        # decode pipeline at every resume / chunk completion (HL202).
        # Device scalars and host expectations ride in parallel lists so
        # the post-loop compare touches no device values.
        deferred_checks: List[jnp.ndarray] = []
        deferred_expect: List[Tuple[int, str]] = []
        join_seq = 0
        # every measurement counter lives in the registry (reset_stats
        # delegates to reg.reset()); only the run-start wall stamp stays
        # in a plain dict so reset_stats can restamp it mid-run. n_steps
        # stays a plain local — it sequences trace snapshots, never stats
        acc = {"t0": 0.0}
        n_steps = 0                 # decode steps actually executed
        clock = 0.0                 # arrival clock: steps (or wall seconds)
        chunk_credit = 0.0          # fractional prefill chunks banked
        # expose the live scheduling state for post-mortem tests: after a
        # PoolExhausted escapes, page accounting must still be consistent
        self._debug_state = {"allocator": allocator, "slots": slots,
                             "swap": swap, "prefix_index": index}
        self._live = {"acc": acc, "allocator": allocator, "swap": swap,
                      "sched": sched}
        with self._mbox_lock:
            self._next_rid = len(requests)
            self._inbox.clear()
            self._cancel_box.clear()
        self._stop_flag = False

        # ---------------- preemption machinery (closures over run state)
        def emitted_toks(rid: int) -> List[int]:
            """Host copies of every greedy token rid has emitted, in
            order, across all of its slot residencies — one batched
            device fetch per call (preemptions are rare; per-step
            fetches would sync the decode pipeline every token). In
            emit mode the per-step streaming fetch already landed every
            token on the host, so this is a pure host read."""
            if emit is not None:
                return list(emitted[rid])
            out = [int(jax.device_get(first_tok[rid]))]
            hits = [(i, s_h) for i, (act, _) in enumerate(history)
                    for s_h, r in act if r == rid]
            if hits:
                toks_np = jax.device_get(
                    jnp.concatenate([t for _, t in history], axis=1))
                out.extend(int(toks_np[s_h, i]) for i, s_h in hits)
            return out

        def drop_pages(pages: List[int]):
            """Release one reference per page; prefix-index entries naming
            any page that reached zero are invalidated (the page may be
            reallocated with different bytes). Shared pages survive — the
            other holders' references keep them resident and indexed."""
            freed = allocator.release(pages)
            if index is not None and freed:
                index.invalidate(freed)

        def evict(s: int):
            """Drop a slot's page references and clear it. Pages shared
            with other sequences stay allocated (their refcount is still
            positive); exclusively-owned ones return to the free list."""
            nonlocal caches
            drop_pages(slots[s].pages)
            caches = [self._evict(c, jnp.int32(s)) for c in caches]
            host_bt[s] = -1
            host_pos[s] = -1
            slots[s] = None

        def drain_mailboxes():
            """Fold submit()/cancel() traffic into the run state — called
            once per loop iteration, so everything else in the loop stays
            single-threaded. Arrivals stamped `at=None` arrive "now" (the
            current clock); cancellations release whatever the request
            holds: queue entry (lazy — the rid is skipped at pop), live
            slot (evicted; shared prefix pages refcount-released),
            mid-prefill job (PrefillScheduler entry + granted pages
            dropped), or resume-queue record (swapped planes discarded
            without charging a swap-in)."""
            with self._mbox_lock:
                arrivals, self._inbox = self._inbox, []
                cxl = self._cancel_box
                self._cancel_box = set()
            self._wake.clear()
            for rid, req, at in arrivals:
                requests[rid] = req
                heapq.heappush(
                    queue, (clock if at is None else float(at), rid, req))
                sp.submitted(rid)
            for rid in cxl:
                if rid in cancelled:
                    continue
                hit = any(q_rid == rid for _, q_rid, _ in queue)
                s = next((i for i, st in enumerate(slots)
                          if st is not None and st.rid == rid), None)
                if s is not None:
                    if sched is not None and sched.has(s):
                        sched.cancel(s)
                    evict(s)
                    hit = True
                rec = next((r for r in resume_q if r.rid == rid), None)
                if rec is not None:
                    resume_q.remove(rec)
                    if rec.swapped:
                        swap.discard(rid)
                    hit = True
                if hit:
                    cancelled.add(rid)
                    c_cancel.inc()
                    sp.cancelled(rid)

        def finished_slot() -> Optional[int]:
            return next((s for s, st in enumerate(slots)
                         if st is not None and st.generated >= st.target),
                        None)

        def select_victim(exclude=()):
            cands = [(s, st) for s, st in enumerate(slots)
                     if st is not None and s not in exclude]
            if not cands or self.policy is None:
                return None
            if self.policy.victim == "fewest_pages":
                key = lambda c: (len(c[1].pages), -c[1].joined)
            else:                               # last_joined
                key = lambda c: (-c[1].joined,)
            return min(cands, key=key)[0]

        def preempt(s: int):
            nonlocal caches
            st = slots[s]
            mid_prefill = sched is not None and sched.has(s)
            toks = emitted_toks(st.rid) if st.rid in first_tok else []
            assert mid_prefill or len(toks) == st.generated, \
                (st.rid, len(toks))
            # swap needs the victim's pages to hold its *complete* cache:
            # a slot mid-chunked-prefill or mid-replay has partial pages
            # only, so it always requeues (nothing but prompt recompute
            # is lost); otherwise the policy decides — "auto" from the
            # modeled recompute-vs-bytes crossover per victim.
            if mid_prefill or st.replay or not toks:
                mode = "requeue"
            else:
                mode = self.policy.resolve(
                    len(requests[st.rid].tokens), st.generated,
                    len(st.pages) * self._page_bytes)
            if mode == "swap" and any(allocator.refcount(p) > 1
                                      for p in st.pages):
                # the swap path refuses to park pages it does not
                # exclusively own: parked planes must restore verbatim
                # onto *fresh* pages later, but a shared page's other
                # holders keep it live in the pool — parking it would
                # fork the bytes (and freeing it would tear it out from
                # under them). Requeue instead: release the references
                # and rebuild by re-prefill, which may even re-match the
                # still-resident shared prefix.
                mode = "requeue"
                c_refuse.inc()
            if mid_prefill:
                sched.cancel(s)
            rec = _Preempted(rid=st.rid, req=requests[st.rid], toks=toks,
                             swapped=mode == "swap")
            if rec.swapped:
                t_sw0 = time.perf_counter() if sp.on else 0.0
                pages_dev = jnp.asarray(st.pages, jnp.int32)
                planes = [self._gather(c, jnp.int32(s), pages_dev)
                          for c in caches]
                nbytes = swap.put(st.rid, planes, int(host_pos[s]))
                if sp.on:
                    sp.swap(st.rid, t_sw0, time.perf_counter(), "out",
                            nbytes)
            caches = [self._evict(c, jnp.int32(s)) for c in caches]
            drop_pages(st.pages)
            host_bt[s] = -1
            host_pos[s] = -1
            slots[s] = None
            resume_q.append(rec)
            (c_pre_swap if rec.swapped else c_pre_req).inc()
            sp.preempted(st.rid, mode=mode)
            if progress:
                how = "swap" if rec.swapped else "requeue"
                print(f"[preempt] rid={st.rid} slot={s} mode={how} "
                      f"done={st.generated}/{st.target}")

        def bind_slot(s: int, rid: int, req: Request, pages: List[int],
                      pos: int, generated: int, last_tok):
            nonlocal tok, join_seq
            tok = tok.at[s, 0].set(last_tok)
            slots[s] = _Slot(rid=rid, target=req.gen, generated=generated,
                             pages=list(pages), joined=join_seq)
            join_seq += 1
            host_bt[s] = -1
            host_bt[s, :len(pages)] = pages
            host_pos[s] = pos

        def bind_prefilling(s: int, rid: int, req: Request, *,
                            recorded=(), start: int = 0, pages=()):
            """Bind a slot whose prompt will stream through the chunked
            prefill path: no pages yet (granted chunk by chunk), host
            position 0 (prompt tokens written so far), device seq_pos
            stays -1 so interleaved decode steps treat it as inactive.
            `recorded` (requeue resume) is the victim's already-emitted
            token list: the chunk program's tok0 is asserted against
            recorded[0] and the rest replays teacher-forced through the
            ordinary decode steps once the prompt completes.
            `start`/`pages` (shared-prefix admission): prompt positions
            [0, start) are already backed by `pages` — the adopted shared
            run plus, when start is mid-page, its private copy-on-write
            boundary page — so the prefill job begins at `start` and only
            the tail streams through the chunk program."""
            nonlocal join_seq
            recorded = list(recorded)
            pages = list(pages)
            slots[s] = _Slot(rid=rid, target=req.gen,
                             generated=len(recorded), pages=pages,
                             joined=join_seq, replay=recorded[1:])
            join_seq += 1
            host_bt[s] = -1
            host_bt[s, :len(pages)] = pages
            host_pos[s] = start
            sched.add(s, rid, req.tokens,
                      expect_tok0=recorded[0] if recorded else None,
                      start=start)

        def resume(s: int, rec: _Preempted):
            """Rebuild a preempted sequence in slot s. Caller guarantees
            the allocator holds enough pages (incl. the growth page when
            pos sits on a block boundary)."""
            nonlocal caches
            t0 = time.perf_counter()
            c_resumes.inc()
            if rec.swapped:
                nbp = swap.n_pages(rec.rid)
                pages = allocator.alloc(nbp)
                planes_np, pos = swap.pop(rec.rid)
                pages_dev = jnp.asarray(pages, jnp.int32)
                caches = [self._restore(
                    c, {k: self._replicated(jnp.asarray(v))
                        for k, v in pl.items()},
                    jnp.int32(s), pages_dev, jnp.int32(pos))
                    for c, pl in zip(caches, planes_np)]
                jax.block_until_ready(caches[0].seq_pos)
            elif sched is not None:
                # chunked requeue: the prompt re-prefills through the
                # chunked path (pages granted chunk by chunk, interleaved
                # with decode) and the emitted tokens replay teacher-
                # forced through the regular decode steps — same traced
                # programs that produced the original bytes, so the
                # rebuilt cache is bit-identical, with no per-length
                # retrace and no contiguous staging cache.
                bind_prefilling(s, rec.rid, rec.req, recorded=rec.toks)
                c_t_resume.inc(time.perf_counter() - t0)
                sp.resumed(rec.rid, phase="prefill")
                if progress:
                    print(f"[resume] rid={rec.rid} slot={s} chunked "
                          f"re-prefill queued ({len(rec.toks)} recorded)")
                return
            else:                               # requeue: recompute
                L, done = len(rec.req.tokens), len(rec.toks)
                pos = L + done - 1
                nbp = math.ceil(pos / ps)
                pages = allocator.alloc(nbp)
                tmp = self.model.init_cache(1, nbp * ps,
                                            cache_cfg=self._cc_replay)
                tok0, tmp = self._prefill(
                    params, {"tokens": jnp.asarray(rec.req.tokens)[None]},
                    tmp)
                deferred_checks.append(tok0[0, 0])
                deferred_expect.append((
                    rec.toks[0],
                    "requeue replay diverged at prefill — greedy decode "
                    "is no longer deterministic"))
                if done > 1:
                    tmp = self._replay(
                        params, jnp.asarray(rec.toks[:-1], jnp.int32)[None],
                        tmp, jnp.int32(L))
                    c_replay.inc(done - 1)
                pages_dev = jnp.asarray(pages, jnp.int32)
                caches = [self._adopt(c, t_g, jnp.int32(s), pages_dev)
                          for c, t_g in zip(caches, tmp)]
            bind_slot(s, rec.rid, rec.req, pages, pos,
                      generated=len(rec.toks), last_tok=rec.toks[-1])
            t1 = time.perf_counter()
            c_t_resume.inc(t1 - t0)
            if sp.on:
                sp.resume_work(rec.rid, t0, t1,
                               mode="swap" if rec.swapped else "replay")
                sp.resumed(rec.rid, phase="decode", t=t1)
            if progress:
                print(f"[resume] rid={rec.rid} slot={s} pos={pos} "
                      f"pages={pages}")

        def growth_debt() -> int:
            """Pages the *running* sequences need before the next step —
            the admission watermark. Joining may not drain the free list
            below this debt: a resume or admission that stole a running
            sequence's growth page would force a preemption in the very
            same iteration (and, worst case, thrash the sequence that
            just resumed)."""
            debt = 0
            for s in range(S):
                st = slots[s]
                if st is None or st.generated >= st.target:
                    continue
                if sched is not None and sched.has(s):
                    continue        # mid-prefill: pages granted per chunk
                if host_bt[s, host_pos[s] // ps] < 0:
                    debt += 1
            return debt

        def prefill_debt() -> int:
            """Pages the partially-prefilled sequences still need to
            finish their prompts — plus, as at sequential admission, the
            first boundary-growth page of any whose prompt ends exactly
            on a block boundary (its first decode write needs a fresh
            page the moment prefill completes). Charged by the admission
            watermark so a burst of new admissions cannot starve
            in-flight prefills or thrash them into preemption at their
            very first decode step (the chunked counterpart of reserving
            prompt pages up front)."""
            if sched is None:
                return 0
            debt = 0
            for j in sched.jobs:
                debt += sched.pages_outstanding(j.slot, host_bt)
                if slots[j.slot].target > 1 and len(j.tokens) % ps == 0:
                    debt += 1
            return debt

        def resume_need(rec: _Preempted) -> int:
            """Pages a resume must find free: the restored pages plus the
            growth page when the next write crosses into a new block —
            reserving it up front keeps a fresh resume from being
            immediately re-preempted by its own growth. (In chunked mode
            a requeue resume allocates lazily, chunk by chunk; the same
            figure then acts as the admission watermark so the resume
            cannot start into guaranteed starvation.)"""
            if rec.swapped:
                nbp, pos = swap.n_pages(rec.rid), swap.pos(rec.rid)
            elif not rec.toks:          # mid-prefill victim: whole prompt
                L = len(rec.req.tokens)
                return math.ceil(L / ps) + (
                    1 if rec.req.gen > 1 and L % ps == 0 else 0)
            else:
                pos = len(rec.req.tokens) + len(rec.toks) - 1
                nbp = math.ceil(pos / ps)
            return nbp + (1 if pos // ps >= nbp else 0)

        def match_prefix(tokens):
            """Longest usable cached prefix for a prompt. Returns None
            (miss) or (T, shared, cow_src, scales): prompt positions
            [0, T) come from the cache (T a segment boundary, so the
            tail job resumes legally at T), `shared` are the whole pages
            adopted for blocks [0, len(shared)), and `cow_src` names the
            donor page to copy-on-write for the next block when T is
            mid-page (a full-prompt match: at least the last segment
            re-runs to produce the first output token, and its writes
            must land in a private copy, never a shared page)."""
            L = len(tokens)
            M, pages, scales = index.match(tokens)
            if M <= 0:
                return None
            # a full-prompt match still needs logits at position L-1:
            # re-run the last segment (the packer resumes at segment
            # boundaries only), attending to the cached pages below it
            T = ((L - 1) // sched.seg) * sched.seg if M >= L else M
            K = T // ps                 # whole shared pages adopted
            if K < self.prefix_min_pages:
                return None
            cow_src = pages[K] if T % ps else None
            return T, list(pages[:K]), cow_src, scales

        def register_prefix(s: int, rid: int):
            """Index a freshly prefilled prompt's whole-quantum prefix.
            Called at chunked-prefill completion: every page below the
            registered boundary is fully written and never written again
            (decode writes land at positions >= the prompt length), and
            the slot's scales are frozen. Re-registration after a resume
            or of a shared prefix is a no-op for segments already
            indexed (first donor wins)."""
            toks = requests[rid].tokens
            reg = (len(toks) // self._quantum) * self._quantum
            if reg <= 0:
                return
            pages_reg = [int(p) for p in host_bt[s, :reg // ps]]
            scales_reg = [(c.k_scale[:, s], c.v_scale[:, s])
                          for c in caches]
            index.insert(toks[:reg], pages_reg, scales_reg)

        def check_page_accounting():
            owned = [p for st in slots if st is not None for p in st.pages]
            mult: Dict[int, int] = {}
            for p in owned:
                mult[p] = mult.get(p, 0) + 1
            assert mult == allocator.refcounts, \
                "page refcounts disagree with block-table references"
            assert allocator.free_count + len(mult) == self.n_pages, \
                "free-list conservation violated (pages leaked)"
            allocator.assert_consistent()
            for s, st in enumerate(slots):
                if st is None:
                    continue
                row = host_bt[s][host_bt[s] >= 0]
                assert list(row) == st.pages, \
                    f"slot {s}: block table disagrees with owned pages"
                assert 0 <= host_pos[s] <= len(st.pages) * ps, \
                    f"slot {s}: position outside its allocated blocks"
                # a sequence never writes into a shared page: its next
                # write position, when it lands mid-page, must target an
                # exclusively-owned page (block boundaries target a page
                # not yet allocated or freshly allocated at refcount 1)
                blk = host_pos[s] // ps
                if host_pos[s] % ps and blk < NB and host_bt[s, blk] >= 0:
                    assert allocator.refcount(int(host_bt[s, blk])) == 1, \
                        f"slot {s}: next write targets shared page " \
                        f"{int(host_bt[s, blk])}"

        def q_peek():
            """Earliest pending (arrive_at, rid, req) by heap order,
            dropping lazily-cancelled entries; None when empty."""
            while queue and queue[0][1] in cancelled:
                heapq.heappop(queue)
            return queue[0] if queue else None

        def arrived():
            head = q_peek()
            return head is not None and head[0] <= clock

        t_run0 = time.perf_counter()
        acc["t0"] = t_run0
        self._t_origin = t_run0
        sp.run_begin(t_run0)
        if sp.on:
            for rid in sorted(requests):
                sp.submitted(rid, t_run0)
        self._run_live.set()
        while True:
            if wall:
                clock = time.perf_counter() - t_run0
            it_t0 = time.perf_counter() if timed else 0.0
            drain_mailboxes()
            # ---- evict finished sequences: pages back to the free list
            # (before the stop check: a shutdown right after a final
            # token must still release that sequence's pages)
            while (fin := finished_slot()) is not None:
                sp.finished(slots[fin].rid)
                evict(fin)
            if self._stop_flag and not drain:
                break                           # serve-forever shutdown
            t_admit0 = time.perf_counter() if timed else 0.0

            # ---- resume preempted sequences, then admit new arrivals.
            # Strict resume-before-admit: while a preempted sequence
            # waits, nothing younger is admitted past it.
            while None in slots and (resume_q or arrived()):
                s = slots.index(None)
                if resume_q:
                    rec = resume_q[0]
                    if allocator.free_count < resume_need(rec) \
                            + growth_debt() + prefill_debt():
                        break                   # wait for evictions
                    resume_q.pop(0)
                    resume(s, rec)
                    continue
                _, rid, req = q_peek()
                L = len(req.tokens)
                nbp = math.ceil(L / ps)
                # shared-prefix match (chunked + --prefix-cache): blocks
                # covered by adopted pages need no fresh allocation, so
                # the watermark charges only the unshared tail. Matching
                # takes no references — safe to re-match next iteration
                # if the watermark defers admission.
                hit = match_prefix(req.tokens) if index is not None \
                    else None
                nbp_fresh = nbp - (len(hit[1]) if hit is not None else 0)
                # watermark: fresh prompt pages, plus this request's own
                # first growth page when its prompt ends on a block
                # boundary, plus the running sequences' growth debt, plus
                # the pages partially-prefilled sequences still need
                own = 1 if (req.gen > 1 and L % ps == 0) else 0
                if allocator.free_count < nbp_fresh + own + growth_debt() \
                        + prefill_debt():
                    if not any(slots):
                        allocator.alloc(nbp_fresh + own)  # PoolExhausted
                    break                       # wait for evictions
                heapq.heappop(queue)
                if sched is not None:
                    # chunked admission is a host-side bind only: pages
                    # are granted chunk by chunk and the prompt streams
                    # through the shared chunk program interleaved with
                    # decode steps — a long prompt no longer stalls the
                    # loop for its whole length
                    if hit is not None:
                        T, shared, cow_src, sc = hit
                        allocator.share(shared)
                        hit_pages = list(shared)
                        if cow_src is not None:
                            # the tail resumes mid-page: duplicate the
                            # donor's boundary page so the tail chunk
                            # rewrites a private copy (rows below T stay
                            # bit-identical; rows at/above are overwritten)
                            (pg,) = allocator.alloc(1)
                            caches = [self._copy_page(
                                c, jnp.int32(cow_src), jnp.int32(pg))
                                for c in caches]
                            hit_pages.append(pg)
                            c_cow.inc()
                        # donor scales must be installed before the tail
                        # chunk runs: the tail carries no first-segment
                        # tokens, so nothing else would calibrate them
                        caches = [self._adopt_scales(
                            c, jnp.int32(s), k_sc, v_sc)
                            for c, (k_sc, v_sc) in zip(caches, sc)]
                        bind_prefilling(s, rid, req, start=T,
                                        pages=hit_pages)
                        c_phit.inc()
                        c_ptok.inc(T)
                        c_pshared.inc(len(shared))
                        sp.admitted(rid, mode="chunked")
                        if progress:
                            print(f"[admit] rid={rid} slot={s} prompt={L} "
                                  f"prefix hit: {T} tokens / "
                                  f"{len(shared)} shared pages"
                                  + (" + CoW" if cow_src is not None
                                     else ""))
                        continue
                    if index is not None:
                        c_pmiss.inc()
                    bind_prefilling(s, rid, req)
                    sp.admitted(rid, mode="chunked")
                    if progress:
                        print(f"[admit] rid={rid} slot={s} prompt={L} "
                              f"(chunked prefill queued)")
                    continue
                t0 = time.perf_counter()
                sp.admitted(rid, t0, mode="sequential")
                pages = allocator.alloc(nbp)
                tmp = self.model.init_cache(1, nbp * ps, cache_cfg=self.cc)
                tok0, tmp = self._prefill(
                    params, {"tokens": jnp.asarray(req.tokens)[None]}, tmp)
                pages_dev = jnp.asarray(pages, jnp.int32)
                caches = [self._adopt(c, t_g, jnp.int32(s), pages_dev)
                          for c, t_g in zip(caches, tmp)]
                first_tok[rid] = tok0[0, 0]
                bind_slot(s, rid, req, pages, pos=len(req.tokens),
                          generated=1, last_tok=tok0[0, 0])
                # drain the async prefill dispatch before reading the
                # clock, so its device time lands in t_prefill rather
                # than decode_s (the contiguous engine blocks the same
                # way before timing). Blocking on tok0 — not on the
                # adopted caches — keeps pending decode steps of *other*
                # slots out of t_prefill; the adoption copies themselves
                # are small and stay with decode_s.
                jax.block_until_ready(tok0)
                t1 = time.perf_counter()
                c_t_prefill.inc(t1 - t0)
                sp.first_token(rid, t1)
                if emit is not None:
                    tk0 = int(jax.device_get(tok0[0, 0]))
                    emitted[rid] = [tk0]
                    emit(rid, tk0, req.gen <= 1, time.perf_counter())
                if progress:
                    print(f"[admit] rid={rid} slot={s} prompt="
                          f"{len(req.tokens)} pages={pages}")
            g_pages.set(allocator.used_count)
            g_peak.set_max(allocator.used_count)
            t_prefill0 = time.perf_counter() if timed else 0.0

            # ---- chunked prefill: run fixed-shape chunks of the packed
            # prompt stream (if any prompts are pending), then fall
            # through to the decode step — admission cost is amortized
            # across the decode loop instead of blocking it. The
            # chunks:steps ratio is metered by `prefill_priority` as a
            # credit accumulator: each iteration banks that many chunk
            # credits (capped at max(priority, 1) so idle iterations
            # cannot stockpile a burst) and each whole credit runs one
            # chunk. 1.0 keeps the one-chunk-per-step cadence; 2.0 runs
            # two chunks per decode step (faster TTFT, slower ITL); 0.5
            # runs one chunk every other step (decode-favouring).
            chunk_ran = False
            chunk_gated = False
            if sched is not None and sched.pending:
                def prefill_budget() -> int:
                    """Pages prefill may take right now: the free count
                    minus the decode growth-debt watermark — a prefill
                    chunk may not take the page a running sequence needs
                    for its very next write (that would force a
                    preemption in the same iteration)."""
                    return max(allocator.free_count - growth_debt(), 0)

                def grant(slot_want: int, blocks: List[int]) -> None:
                    """Allocate pages for `blocks` (ascending logical
                    blocks) of a mid-prefill slot; the scheduler sized
                    the request to the budget, so it always succeeds."""
                    for b in blocks:
                        (pg,) = allocator.alloc(1)
                        slots[slot_want].pages.append(pg)
                        host_bt[slot_want, b] = pg

                chunk_credit = min(chunk_credit + self.prefill_priority,
                                   max(self.prefill_priority, 1.0))
                chunk_gated = chunk_credit < 1.0
                while chunk_credit >= 1.0 and sched.pending:
                    plan = sched.plan(prefill_budget, grant, host_bt)
                    if plan is None:
                        break
                    chunk_credit -= 1.0
                    bt_dev = self._replicated(jnp.asarray(host_bt, jnp.int32))
                    caches = [dataclasses.replace(
                        c, block_table=jnp.broadcast_to(
                            bt_dev, c.block_table.shape))
                        for c in caches]
                    spa = np.full((S,), -1, np.int64)
                    for s2 in range(S):
                        if slots[s2] is not None and not sched.has(s2):
                            spa[s2] = host_pos[s2]
                    for s2, _, _ in plan.completed:
                        spa[s2] = host_pos[s2] + plan.advanced[s2]
                    t0 = time.perf_counter()
                    am, caches = sched.run(params, caches, plan, spa)
                    jax.block_until_ready(am)
                    t1 = time.perf_counter()
                    c_t_prefill.inc(t1 - t0)
                    c_chunks.inc()
                    chunk_ran = True
                    if sp.on:
                        for s2, n in plan.advanced.items():
                            sp.chunk(slots[s2].rid, t0, t1, tokens=n)
                    am_np = jax.device_get(am) if emit is not None else None
                    t_am = time.perf_counter()
                    for s2, n in plan.advanced.items():
                        host_pos[s2] += n
                    for s2, rid2, expect in plan.completed:
                        t_c = am[s2]
                        if expect is not None:
                            deferred_checks.append(t_c)
                            deferred_expect.append((
                                expect,
                                "chunked re-prefill diverged from the "
                                "recorded first token — greedy decode "
                                "is no longer deterministic"))
                            sp.decoding(rid2, t_am)
                        else:
                            first_tok[rid2] = t_c
                            slots[s2].generated = 1
                            sp.first_token(rid2, t_am)
                            if emit is not None:
                                tk0 = int(am_np[s2])
                                emitted[rid2] = [tk0]
                                emit(rid2, tk0,
                                     slots[s2].target <= 1, t_am)
                        tok = tok.at[s2, 0].set(t_c)
                        if index is not None:
                            register_prefix(s2, rid2)
                        if progress:
                            print(f"[prefill] rid={rid2} slot={s2} "
                                  f"complete at pos {host_pos[s2]}")
                    g_pages.set(allocator.used_count)
                    g_peak.set_max(allocator.used_count)

            if not any(slots):
                if resume_q or arrived():
                    continue                    # a resume/admit now fits
                head = q_peek()
                if head is not None:
                    # idle until the *earliest* pending arrival (the heap
                    # head) — never past it, so staggered arrivals admit
                    # in (arrive_at, rid) order even when a later-indexed
                    # request carries the earlier timestamp
                    if wall:
                        self._wake.wait(timeout=max(head[0] - clock, 0.0))
                    else:
                        clock = max(clock, head[0])
                    continue
                if not drain:
                    # serve-forever: sleep until traffic or stop. The
                    # timeout bounds the wait so a stop that raced the
                    # wake-clear above is still honoured promptly.
                    self._wake.wait(timeout=0.05)
                    continue
                break                           # drained
            t_decode0 = time.perf_counter() if timed else 0.0

            # ---- allocate the page the next token will be written into
            # (finished slots were evicted above and never reach here).
            # Allocation is transactional per page: a page leaves the
            # free list only together with its slot-ownership record, so
            # a PoolExhausted mid-step (no victim left) cannot strand
            # pages — asserted by check_page_accounting every iteration.
            dirty = False
            for s in range(S):
                if slots[s] is None or slots[s].generated >= slots[s].target:
                    continue
                if sched is not None and sched.has(s):
                    continue        # mid-prefill: pages granted per chunk
                blk = host_pos[s] // ps
                if host_bt[s, blk] >= 0:
                    continue
                while allocator.free_count < 1:
                    # a finished slot is a free win: evict it instead of
                    # paying a swap round trip / replay for work that
                    # will emit nothing. (The admission watermark keeps
                    # this branch from triggering today — admissions may
                    # not drain the pool below the growth debt — but the
                    # ordering "reclaim finished, then preempt" is a
                    # liveness guarantee, not an optimization.)
                    fin = finished_slot()
                    if fin is not None:
                        sp.finished(slots[fin].rid)
                        evict(fin)
                        dirty = True
                        continue
                    victim = select_victim(exclude=(s,))
                    if victim is None:
                        check_page_accounting()
                        raise paging.PoolExhausted(
                            f"page pool exhausted growing slot {s} and no "
                            f"victim left to preempt — grow --n-pages or "
                            f"enable --preempt requeue|swap"
                            if self.policy is None else
                            f"page pool exhausted growing slot {s}: every "
                            f"other sequence is already preempted")
                    preempt(victim)
                    dirty = True
                (pg,) = allocator.alloc(1)
                slots[s].pages.append(pg)
                host_bt[s, blk] = pg
                dirty = True
            g_pages.set(allocator.used_count)
            g_peak.set_max(allocator.used_count)
            if dirty:
                bt_dev = self._replicated(jnp.asarray(host_bt, jnp.int32))
                caches = [dataclasses.replace(
                    c, block_table=jnp.broadcast_to(
                        bt_dev, c.block_table.shape))
                    for c in caches]
            check_page_accounting()

            # ---- one traced decode step over every slot. Slots that just
            # hit their target still ride along (their masked write lands
            # in their own pages, freed at eviction) but emit no token.
            # Mid-prefill slots ride along inactive (device seq_pos -1:
            # trash write, masked attention, no advance); replaying slots
            # (chunked requeue resume) consume their recorded tokens
            # teacher-forced — the step writes their K/V, the emitted
            # token is discarded (it is already recorded).
            prefilling = tuple(s for s in range(S)
                               if sched is not None and sched.has(s))
            replaying = tuple(s for s in range(S)
                              if slots[s] is not None and slots[s].replay
                              and s not in prefilling)
            active = tuple((s, slots[s].rid) for s in range(S)
                           if slots[s] is not None
                           and slots[s].generated < slots[s].target
                           and s not in prefilling and s not in replaying)
            if not active and not replaying:
                if chunk_gated and sched is not None and sched.pending:
                    # nothing to decode and the only pending work is a
                    # credit-gated prefill chunk: skipping it would spin
                    # forever (and the stalled-prefill branch below would
                    # wrongly preempt). Force a whole credit — priority
                    # metering trades prefill against *decode* work, and
                    # there is none to favour.
                    chunk_credit = 1.0
                    continue
                if sched is not None and sched.pending and not chunk_ran:
                    # every live slot is a stalled prefill: no decode
                    # step can run and no chunk could take a page.
                    # Reclaim by preempting a victim (policy permitting)
                    # so the oldest job progresses next iteration.
                    first_slot = sched.jobs[0].slot
                    victim = select_victim(exclude=(first_slot,))
                    if victim is None:
                        check_page_accounting()
                        raise paging.PoolExhausted(
                            f"page pool exhausted mid-prefill of slot "
                            f"{first_slot} and no victim left to preempt "
                            f"— grow --n-pages or enable --preempt "
                            f"requeue|swap")
                    preempt(victim)
                continue                        # every slot done: evict
            if trace_hook is not None or sp.on:
                snap = self._snapshot(
                    n_steps, allocator, slots, host_bt, host_pos, caches,
                    [e for e in queue if e[1] not in cancelled],
                    resume_q, swap, prefilling=prefilling,
                    replaying=replaying,
                    prefix=prefix_stats() if index is not None else None)
                if trace_hook is not None:
                    trace_hook(snap)
                # the tracer's counter tracks ride the same snapshot
                # point (pool occupancy + load, rendered as Perfetto
                # counter lanes)
                sp.snapshot({"pages_in_use": allocator.used_count,
                             "free_pages": allocator.free_count,
                             "active": len(active),
                             "queued": len(snap["queued"]),
                             "swapped": len(snap["swapped_rids"])})
            pos_dev = caches[0].seq_pos[0]      # [S]; host_pos for active
            tok, caches = self._step(params, tok, caches, pos_dev)
            n_steps += 1
            c_steps.inc()
            c_tokens.inc(len(active))
            if not wall:
                clock += 1
            if emit is None:
                # batch mode: keep the device token columns alive; the
                # post-loop assembly fetches them all in one device_get
                history.append((active, tok))
                toks_np = None
            else:
                # streaming mode: one batched fetch per step (the only
                # per-step sync), fanned out host-side — no history, so
                # a serve-forever loop accumulates no device garbage
                toks_np = jax.device_get(tok)
            t_step = time.perf_counter()
            for s, _ in active:
                slots[s].generated += 1
                host_pos[s] += 1
            if emit is not None:
                for s, rid_a in active:
                    tk = int(toks_np[s, 0])
                    emitted[rid_a].append(tk)
                    emit(rid_a, tk,
                         slots[s].generated >= slots[s].target, t_step)
            for s in replaying:
                host_pos[s] += 1
                tok = tok.at[s, 0].set(slots[s].replay.pop(0))
                c_replay.inc()
            if sp.on and emit is not None:
                # per-token instants ride the streaming path's existing
                # host stamp (one batched device_get per step — reading
                # token values for batch-mode instants would add a sync)
                for _, rid_a in active:
                    sp.token(rid_a, t_step)
            if timed:
                t_it1 = time.perf_counter()
                g_active.set(len(active))
                g_queued.set(sum(1 for e in queue
                                 if e[1] not in cancelled))
                h_retire.observe(t_admit0 - it_t0)
                h_admit.observe(t_prefill0 - t_admit0)
                h_prefill.observe(t_decode0 - t_prefill0)
                h_decode.observe(t_it1 - t_decode0)
                sp.step(it_t0, t_it1,
                        phases=(("retire", it_t0, t_admit0),
                                ("admit", t_admit0, t_prefill0),
                                ("prefill", t_prefill0, t_decode0),
                                ("decode", t_decode0, t_it1)),
                        active=len(active))

        jax.block_until_ready(tok)
        t_total = time.perf_counter() - acc["t0"]
        sp.run_end()

        # ---- verify the deferred replay-divergence checks (one fetch)
        if deferred_checks:
            got = jax.device_get(jnp.stack(deferred_checks))
            for g, (want, msg) in zip(got.tolist(), deferred_expect):
                assert g == want, msg

        # ---- assemble per-request token streams (single device fetch;
        # a streaming run already holds every token host-side)
        if emit is not None:
            results = {rid: np.asarray(t, np.int32)
                       for rid, t in emitted.items()}
        else:
            outputs: Dict[int, List[int]] = {
                rid: [int(jax.device_get(t))]
                for rid, t in first_tok.items()}
            if history:
                toks_np = jax.device_get(
                    jnp.concatenate([t for _, t in history], axis=1))
                for i_h, (act_h, _) in enumerate(history):
                    for s_h, rid_h in act_h:
                        outputs[rid_h].append(int(toks_np[s_h, i_h]))
            results = {rid: np.asarray(t, np.int32)
                       for rid, t in outputs.items()}
        if drain:
            # every non-cancelled request ran to completion (a stopped
            # serve-forever loop legitimately returns partial streams)
            for rid, req in requests.items():
                if rid in cancelled:
                    continue
                assert len(results[rid]) == req.gen, \
                    (rid, len(results[rid]))

        # every stats entry below is a registry read (or a pure config
        # echo) — the back-compat parity test in tests/test_obs.py
        # asserts this key set and value equality against the registry
        prefill_s = c_t_prefill.value()
        resume_s = c_t_resume.value()
        decode_s = max(t_total - prefill_s - resume_s, 1e-9)
        pool_slots = self.n_pages * ps
        total_tokens = sum(len(r.tokens) + r.gen - 1
                           for r in requests.values())
        pstats = prefix_stats()
        c_swap_bytes = reg.counter("swap_bytes_total",
                                   labelnames=("dir",))
        stats = {
            "prefill_s": prefill_s,
            "prefill_mode": self.prefill_mode,
            "prefill_priority": self.prefill_priority,
            "prefill_chunks": int(c_chunks.value()),
            "prefill_compile_count":
                sched.compile_count if sched is not None else None,
            "run_s": t_total,
            "resume_s": resume_s,
            "decode_s": decode_s,
            "decode_steps": int(c_steps.value()),
            "decode_tok_s": c_tokens.value() / decode_s,
            "clock_mode": clock_mode,
            "pool_pages": self.n_pages,
            "page_size": ps,
            "pool_slots": pool_slots,
            "peak_pages_used": int(g_peak.value()),
            "peak_pool_utilization":
                g_peak.value() / max(self.n_pages, 1),
            "total_tokens_served": total_tokens,
            "cancelled": int(c_cancel.value()),
            "preemptions": int(c_pre_req.value() + c_pre_swap.value()),
            "preempt_requeue": int(c_pre_req.value()),
            "preempt_swap": int(c_pre_swap.value()),
            "resumes": int(c_resumes.value()),
            "replay_steps": int(c_replay.value()),
            "swap_bytes_out": int(c_swap_bytes.value(dir="out")),
            "swap_bytes_in": int(c_swap_bytes.value(dir="in")),
            "swap_peak_bytes":
                int(reg.gauge("swap_peak_bytes").value()),
            "prefix_cache": self.prefix_cache,
            "prefix_hits": pstats["prefix_hits"],
            "prefix_misses": pstats["prefix_misses"],
            "prefix_hit_rate": pstats["prefix_hits"] / max(
                pstats["prefix_hits"] + pstats["prefix_misses"], 1),
            "prefix_hit_tokens": pstats["prefix_hit_tokens"],
            "prefix_shared_pages": pstats["prefix_shared_pages"],
            "cow_copies": pstats["cow_copies"],
            "swap_refusals": pstats["swap_refusals"],
            "cache_bytes_per_value":
                cache_mod.bytes_per_value(self.cc),
            "cache_total_bytes":
                paging.modeled_pool_bytes(caches)["total_bytes"],
            "tp": self.tp,
            "pool_bytes_per_device":
                paging.modeled_pool_bytes_per_device(caches)["total_bytes"],
        }
        return results, stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparq", choices=list(SPARQ_PRESETS), default="5opt")
    ap.add_argument("--kv-cache", choices=("fp32", "bf16", "sparq"),
                    default="fp32", help="KV-cache storage layout")
    ap.add_argument("--impl", choices=("reference", "pallas", "auto"),
                    default="reference",
                    help="kernel impl for quantized matmuls + cache codec")
    ap.add_argument("--engine", choices=("scan", "paged"), default="scan",
                    help="scan: one traced lax.scan over a uniform batch; "
                         "paged: continuous batching over the page pool")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged engine: cache slots per page")
    ap.add_argument("--n-pages", type=int, default=64,
                    help="paged engine: pages in the shared pool")
    ap.add_argument("--max-active", type=int, default=0,
                    help="paged engine: concurrent sequence slots "
                         "(default: --batch)")
    ap.add_argument("--prefill", choices=("sequential", "chunked"),
                    default="sequential",
                    help="paged engine admission: sequential (one prompt "
                         "at a time, shape-specialized jit per length) or "
                         "chunked (ragged prompts packed into a fixed-"
                         "shape token stream, one jitted chunk program "
                         "for every length, §5.1 pages written directly)")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="chunked prefill: stream tokens per chunk")
    ap.add_argument("--chunk-align", type=int, default=8,
                    help="chunked prefill: query-tile alignment of each "
                         "sequence's run inside the stream")
    ap.add_argument("--chunk-seg", type=int, default=0,
                    help="chunked prefill: segment quantum (prompt split "
                         "granularity; 0 = chunk size). Prompts up to one "
                         "segment admit bit-identically to sequential; "
                         "longer prompts attend earlier segments through "
                         "their packed pages")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged engine: reuse packed pages across requests "
                         "with a shared prompt prefix (radix index over "
                         "whole-page, whole-segment prefixes; refcounted "
                         "pages; copy-on-write at the tail boundary). "
                         "Requires --prefill chunked; greedy tokens are "
                         "bit-identical with the flag off")
    ap.add_argument("--prefix-min-pages", type=int, default=1,
                    help="prefix cache: minimum whole shared pages an "
                         "admission must match to take the hit path "
                         "(shorter matches prefill from scratch)")
    ap.add_argument("--prefill-priority", type=float, default=1.0,
                    help="paged engine, chunked prefill: prefill chunks "
                         "admitted per decode-loop iteration (fractional "
                         "< 1 throttles prefill to favor decode ITL; "
                         "> 1 lets several chunks run back-to-back to "
                         "favor TTFT)")
    ap.add_argument("--serve", choices=("sync", "async"), default="sync",
                    help="sync: one blocking engine.run over the batch; "
                         "async: the asyncio streaming front-end "
                         "(launch.frontend) replays a timed arrival "
                         "trace through a serve-forever engine loop and "
                         "reports TTFT/ITL percentiles (paged engine "
                         "only)")
    ap.add_argument("--arrival-trace", choices=("none", "poisson", "bursty"),
                    default="none",
                    help="async serving: arrival process for the replay "
                         "(none: every request arrives at t=0)")
    ap.add_argument("--arrival-rate", type=float, default=16.0,
                    help="async serving: offered load in requests/s for "
                         "--arrival-trace poisson|bursty")
    ap.add_argument("--preempt", choices=("off", "requeue", "swap", "auto"),
                    default="off",
                    help="paged engine: on decode-time pool exhaustion, "
                         "preempt victims — requeue (drop pages, replay on "
                         "resume), swap (packed pages to host, verbatim "
                         "restore), or auto (per-victim cost model: replay "
                         "FLOPs vs swap bytes); off raises PoolExhausted")
    ap.add_argument("--victim", choices=("last_joined", "fewest_pages"),
                    default="last_joined",
                    help="paged engine: preemption victim selection")
    ap.add_argument("--tp", type=int, default=1,
                    help="paged engine: tensor-parallel degree over a "
                         "(\"data\",\"model\") host mesh (launch.mesh."
                         "make_tp_mesh). Pools and attention heads shard "
                         "by GQA head group; greedy tokens are "
                         "bit-identical to --tp 1. Needs tp | n_kv_heads "
                         "and tp | device count (on CPU, force devices "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--oversubscribe", type=float, default=0.0,
                    metavar="FRAC",
                    help="paged engine: shrink the pool to FRAC of the "
                         "batch's uncontended working set (forces "
                         "preemption; requires --preempt requeue|swap, "
                         "overrides --n-pages)")
    ap.add_argument("--metrics-dump", metavar="PATH", default=None,
                    help="paged engine: write the telemetry registry as "
                         "a Prometheus text-exposition dump after the "
                         "run (docs/observability.md)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="paged engine: enable full span tracing and "
                         "write Chrome trace-event JSON after the run "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="async serving: serve GET /metrics (Prometheus "
                         "text exposition) from the event loop on this "
                         "port while the trace plays (0 = ephemeral)")
    ap.add_argument("--calibrate", type=int, default=2,
                    help="calibration batches (0 = dynamic scales)")
    ap.add_argument("--prequantize", action="store_true",
                    help="deploy int8 weight codes (offline quantization)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the untimed warmup pass (timings then "
                         "include XLA compilation)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    data = Batcher(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
        global_batch=args.batch, seed=args.seed, frontend=cfg.frontend,
        frontend_len=cfg.frontend_len, d_model=cfg.d_model))
    batch = data.global_batch(0)
    batch.pop("labels", None)

    scfg = SPARQ_PRESETS[args.sparq]
    ctx, scales = None, None
    if scfg is not None:
        scales = model.calibrate(params, data.calib_batches(args.calibrate)) \
            if args.calibrate else None
        ctx = QuantCtx(mode="quantized", cfg=scfg, impl=args.impl)
        if args.prequantize:
            from repro.models.quantize import quantize_params
            params = quantize_params(params, scfg.weight_bits)

    cache_cfg = make_cache_config(args.kv_cache, scfg, args.impl)
    print(f"arch={cfg.name} sparq={args.sparq} kv-cache={args.kv_cache} "
          f"impl={args.impl} engine={args.engine} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")

    if args.serve == "async" and args.engine != "paged":
        ap.error("--serve async streams from the paged engine's decode "
                 "loop; add --engine paged")
    if (args.metrics_dump or args.trace_out or args.metrics_port
            is not None) and args.engine != "paged":
        ap.error("--metrics-dump/--trace-out/--metrics-port read the "
                 "paged engine's telemetry registry; add --engine paged")
    if args.metrics_port is not None and args.serve != "async":
        ap.error("--metrics-port scrapes from the asyncio front-end; "
                 "add --serve async")
    if args.arrival_trace != "none" and args.serve != "async":
        ap.error("--arrival-trace replays through the async front-end; "
                 "add --serve async")
    if args.engine == "paged":
        if args.prefix_cache and args.prefill != "chunked":
            ap.error("--prefix-cache relies on the chunked path's "
                     "scheduling-invariant packed bytes; add "
                     "--prefill chunked")
        need = args.prompt_len + args.gen - 1
        max_seq = -(-need // args.page_size) * args.page_size
        pages_per_seq = max_seq // args.page_size
        n_pages = args.n_pages
        if args.oversubscribe:
            if args.preempt == "off":
                ap.error("--oversubscribe deliberately undersizes the "
                         "pool; pick --preempt requeue|swap so the engine "
                         "can evict victims instead of raising")
            n_pages = max(pages_per_seq,
                          math.ceil(args.oversubscribe * args.batch
                                    * pages_per_seq))
        policy = None if args.preempt == "off" else SchedulerPolicy(
            preempt=args.preempt, victim=args.victim)
        mesh = None
        if args.tp > 1:
            from repro.launch.mesh import make_tp_mesh
            mesh = make_tp_mesh(args.tp)
        telemetry = Telemetry.tracing() if args.trace_out else Telemetry()
        engine = ContinuousBatchingEngine(
            model, cache_cfg, ctx, scales,
            page_size=args.page_size, n_pages=n_pages,
            max_active=args.max_active or args.batch,
            max_seq_len=max_seq, policy=policy,
            prefill=args.prefill, chunk_size=args.chunk_size,
            chunk_align=args.chunk_align,
            chunk_seg=args.chunk_seg or None,
            prefix_cache=args.prefix_cache,
            prefix_min_pages=args.prefix_min_pages,
            prefill_priority=args.prefill_priority,
            mesh=mesh, telemetry=telemetry)

        def dump_telemetry():
            from repro.obs import export as obs_export
            if args.metrics_dump:
                obs_export.write_prometheus(engine.telemetry.registry,
                                            args.metrics_dump)
                print(f"metrics dump: {args.metrics_dump}")
            if args.trace_out:
                obs_export.write_trace(engine.telemetry.tracer,
                                       args.trace_out)
                print(f"trace (Perfetto/chrome://tracing): "
                      f"{args.trace_out}")

        reqs = [Request(np.asarray(batch["tokens"][b]), args.gen)
                for b in range(args.batch)]
        if args.serve == "async":
            from repro.launch import frontend
            ats = [0.0] * args.batch if args.arrival_trace == "none" \
                else frontend.arrival_times(
                    args.arrival_trace, args.batch, args.arrival_rate,
                    rng=np.random.default_rng(args.seed))
            trace = [(r.tokens, r.gen, at) for r, at in zip(reqs, ats)]
            warm = None if args.no_warmup else [(r.tokens, r.gen)
                                                for r in reqs]
            results, slo, stats = frontend.play_trace(
                engine, params, trace, warmup=warm,
                metrics_port=args.metrics_port)
            stats["slo"] = slo
            dump_telemetry()
            print(f"async {args.arrival_trace or 'none'} trace "
                  f"({len(trace)} requests): "
                  f"ttft p50 {slo['ttft']['p50_ms']:.1f} ms / "
                  f"p99 {slo['ttft']['p99_ms']:.1f} ms | "
                  f"itl p50 {slo['itl']['p50_ms']:.2f} ms / "
                  f"p99 {slo['itl']['p99_ms']:.2f} ms | decode "
                  f"{stats['decode_tok_s']:.1f} tok/s")
            print("sample:", results[0][:16])
            return stats
        if not args.no_warmup:
            engine.run(params, reqs)            # compile pass, untimed
        results, stats = engine.run(params, reqs)
        dump_telemetry()
        print(f"prefill {stats['prefill_s']*1e3:.0f} ms | decode "
              f"{stats['decode_tok_s']:.1f} tok/s | pool "
              f"{stats['peak_pages_used']}/{stats['pool_pages']} pages "
              f"({stats['page_size']} slots) peak, "
              f"{stats['cache_total_bytes']/1e6:.2f} MB modeled")
        if stats["tp"] > 1:
            print(f"tp={stats['tp']}: "
                  f"{stats['pool_bytes_per_device']/1e6:.2f} MB "
                  f"modeled pool per device")
        if args.prefix_cache:
            print(f"prefix-cache: {stats['prefix_hits']} hits / "
                  f"{stats['prefix_misses']} misses "
                  f"({stats['prefix_hit_rate']:.0%}), "
                  f"{stats['prefix_hit_tokens']} prompt tokens from "
                  f"cache, {stats['prefix_shared_pages']} pages shared, "
                  f"{stats['cow_copies']} CoW copies")
        if policy is not None:
            print(f"preempt={args.preempt} victim={args.victim}: "
                  f"{stats['preemptions']} preemptions, "
                  f"{stats['resumes']} resumes, "
                  f"{stats['replay_steps']} replay steps, "
                  f"swap {stats['swap_bytes_out']/1e6:.2f} MB out / "
                  f"{stats['swap_bytes_in']/1e6:.2f} MB in")
        print("sample:", results[0][:16])
        return stats

    toks, stats = serve(model, params, batch, args.gen, ctx, scales,
                        cache_cfg, warmup=not args.no_warmup)
    print(f"compile {stats['compile_s']:.1f} s | "
          f"prefill {stats['prefill_s']*1e3:.0f} ms | decode "
          f"{stats['decode_tok_s']:.1f} tok/s | cache "
          f"{stats['cache_bytes_per_value']:.4f} B/value data "
          f"(+{stats['cache_ctrl_bytes_per_value']:.4f} ctrl), "
          f"{stats['cache_total_bytes']/1e6:.2f} MB modeled")
    print("sample:", np.asarray(toks[0, :16]))
    return stats


if __name__ == "__main__":
    main()
