"""Serving driver: batched prefill + decode with SPARQ-quantized matmuls
(the paper's deployment scenario — PTQ'd activations over int8 weights).

Local demo:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 64 --gen 32 --sparq 5opt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced_config
from repro.core.sparq import SparqConfig
from repro.data.pipeline import Batcher, DataConfig
from repro.models.common import QuantCtx
from repro.models.model import Model

SPARQ_PRESETS = {
    "off": None,
    "a8w8": SparqConfig(enabled=False, signed=True),
    "5opt": SparqConfig.opt5(signed=True),
    "3opt": SparqConfig.opt3(signed=True),
    "2opt": SparqConfig.opt2(signed=True),
    "6opt": SparqConfig.opt6(signed=True),
    "7opt": SparqConfig.opt7(signed=True),
}


def serve(model: Model, params, batch, caches, gen: int,
          ctx: QuantCtx | None, scales_groups=None):
    """Greedy batched generation. Returns (tokens [B, gen], stats)."""
    prefill = jax.jit(lambda p, b, c: model.prefill(
        p, b, c, ctx=ctx, scales_groups=scales_groups))
    decode = jax.jit(lambda p, t, c, pos: model.decode_step(
        p, t, c, pos, ctx=ctx, scales_groups=scales_groups),
        static_argnums=())

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    pos0 = batch["tokens"].shape[1] + \
        (model.cfg.frontend_len if model.cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    B = batch["tokens"].shape[0]
    return jnp.concatenate(out, 1), {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": B * max(gen - 1, 1) / max(t_decode, 1e-9),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparq", choices=list(SPARQ_PRESETS), default="5opt")
    ap.add_argument("--calibrate", type=int, default=2,
                    help="calibration batches (0 = dynamic scales)")
    ap.add_argument("--prequantize", action="store_true",
                    help="deploy int8 weight codes (offline quantization)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    data = Batcher(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
        global_batch=args.batch, seed=args.seed, frontend=cfg.frontend,
        frontend_len=cfg.frontend_len, d_model=cfg.d_model))
    batch = data.global_batch(0)
    batch.pop("labels", None)

    scfg = SPARQ_PRESETS[args.sparq]
    ctx, scales = None, None
    if scfg is not None:
        scales = model.calibrate(params, data.calib_batches(args.calibrate)) \
            if args.calibrate else None
        ctx = QuantCtx(mode="quantized", cfg=scfg, impl="reference")
        if args.prequantize:
            from repro.models.quantize import quantize_params
            params = quantize_params(params, scfg.weight_bits)

    caches = model.init_cache(args.batch, args.prompt_len + args.gen + 8,
                              dtype=jnp.float32)
    toks, stats = serve(model, params, batch, caches, args.gen, ctx, scales)
    print(f"arch={cfg.name} sparq={args.sparq} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {stats['prefill_s']*1e3:.0f} ms | decode "
          f"{stats['decode_tok_s']:.1f} tok/s")
    print("sample:", np.asarray(toks[0, :16]))
    return stats


if __name__ == "__main__":
    main()
