"""Serving driver: batched prefill + scan-based greedy decode with SPARQ
quantization at both matmuls (the paper's compute path) and the KV cache
(the §5.1 packed storage path — the memory-bound decode workload).

The decode loop is a `DecodeEngine`: generation runs as a single traced
`jax.lax.scan` inside one jitted program — no per-step Python dispatch —
so tok/s measures the model, not the host loop. With the sparq layout the
decode step consumes the packed cache directly through the fused
flash-decode kernel (kernels.sparq_decode_attn); the full fp K/V planes
are never materialized. The cache layout is selected with
`--kv-cache {fp32,bf16,sparq}`; `--impl` picks the kernel implementation
(reference / Pallas / auto) for the quantized matmuls, the cache codec,
and the fused decode-attention kernel.

Local demo:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 64 --gen 32 --sparq 5opt \
      --kv-cache sparq
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced_config
from repro.core.sparq import SparqConfig
from repro.data.pipeline import Batcher, DataConfig
from repro.models import cache as cache_mod
from repro.models.cache import CacheConfig
from repro.models.common import QuantCtx
from repro.models.model import Model

SPARQ_PRESETS = {
    "off": None,
    "a8w8": SparqConfig(enabled=False, signed=True),
    "5opt": SparqConfig.opt5(signed=True),
    "3opt": SparqConfig.opt3(signed=True),
    "2opt": SparqConfig.opt2(signed=True),
    "6opt": SparqConfig.opt6(signed=True),
    "7opt": SparqConfig.opt7(signed=True),
}


def make_cache_config(layout: str, sparq: Optional[SparqConfig],
                      impl: str = "auto") -> CacheConfig:
    """`--kv-cache` flag -> CacheConfig. The sparq layout reuses the active
    SPARQ preset as its codec (signed; falls back to plain int8 when the
    preset is off/a8w8)."""
    if layout == "fp32":
        return CacheConfig.fp32()
    if layout == "bf16":
        return CacheConfig.bf16()
    if layout == "sparq":
        if sparq is None:   # preset off -> plain int8 storage, no trimming
            return CacheConfig(layout="sparq", impl=impl)
        return CacheConfig.sparq_cache(sparq, impl=impl)
    raise ValueError(layout)


class DecodeEngine:
    """Greedy batched generation as one traced program per phase:
    a jitted prefill and a jitted `lax.scan` over decode steps (the scan
    carries (token, caches, pos)). With the sparq layout the traced step
    quantizes on write and attends through the fused packed-cache decode
    kernel on read — the packed planes are streamed directly; no full-plane
    dequantize inside the decode loop."""

    def __init__(self, model: Model, cache_cfg: Optional[CacheConfig] = None,
                 ctx: Optional[QuantCtx] = None, scales_groups=None):
        self.model = model
        self.cache_cfg = cache_cfg or CacheConfig.fp32()
        self.ctx = ctx
        self.scales_groups = scales_groups
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn, static_argnames=("steps",))

    # ------------------------------------------------------------ traced
    def _prefill_fn(self, params, batch, caches):
        logits, caches = self.model.prefill(
            params, batch, caches, ctx=self.ctx,
            scales_groups=self.scales_groups)
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32), caches

    def _decode_fn(self, params, tok0, caches, pos0, *, steps: int):
        def step(carry, _):
            tok, caches, pos = carry
            logits, caches = self.model.decode_step(
                params, tok, caches, pos, ctx=self.ctx,
                scales_groups=self.scales_groups)
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            return (nxt, caches, pos + 1), nxt[:, 0]

        (_, caches, _), toks = jax.lax.scan(
            step, (tok0, caches, jnp.asarray(pos0, jnp.int32)), None,
            length=steps)
        return toks.swapaxes(0, 1), caches  # [B, steps]

    # ------------------------------------------------------------ public
    def init_cache(self, batch: int, max_len: int):
        return self.model.init_cache(batch, max_len,
                                     cache_cfg=self.cache_cfg)

    def generate(self, params, batch, gen: int, pad: int = 8,
                 max_len: Optional[int] = None, warmup: bool = True):
        """Returns (tokens [B, gen], stats).

        `max_len` caps the cache capacity (default: prompt + gen + pad
        slots). The capacity check runs host-side *before* tracing: the
        traced write path (`dynamic_update_slice_in_dim`) silently clamps
        its start index, so an overflowing decode would quietly overwrite
        the newest cache slots instead of erroring.

        `warmup` runs prefill + decode once untimed first, so prefill_s /
        decode_tok_s measure steady-state execution rather than XLA
        compilation; the first (compiling) pass is reported as compile_s.
        """
        B, prompt_len = batch["tokens"].shape
        pos0 = prompt_len + (self.model.cfg.frontend_len
                             if self.model.cfg.family == "vlm" else 0)
        max_len = max_len if max_len is not None else pos0 + gen + pad
        if pos0 + gen > max_len:
            raise ValueError(
                f"KV-cache overflow: prompt ({pos0} slots) + generation "
                f"({gen}) needs {pos0 + gen} cache slots but capacity is "
                f"{max_len}; the traced write path would silently clamp "
                f"and overwrite the newest entries")
        caches = self.init_cache(B, max_len)

        compile_s = 0.0
        if warmup:
            t0 = time.time()
            tok_w, caches_w = self._prefill(params, batch, caches)
            if gen > 1:
                rest_w, _ = self._decode(params, tok_w, caches_w, pos0,
                                         steps=gen - 1)
                jax.block_until_ready(rest_w)
            else:
                jax.block_until_ready(tok_w)
            compile_s = time.time() - t0

        t0 = time.time()
        tok0, caches = self._prefill(params, batch, caches)
        jax.block_until_ready(tok0)
        t_prefill = time.time() - t0

        t0 = time.time()
        if gen > 1:
            rest, caches = self._decode(params, tok0, caches, pos0,
                                        steps=gen - 1)
            jax.block_until_ready(rest)
            toks = jnp.concatenate([tok0, rest], axis=1)
        else:
            toks = tok0
        t_decode = time.time() - t0

        tally = cache_mod.modeled_cache_bytes(caches)
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "compile_s": compile_s,
            "decode_tok_s": (B * (gen - 1) / max(t_decode, 1e-9))
                            if gen > 1 else 0.0,
            "cache_bytes_per_value":
                cache_mod.bytes_per_value(self.cache_cfg),
            "cache_ctrl_bytes_per_value":
                cache_mod.ctrl_bytes_per_value(self.cache_cfg),
            "cache_data_bytes": tally["data_bytes"],
            "cache_total_bytes": tally["total_bytes"],
        }
        return toks, stats


def serve(model: Model, params, batch, gen: int,
          ctx: QuantCtx | None, scales_groups=None,
          cache_cfg: Optional[CacheConfig] = None, warmup: bool = True):
    """Greedy batched generation. Returns (tokens [B, gen], stats)."""
    engine = DecodeEngine(model, cache_cfg, ctx, scales_groups)
    return engine.generate(params, batch, gen, warmup=warmup)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparq", choices=list(SPARQ_PRESETS), default="5opt")
    ap.add_argument("--kv-cache", choices=("fp32", "bf16", "sparq"),
                    default="fp32", help="KV-cache storage layout")
    ap.add_argument("--impl", choices=("reference", "pallas", "auto"),
                    default="reference",
                    help="kernel impl for quantized matmuls + cache codec")
    ap.add_argument("--calibrate", type=int, default=2,
                    help="calibration batches (0 = dynamic scales)")
    ap.add_argument("--prequantize", action="store_true",
                    help="deploy int8 weight codes (offline quantization)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the untimed warmup pass (timings then "
                         "include XLA compilation)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    data = Batcher(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.prompt_len,
        global_batch=args.batch, seed=args.seed, frontend=cfg.frontend,
        frontend_len=cfg.frontend_len, d_model=cfg.d_model))
    batch = data.global_batch(0)
    batch.pop("labels", None)

    scfg = SPARQ_PRESETS[args.sparq]
    ctx, scales = None, None
    if scfg is not None:
        scales = model.calibrate(params, data.calib_batches(args.calibrate)) \
            if args.calibrate else None
        ctx = QuantCtx(mode="quantized", cfg=scfg, impl=args.impl)
        if args.prequantize:
            from repro.models.quantize import quantize_params
            params = quantize_params(params, scfg.weight_bits)

    cache_cfg = make_cache_config(args.kv_cache, scfg, args.impl)
    toks, stats = serve(model, params, batch, args.gen, ctx, scales,
                        cache_cfg, warmup=not args.no_warmup)
    print(f"arch={cfg.name} sparq={args.sparq} kv-cache={args.kv_cache} "
          f"impl={args.impl} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"compile {stats['compile_s']:.1f} s | "
          f"prefill {stats['prefill_s']*1e3:.0f} ms | decode "
          f"{stats['decode_tok_s']:.1f} tok/s | cache "
          f"{stats['cache_bytes_per_value']:.4f} B/value data "
          f"(+{stats['cache_ctrl_bytes_per_value']:.4f} ctrl), "
          f"{stats['cache_total_bytes']/1e6:.2f} MB modeled")
    print("sample:", np.asarray(toks[0, :16]))
    return stats


if __name__ == "__main__":
    main()
