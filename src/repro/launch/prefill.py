"""Chunked ragged prefill: one jitted program for every prompt.

The sequential admission path prefills one sequence at a time through a
shape-specialized jit — every unique prompt length triggers an XLA
retrace, and a long prompt stalls the decode loop for its whole length.
Under continuous batching this is the dominant admission cost at heavy
join rates (ROADMAP: "Batched ragged prefill").

`PrefillScheduler` amortizes it: pending prompts are packed into a
fixed-shape token *stream* with per-token (seq_id, pos) metadata and
processed in fixed-size chunks interleaved with decode steps. One jitted
chunk program (`Model.prefill_chunk`) covers every prompt length and
join pattern — the compile-count regression test pins its jit cache at
exactly one entry — and each chunk's K/V quantizes straight into
`PagedCacheStore` pages (`write_chunk`): no contiguous staging cache, no
`adopt_prefill` copy on the hot path.

Stream layout (C = chunk_size tokens, bq = query-tile alignment):

      tokens   [ p0 p1 p2 p3 | p4 p5 .. .. | q0 q1 q2 q3 | .. .. .. .. ]
      seq_id   [  2  2  2  2 |  2  2 -1 -1 |  0  0  0  0 | -1 -1 -1 -1 ]
      pos      [  8  9 10 11 | 12 13  0  0 |  0  1  2  3 |  0  0  0  0 ]
      tile_seq [      2      |      2      |      0      |     -1      ]

Each sequence's run is contiguous and padded to a bq boundary so one
query tile gathers exactly one block-table row (the Pallas kernel
scalar-prefetches `tile_seq`); -1 tokens/tiles are padding and fully
masked. A prompt longer than one chunk continues across chunks, and
`seq_pos_after` keeps the slot's device position at -1 (inactive for the
interleaved decode steps) until the last prompt token lands.

Prompts are split at fixed *segment* boundaries (`seg` tokens, default
the chunk size) and the packer only ever places whole segments (the
ragged final segment included) — never a partial one. Attention inside a
segment reads float K/V; attention across segments reads the already-
written packed pages (per-token `hist` boundary). The consequence is
the scheduling-invariance property the engine's exactness guarantees
lean on: a prompt's cache bytes and greedy tokens depend only on
(prompt, seg), not on join order, pool pressure, chunk packing, or
preemption — so a requeue-replay resume re-prefills to bit-identical
bytes, and prompts of at most `seg` tokens are bit-identical to the
sequential (whole-prompt, float-attention) admission path.

Page allocation stays with the engine (the scheduler's `plan` calls
back into an engine-provided `grant`), mirroring the division of labor
in models/paging.py: scheduling decisions happen host-side between
traced steps; the traced chunk only consumes an already-consistent
block table.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.paging import ChunkMeta

# host/device topology for the static analyzer (repro.analysis.host_lint;
# see docs/analysis.md). Pure literal — parsed with ast.literal_eval.
__analysis__ = {
    "traced": ("PrefillScheduler._chunk_fn",),
    "host_loop": ("PrefillScheduler.plan", "PrefillScheduler.run"),
    "device_returning": (),
    "device_params": ("PrefillScheduler.run.caches",),
}


@dataclasses.dataclass
class _Job:
    """One pending prompt: admitted to a slot, not yet fully prefilled."""
    slot: int
    rid: int
    tokens: np.ndarray
    done: int = 0                       # prompt tokens already written
    expect_tok0: Optional[int] = None   # resume: recorded first token

    @property
    def remaining(self) -> int:
        return len(self.tokens) - self.done


class ChunkPlan(NamedTuple):
    """Host-side description of one packed chunk (see module docstring)."""
    tokens: np.ndarray      # [C] int32 stream token ids (0 = padding)
    seq_id: np.ndarray      # [C] slot per token (-1 = padding)
    pos: np.ndarray         # [C] absolute position per token
    hist: np.ndarray        # [C] per-token history boundary (segment
                            #     start; packed pages below, float above)
    tile_seq: np.ndarray    # [C/bq] slot per query tile (-1 = padding)
    last_rows: np.ndarray   # [S] stream row of the slot's final prompt
                            #     token (-1: prefill incomplete)
    completed: List[Tuple[int, int, Optional[int]]]  # (slot, rid, expect)
    advanced: Dict[int, int]            # slot -> prompt tokens written


class PrefillScheduler:
    """Packs ragged pending prompts into fixed-shape chunks and runs them
    through one jitted chunk program.

    The engine admits a request by binding a slot and calling `add`; each
    engine iteration then calls `plan` (packing + page negotiation via
    the engine's `grant` callback) and `run` (the traced chunk). A job
    whose next page cannot be granted simply stalls until evictions or
    preemptions free pages — the engine handles liveness.
    """

    def __init__(self, model, ctx=None, scales_groups=None, *,
                 chunk_size: int = 32, align: int = 8, page_size: int,
                 n_slots: int, seg: Optional[int] = None, mesh=None,
                 telemetry=None):
        if chunk_size % align:
            raise ValueError(f"chunk_size {chunk_size} must be a multiple "
                             f"of the query-tile alignment {align}")
        seg = chunk_size if seg is None else seg
        if not 0 < seg <= chunk_size:
            raise ValueError(f"segment quantum {seg} must be in "
                             f"(0, chunk_size={chunk_size}] — a whole "
                             f"segment must fit one chunk")
        self.model = model
        self.ctx = ctx
        self.scales_groups = scales_groups
        self.C = chunk_size
        self.bq = align
        self.seg = seg
        self.ps = page_size
        self.S = n_slots
        # tensor parallelism: chunk metadata and the token stream are
        # global control state — placed replicated over the mesh so the
        # chunk program (whose pools are head-sharded) sees committed,
        # consistently-placed inputs (see docs/sharding.md). The
        # NamedSharding is built once here, outside the host loop.
        self.mesh = mesh
        self._rep_sharding = None if mesh is None else \
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        self.jobs: List[_Job] = []          # FIFO
        self.chunks_run = 0
        # chunk-stream utilization (repro.obs): non-pad fraction of each
        # chunk's C stream rows — low fill means admission is paying a
        # whole fixed-shape chunk for a sliver of prompt
        self._h_fill = None
        if telemetry is not None:
            self._h_fill = telemetry.registry.histogram(
                "prefill_chunk_fill_ratio",
                "non-pad fraction of each chunk's token stream",
                buckets=tuple(i / 10 for i in range(1, 11))).series()
        # ONE jitted program serves every chunk: all shapes are fixed by
        # (chunk_size, n_slots, pool geometry), so the jit cache holds a
        # single entry regardless of prompt lengths/join patterns —
        # asserted by the compile-count regression test via compile_count.
        self._chunk = jax.jit(self._chunk_fn, donate_argnums=(2,))

    # ------------------------------------------------------------ traced
    def _chunk_fn(self, params, toks, caches, meta, last_rows):
        return self.model.prefill_chunk(
            params, toks, caches, meta, last_rows,
            ctx=self.ctx, scales_groups=self.scales_groups)

    # ------------------------------------------------------------- state
    def reset(self) -> None:
        """Drop all jobs (a fresh engine run); keeps the jitted program."""
        self.jobs = []
        self.chunks_run = 0

    def add(self, slot: int, rid: int, tokens: np.ndarray,
            expect_tok0: Optional[int] = None, start: int = 0) -> None:
        """Queue a prompt for chunked prefill. `start` > 0 (shared-prefix
        admission) skips the prompt's first `start` tokens: their packed
        pages are already in the slot's block table (adopted from the
        prefix cache) and only the tail streams through the chunk
        program. `start` must sit on a segment boundary — the packer's
        history arithmetic (`hist = (pos // seg) * seg`) and the
        segment-atomic placement rule both assume `done` always is."""
        assert not self.has(slot), f"slot {slot} already mid-prefill"
        assert start % self.seg == 0, \
            f"prefill start {start} must be a multiple of seg {self.seg}"
        assert 0 <= start < len(tokens), (start, len(tokens))
        self.jobs.append(_Job(slot=slot, rid=rid,
                              tokens=np.asarray(tokens), done=start,
                              expect_tok0=expect_tok0))

    def has(self, slot: int) -> bool:
        return any(j.slot == slot for j in self.jobs)

    def job(self, slot: int) -> _Job:
        return next(j for j in self.jobs if j.slot == slot)

    def cancel(self, slot: int) -> None:
        """Drop a mid-prefill job (its slot was preempted)."""
        self.jobs = [j for j in self.jobs if j.slot != slot]

    @property
    def pending(self) -> bool:
        return bool(self.jobs)

    @property
    def pending_tokens(self) -> int:
        """Prompt tokens still to stream across every queued job — the
        prefill backlog depth (SLO snapshots and admission telemetry)."""
        return sum(j.remaining for j in self.jobs)

    @property
    def compile_count(self) -> int:
        """Number of traced chunk programs (the retrace regression guard)."""
        return self._chunk._cache_size()

    def pages_outstanding(self, slot: int, host_bt: np.ndarray) -> int:
        """Pages this mid-prefill slot still needs to finish its prompt —
        the engine's admission watermark charges these so new admissions
        cannot starve an in-flight prefill."""
        job = self.job(slot)
        last_blk = (len(job.tokens) - 1) // self.ps
        row = host_bt[slot]
        return sum(1 for b in range(last_blk + 1) if row[b] < 0)

    # -------------------------------------------------------------- plan
    def _seg_floor(self, job: _Job, n: int) -> int:
        """Largest segment-atomic token count <= n from job's position:
        whole segments, or everything that remains (the ragged final
        segment rides with the last whole one). job.done is always a
        segment boundary, so atomicity is per-job-local arithmetic."""
        if n >= job.remaining:
            return job.remaining
        return (n // self.seg) * self.seg

    def plan(self, budget: Callable[[], int],
             grant: Callable[[int, List[int]], None],
             host_bt: np.ndarray) -> Optional[ChunkPlan]:
        """Pack the next chunk, FIFO over pending jobs.

        `budget()` reports how many pages prefill may take right now (the
        engine's free count minus the decode growth-debt watermark);
        `grant(slot, blocks)` then allocates physical pages for exactly
        those (ascending) logical blocks of `slot` and updates the host
        block table. The run is shrunk segment-atomically to the budget
        *before* granting, so every granted page receives tokens in this
        very chunk — a page shortage can stall a job but never strand an
        allocated page. Mutates job progress (`done`) and removes
        completed jobs; returns None when nothing could be packed."""
        C, bq, ps = self.C, self.bq, self.ps
        used = 0
        runs: List[Tuple[_Job, int, int]] = []       # (job, n, at)
        for job in list(self.jobs):
            if used >= C:
                break
            n = self._seg_floor(job, C - used)
            first_blk = job.done // ps

            def missing(n_tok):
                last_blk = (job.done + n_tok - 1) // ps
                return [b for b in range(first_blk, last_blk + 1)
                        if host_bt[job.slot, b] < 0]

            while n > 0:
                need = missing(n)
                if len(need) <= budget():
                    break
                # shrink to the positions the affordable page prefix
                # covers, keeping whole segments only; need[budget()] is
                # the first block we cannot take
                n = self._seg_floor(job, need[budget()] * ps - job.done)
            if n <= 0:
                continue                             # stalled: no page
            need = missing(n)
            grant(job.slot, need)
            runs.append((job, n, used))
            used += -(-n // bq) * bq                 # align run to bq
        if not runs:
            return None

        tokens = np.zeros(C, np.int64)
        seq_id = np.full(C, -1, np.int64)
        pos = np.zeros(C, np.int64)
        hist = np.zeros(C, np.int64)
        tile_seq = np.full(C // bq, -1, np.int64)
        last_rows = np.full(self.S, -1, np.int64)
        completed: List[Tuple[int, int, Optional[int]]] = []
        advanced: Dict[int, int] = {}
        for job, n, at in runs:
            tokens[at:at + n] = job.tokens[job.done:job.done + n]
            seq_id[at:at + n] = job.slot
            p = np.arange(job.done, job.done + n)
            pos[at:at + n] = p
            hist[at:at + n] = (p // self.seg) * self.seg
            tile_seq[at // bq: (at + n + bq - 1) // bq] = job.slot
            advanced[job.slot] = n
            job.done += n
            if job.remaining == 0:
                last_rows[job.slot] = at + n - 1
                completed.append((job.slot, job.rid, job.expect_tok0))
                self.jobs.remove(job)
        return ChunkPlan(tokens=tokens, seq_id=seq_id, pos=pos, hist=hist,
                         tile_seq=tile_seq, last_rows=last_rows,
                         completed=completed, advanced=advanced)

    # --------------------------------------------------------------- run
    def run(self, params, caches, plan: ChunkPlan,
            seq_pos_after: np.ndarray):
        """Execute one planned chunk. Returns (tok0 [S] int32 device
        array — greedy token at each completing slot's last prompt row —
        , caches). The caches argument is donated (the pools are
        rewritten in place, like the engine's decode step)."""
        if self._rep_sharding is None:
            rep = lambda x: x
        else:
            rep = lambda x: jax.device_put(x, self._rep_sharding)
        meta = ChunkMeta(
            seq_id=rep(jnp.asarray(plan.seq_id, jnp.int32)),
            pos=rep(jnp.asarray(plan.pos, jnp.int32)),
            hist=rep(jnp.asarray(plan.hist, jnp.int32)),
            tile_seq=rep(jnp.asarray(plan.tile_seq, jnp.int32)),
            seq_pos_after=rep(jnp.asarray(seq_pos_after, jnp.int32)))
        self.chunks_run += 1
        if self._h_fill is not None:
            # plan arrays are host numpy: a pure host-side observation
            self._h_fill.observe(int((plan.seq_id >= 0).sum()) / self.C)
        return self._chunk(params,
                           rep(jnp.asarray(plan.tokens, jnp.int32)[None]),
                           caches, meta,
                           rep(jnp.asarray(plan.last_rows, jnp.int32)))
