import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (assignment contract): lower + compile every
(architecture x input shape) cell on the single-pod 16x16 mesh and the
2x16x16 multi-pod mesh, with ShapeDtypeStruct inputs (no allocation).

Per cell we record: memory_analysis (fits-in-HBM proof), cost_analysis
(FLOPs/bytes for §Roofline), and per-device collective bytes parsed from
the post-SPMD HLO (all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute operand sizes).

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out report.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCHS, SHAPES, cell_is_runnable, get_config,
                                input_specs)
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamW, cosine_schedule

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by collectives, from post-SPMD HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    pat = re.compile(
        r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(?:-start|-done)?\(")
    shape_pat = re.compile(r"(\w+)\[([\d,]*)\]")
    for m in pat.finditer(hlo_text):
        op = m.group(4)
        shapes = []
        if m.group(1) is not None:   # tuple result
            shapes = shape_pat.findall(m.group(1))
        else:
            shapes = [(m.group(2), m.group(3))]
        nbytes = 0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        # avoid double counting start/done pairs: count "-start" and bare ops
        if op + "-done(" in m.group(0):
            continue
        out[op] += nbytes
    return out


def model_stats(cfg, shape) -> Dict[str, float]:
    """Analytic N_total / N_active / MODEL_FLOPS (assignment §Roofline:
    6*N*D train, 2*N*D inference; MoE uses active params — shared + top-k
    of the routed experts; embeddings excluded unless tied)."""
    model = Model(cfg)
    params_abs = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    total = 0
    routed = 0
    embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
        name = str(path[-1])
        total += leaf.size
        if "embed" in name:
            embed += leaf.size
        # stacked routed experts are 4-D [L, E, din, dout]
        if leaf.ndim == 4 and any(
                k in name for k in ("w_up", "w_gate", "w_down")):
            routed += leaf.size
    n_total = total - (0 if cfg.tie_embeddings else embed)
    active_frac = (cfg.experts_per_token / cfg.n_experts) \
        if cfg.n_experts else 1.0
    n_active = n_total - routed * (1.0 - active_frac)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2

    # ---- analytic HBM traffic (global bytes/step) ----
    # The per-op HLO estimate (hlo_full.hbm_bytes_est) over-counts flash/
    # recurrent inner loops whose tiles are VMEM-resident on TPU, so the
    # roofline memory term uses this first-order model instead:
    pd = 2 if cfg.param_dtype == jnp.bfloat16 else 4
    P = total
    layers = cfg.n_layers + cfg.n_enc_layers
    d = cfg.d_model
    cache_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2  # k+v bf16
    if cfg.kv_lora_rank:
        cache_per_tok = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    if cfg.family == "rwkv6":
        cache_per_tok = 0   # O(1) state
    if shape.kind == "train":
        accum = cfg.train_microbatches
        weight_traffic = 3 * P * pd * accum      # fwd + remat + bwd reads
        opt_traffic = P * 4 * (1 + 4)            # grads w + m,v r/w
        act_traffic = 4 * tokens * d * 2 * layers  # boundaries + attn io
        hbm = weight_traffic + opt_traffic + act_traffic
    elif shape.kind == "prefill":
        hbm = P * 1 + tokens * cache_per_tok * layers \
            + 4 * tokens * d * 2 * layers        # int8 weights (serving)
    else:  # decode: weight + cache read dominate
        T_ctx = shape.seq_len
        cache_rw = shape.global_batch * T_ctx * cache_per_tok * layers
        if cfg.family == "rglru":
            # only 1/3 of layers are (windowed) attention
            cache_rw = shape.global_batch * min(T_ctx, cfg.local_window) * \
                2 * cfg.n_kv_heads * cfg.head_dim * 2 * (layers // 3)
        hbm = P * 1 + cache_rw
    return {"n_total": int(total), "n_active": int(n_active),
            "tokens": int(tokens),
            "model_flops": float(mult * n_active * tokens),
            "analytic_hbm_bytes": float(hbm)}


def _specs_to_shardings(tree, mesh, spec_fn):
    specs = spec_fn(tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, sp: bool = True,
               quantized_serving: bool = True):
    """Returns (jitted_fn, abstract_args, in_shardings) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    opt = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))

    params_abs = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0),
                                  dtype=cfg.param_dtype))
    batch_abs = input_specs(cfg, shape)

    # ZeRO-DP layout only helps training (big global batch); serving keeps
    # the TP layout so small request batches still shard the model axis,
    # and ZeRO requires the global batch to divide the full chip count
    # (256 sequences cannot pure-DP 512 chips — §Perf iteration 16).
    tp = cfg.tensor_parallel or shape.kind != "train" or \
        shape.global_batch % mesh.devices.size != 0

    if shape.kind != "train":
        # serving deploys pre-quantized int8 weights (paper deployment;
        # §Perf: 4x smaller per-layer weight gathers than f32 masters)
        from repro.models.quantize import quantize_params
        params_abs = jax.eval_shape(
            lambda: quantize_params(model.init_params(
                jax.random.PRNGKey(0), dtype=jnp.float32)))

    p_specs = shd.param_pspecs(params_abs, mesh, tensor_parallel=tp)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    b_specs = shd.batch_pspecs(batch_abs, mesh, tensor_parallel=tp)
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                           is_leaf=lambda x: isinstance(x, P))

    shd.set_activation_spec(
        shd.activation_spec(mesh, sp=sp and shape.kind == "train",
                            tensor_parallel=tp),
        mesh=mesh, tensor_parallel=tp)

    if shape.kind == "train":
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_specs = jax.tree.map(lambda _: None, opt_abs)  # mirror params
        o_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shd.param_pspecs(opt_abs.m, mesh, tensor_parallel=tp),
            is_leaf=lambda x: isinstance(x, P))
        opt_shard = type(opt_abs)(
            m=o_shard, v=o_shard,
            count=NamedSharding(mesh, P()))

        from repro.launch.train import build_train_step
        inner = build_train_step(model, opt)

        def train_step(params, opt_state, batch):
            new_p, new_s, _, metrics = inner(params, opt_state, None, batch)
            return new_p, new_s, metrics

        fn = jax.jit(train_step,
                     in_shardings=(p_shard, opt_shard, b_shard),
                     donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch_abs), cfg

    # serving path: quantized per the paper (A8W8 + SPARQ on activations)
    from repro.core.sparq import SparqConfig
    from repro.models.common import QuantCtx
    qctx = QuantCtx(mode="quantized",
                    cfg=SparqConfig.opt5(signed=True),
                    impl="reference") if quantized_serving else None

    if shape.kind == "prefill":
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len + 16))
        c_shard = _specs_to_shardings(
            cache_abs, mesh, lambda t: shd.cache_pspecs(
                t, model, mesh, tensor_parallel=tp))

        def prefill_step(params, batch, caches):
            # dynamic per-tensor scales (calibration-free serving fallback)
            logits, caches = model.prefill(params, batch, caches, ctx=qctx)
            return jnp.argmax(logits, -1), caches

        fn = jax.jit(prefill_step,
                     in_shardings=(p_shard, b_shard, c_shard),
                     donate_argnums=(2,))
        return fn, (params_abs, batch_abs, cache_abs), cfg

    # decode: one token against a cache holding shape.seq_len tokens
    # (+16 pad keeps the time axis divisible by the 16-way model axis)
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len + 16))
    c_shard = _specs_to_shardings(
        cache_abs, mesh, lambda t: shd.cache_pspecs(
            t, model, mesh, tensor_parallel=tp))

    def decode_step(params, batch, caches):
        logits, caches = model.decode_step(
            params, batch["tokens"], caches, pos=shape.seq_len, ctx=qctx)
        return jnp.argmax(logits, -1), caches

    fn = jax.jit(decode_step,
                 in_shardings=(p_shard, b_shard, c_shard),
                 donate_argnums=(2,))
    return fn, (params_abs, batch_abs, cache_abs), cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sp: bool = True) -> Dict[str, Any]:
    ok, reason = cell_is_runnable(arch, shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    try:
        with mesh:
            fn, args, cfg = build_cell(arch, shape_name, mesh, sp=sp)
            lowered = fn.lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            coll = collective_bytes(compiled.as_text())
            try:  # while-aware re-analysis (benchmarks/hlo_cost.py)
                from benchmarks.hlo_cost import HloCost
                full = HloCost(compiled.as_text()).cost()
            except Exception as e:
                full = {"error": str(e)}
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            flops_per_device=float(cost.get("flops", -1)),
            bytes_per_device=float(cost.get("bytes accessed", -1)),
            collective_bytes_per_device=coll,
            model_stats=model_stats(cfg, SHAPES[shape_name]),
            hlo_full=full,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
                "output_bytes": getattr(mem, "output_size_in_bytes", -1),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", -1),
            })
    except Exception as e:  # a dry-run failure is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    finally:
        shd.set_activation_spec(None, None)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel residual stream")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp, sp=not args.no_sp)
                results.append(rec)
                status = rec["status"]
                extra = "" if status != "ok" else (
                    f" flops/dev={rec['flops_per_device']:.3e}"
                    f" temp={rec['memory']['temp_bytes']/2**30:.2f}GiB"
                    f" compile={rec['compile_s']}s")
                print(f"[{rec['mesh']}] {arch} x {shape}: {status}{extra}",
                      flush=True)
                if status == "error":
                    print(rec["error"], flush=True)
    n_err = sum(r["status"] == "error" for r in results)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    print(f"{len(results)} cells: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{n_err} errors")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
