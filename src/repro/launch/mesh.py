"""Production mesh builders (assignment contract).

Functions, not module-level constants, so importing this module never
touches jax device state. The production target is TPU v5e:
  single pod : (16, 16)    -> ("data", "model"), 256 chips
  multi-pod  : (2, 16, 16) -> ("pod", "data", "model"), 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def make_tp_mesh(tp: int):
    """The serving `--tp N` path: a ("data","model") mesh with an N-way
    model axis for the tensor-parallel paged engine. Validates the device
    count up front with an actionable message (on CPU, force devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    n = len(jax.devices())
    if tp < 1:
        raise ValueError(f"--tp must be >= 1, got {tp}")
    if n % tp != 0:
        raise ValueError(
            f"--tp {tp} does not divide the {n} visible jax devices; on "
            f"CPU set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            f"before jax import to fake a multi-device host")
    return make_host_mesh(model_parallel=tp)
