"""Production mesh builders (assignment contract).

Functions, not module-level constants, so importing this module never
touches jax device state. The production target is TPU v5e:
  single pod : (16, 16)    -> ("data", "model"), 256 chips
  multi-pod  : (2, 16, 16) -> ("pod", "data", "model"), 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
