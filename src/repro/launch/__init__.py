"""launch subsystem."""
