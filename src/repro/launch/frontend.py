"""Asyncio streaming front-end over the paged continuous-batching engine.

`ContinuousBatchingEngine.run` is a synchronous host loop: one thread,
one jitted decode step per iteration, mailboxes (`submit`/`cancel`)
drained once per iteration. This module puts an asyncio face on it
without touching that discipline:

  * the engine loop runs on a daemon thread in serve-forever mode
    (`clock_mode="wall"`, `drain=False`);
  * `AsyncFrontend.submit()` hands a request to the engine's thread-safe
    mailbox and returns a `RequestStream` — an async iterator of
    `TokenEvent`s fed from the engine's per-step batched `jax.device_get`
    (ONE device fetch per decode step for all slots, fanned out to
    per-request asyncio queues via `call_soon_threadsafe`; no per-token
    device sync, so the engine's HL201/HL202 host discipline is intact);
  * `RequestStream.cancel()` maps onto the engine's eviction/`release`
    path: queued requests are dropped, mid-prefill requests drop their
    PrefillScheduler job and granted pages, active/preempted requests
    are evicted with shared prefix pages refcount-released and swapped
    planes discarded without a swap-in charge;
  * `stop()` shuts the loop down and returns the engine's results/stats.

The greedy tokens streamed here are bit-identical to a synchronous
`engine.run` over the same requests — scheduling, arrival times,
preemption, and the prefix cache never change tokens (the engine's
core exactness contract; tests/test_frontend.py asserts it end-to-end).

`play_trace` is the synchronous harness: replay a timed arrival trace
(list of `(tokens, gen, at_seconds)`) through the front-end and report
latency SLOs — per-request TTFT (first token time minus *scheduled*
arrival, so queueing delay is charged) and inter-token latency, with
p50/p99 summaries. benchmarks/run.py builds BENCH_slo.json from it.
"""
from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.obs import export as obs_export
from repro.obs.metrics import summary_ms

# host/device topology for the static analyzer (repro.analysis.host_lint).
# This module is pure host code — it never imports jax; every device
# value it sees already crossed through the engine's batched device_get.
__analysis__ = {
    "traced": (),
    "host_loop": (),
    "device_returning": (),
    "device_params": (),
    "host_objects": ("engine", "_engine", "registry", "reg", "server"),
}


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed greedy token: value, perf_counter stamp of the step
    that produced it, and whether it completes its request."""
    token: int
    t: float
    final: bool


class RequestStream:
    """Per-request handle: an async iterator of `TokenEvent`s.

    `arrive_t` is the scheduled engine-clock arrival (seconds since run
    start) or None for "submitted now"; `submit_t` is the perf_counter
    stamp of the submit call. TTFT is measured against the scheduled
    arrival when there is one — a request that waited in the queue is
    charged its queueing delay.
    """

    def __init__(self, frontend: "AsyncFrontend", rid: int,
                 submit_t: float, arrive_t: Optional[float]):
        self._frontend = frontend
        self.rid = rid
        self.submit_t = submit_t
        self.arrive_t = arrive_t
        self.cancelled = False
        self.done = False
        self.events: List[TokenEvent] = []
        self._q: asyncio.Queue = asyncio.Queue()

    def __aiter__(self):
        return self

    async def __anext__(self) -> TokenEvent:
        if self.done and self._q.empty():
            raise StopAsyncIteration
        item = await self._q.get()
        if item is None:
            self.done = True
            raise StopAsyncIteration
        if isinstance(item, BaseException):
            self.done = True
            raise item
        return item

    @property
    def tokens(self) -> np.ndarray:
        """Tokens streamed so far (all of them, once drained)."""
        return np.asarray([e.token for e in self.events], np.int32)

    async def drain(self) -> np.ndarray:
        """Consume the stream to completion; returns the token array."""
        async for _ in self:
            pass
        return self.tokens

    def cancel(self) -> None:
        """Cancel this request (idempotent, best-effort — see
        ContinuousBatchingEngine.cancel). Closes the stream immediately;
        tokens already streamed stay in `events`."""
        if self.cancelled or self.done:
            return
        self.cancelled = True
        self._frontend._engine.cancel(self.rid)
        self._q.put_nowait(None)            # close the iterator


class AsyncFrontend:
    """Drives one serve-forever engine loop from asyncio.

    Lifecycle::

        fe = AsyncFrontend(engine, params)
        await fe.start()                  # engine loop on a daemon thread
        h = fe.submit(tokens, gen)        # or at=<seconds since start>
        async for ev in h: ...            # stream TokenEvents
        results, stats = await fe.stop()  # drain mailboxes, join thread

    One frontend per engine at a time (the engine owns one live run).
    """

    def __init__(self, engine: ContinuousBatchingEngine, params, *,
                 trace_hook=None):
        self._engine = engine
        self._params = params
        self._trace_hook = trace_hook
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._streams: Dict[int, RequestStream] = {}
        self._error: Optional[BaseException] = None
        self.results: Optional[Dict[int, np.ndarray]] = None
        self.stats: Optional[dict] = None
        # online latency distributions, observed per streamed token on
        # the event loop (host stamps from the engine's batched
        # device_get — no extra sync). The engine's registry reset at
        # run start / reset_stats() purges warmup observations; these
        # series handles survive the reset.
        reg = engine.telemetry.registry
        self._h_ttft = reg.histogram(
            "frontend_ttft_seconds",
            "time to first token vs scheduled arrival",
            unit="seconds").series()
        self._h_itl = reg.histogram(
            "frontend_itl_seconds",
            "inter-token latency (consecutive stream gaps, pooled)",
            unit="seconds").series()

    # ------------------------------------------------------- lifecycle
    async def start(self) -> None:
        """Start the engine loop and wait until it accepts traffic."""
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(
            target=self._serve, name="engine-loop", daemon=True)
        self._thread.start()
        ok = await self._loop.run_in_executor(
            None, self._engine._run_live.wait, 30.0)
        if not ok:
            raise RuntimeError("engine loop failed to come up") \
                from self._error

    def _serve(self) -> None:
        """Engine thread body: the serve-forever run loop."""
        try:
            self.results, self.stats = self._engine.run(
                self._params, [], trace_hook=self._trace_hook,
                emit=self._emit, clock_mode="wall", drain=False)
        except BaseException as e:          # propagate into the streams
            self._error = e
            if self._loop is not None:
                self._loop.call_soon_threadsafe(self._fail, e)

    def _emit(self, rid: int, token: int, final: bool, t: float) -> None:
        # engine thread -> event loop; tokens are already host ints
        self._loop.call_soon_threadsafe(
            self._dispatch, rid, token, final, t)

    def _dispatch(self, rid: int, token: int, final: bool,
                  t: float) -> None:
        h = self._streams.get(rid)
        if h is None or h.cancelled or h.done:
            return                          # late token of a cancelled rid
        ev = TokenEvent(token=token, t=t, final=final)
        h.events.append(ev)
        if len(h.events) == 1:
            ref = self.t_origin + h.arrive_t if h.arrive_t is not None \
                else h.submit_t
            self._h_ttft.observe(t - ref)
        else:
            self._h_itl.observe(t - h.events[-2].t)
        h._q.put_nowait(ev)
        if final:
            h._q.put_nowait(None)           # close the iterator

    def _fail(self, e: BaseException) -> None:
        for h in self._streams.values():
            if not h.done:
                h._q.put_nowait(e)

    @property
    def t_origin(self) -> float:
        """perf_counter stamp of the engine clock's zero (run start)."""
        return self._engine._t_origin

    # --------------------------------------------------------- traffic
    def submit(self, tokens, gen: int,
               at: Optional[float] = None) -> RequestStream:
        """Submit a request; returns its stream handle. `at` schedules
        the arrival on the engine clock (seconds since run start) —
        None means "arrives now". Must be called on the event loop."""
        if self._error is not None:
            raise RuntimeError("engine loop died") from self._error
        t_sub = time.perf_counter()
        rid = self._engine.submit(Request(np.asarray(tokens), gen), at=at)
        h = RequestStream(self, rid, submit_t=t_sub, arrive_t=at)
        self._streams[rid] = h
        return h

    async def stop(self) -> Tuple[Dict[int, np.ndarray], dict]:
        """Stop the engine loop and return its (results, stats). Streams
        still open (cancelled or in flight at stop) are closed; their
        partial tokens remain on the handles."""
        self._engine.request_stop()
        await self._loop.run_in_executor(None, self._thread.join)
        if self._error is not None:
            raise self._error
        for h in self._streams.values():
            if not h.done:
                h._q.put_nowait(None)
        return self.results, self.stats


# ----------------------------------------------------------------------
# arrival traces + latency-SLO accounting
# ----------------------------------------------------------------------

def arrival_times(kind: str, n: int, rate: float, *,
                  burst: int = 4, rng=None) -> List[float]:
    """Arrival offsets (seconds since run start) for an open-loop trace.

    `poisson`: i.i.d. exponential inter-arrival gaps at `rate` req/s —
    the memoryless baseline every queueing model assumes. `bursty`:
    groups of `burst` requests land simultaneously, bursts spaced so the
    long-run offered load is still `rate` req/s — same average load,
    far worse tail (admission queueing concentrates at each burst).
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(0) if rng is None else rng
    if kind == "poisson":
        return list(np.cumsum(rng.exponential(1.0 / rate, n)))
    if kind == "bursty":
        gap = burst / rate
        return [(i // burst) * gap for i in range(n)]
    raise ValueError(f"unknown arrival trace kind: {kind!r}")


def _pctl(xs: Sequence[float]) -> dict:
    """p50/p99/mean/max of a sample, in milliseconds."""
    if not xs:
        return {"p50_ms": None, "p99_ms": None,
                "mean_ms": None, "max_ms": None, "n": 0}
    a = np.asarray(xs, np.float64) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()), "max_ms": float(a.max()),
            "n": int(a.size)}


def slo_summary(streams: Sequence[RequestStream],
                t_origin: float) -> dict:
    """TTFT and inter-token latency percentiles over finished streams.

    TTFT is first-token stamp minus the request's *scheduled* arrival
    (t_origin + arrive_t), so queueing/admission delay is charged to the
    server; for unscheduled submissions the submit stamp is used. ITL
    pools every consecutive-token gap across all streams (per-request
    means hide tail stalls — a preemption is one giant gap, and the
    pooled p99 is exactly where it shows)."""
    ttft: List[float] = []
    itl: List[float] = []
    for h in streams:
        if not h.events:
            continue
        ref = t_origin + h.arrive_t if h.arrive_t is not None \
            else h.submit_t
        ttft.append(h.events[0].t - ref)
        itl.extend(b.t - a.t for a, b in zip(h.events, h.events[1:]))
    return {"requests": len(streams),
            "ttft": _pctl(ttft), "itl": _pctl(itl)}


def play_trace(engine: ContinuousBatchingEngine, params,
               trace: Sequence[Tuple[np.ndarray, int, float]], *,
               warmup: Optional[Sequence] = None,
               trace_hook=None,
               metrics_port: Optional[int] = None
               ) -> Tuple[Dict[int, np.ndarray], dict, dict]:
    """Replay a timed arrival trace through the async front-end.

    `trace` rows are (prompt_tokens, gen, at_seconds). Every request is
    submitted up front with its scheduled arrival; the engine's wall
    clock admits each one when its time comes, so the replay is an
    open-loop load test (arrivals do not wait for completions).

    `warmup` rows (same shape, `at` ignored) run to completion first and
    are then erased from the books via `engine.reset_stats()` — compiled
    programs and a warm PrefixIndex stay, counters/timings/watermarks
    restart — so the reported stats and SLOs reflect only the trace.

    `metrics_port` (not None) serves `GET /metrics` from the engine's
    live registry on 127.0.0.1 for the duration of the replay (0 picks
    an ephemeral port) — scrapes read host floats only.

    Returns ({trace_row_index: streamed int32 tokens}, slo_summary,
    engine stats) — keyed by trace position, so callers can compare
    against a synchronous `engine.run` over the same rows directly.
    The SLO percentiles are read from the shared
    `frontend_ttft_seconds` / `frontend_itl_seconds` histograms that
    the front-end observes online (the registry reset at the warmup
    boundary guarantees they hold exactly the trace's samples), so the
    Prometheus exposition and BENCH_slo.json report the same numbers.
    """
    async def _main():
        fe = AsyncFrontend(engine, params, trace_hook=trace_hook)
        server = None
        if metrics_port is not None:
            server = obs_export.MetricsServer(
                engine.telemetry.registry, port=metrics_port)
            await server.start()
            print(f"metrics: http://127.0.0.1:{server.port}/metrics")
        await fe.start()
        if warmup:
            wh = [fe.submit(toks, gen) for toks, gen, *_ in warmup]
            for h in wh:
                await h.drain()
            # let the engine quiesce (final evictions run one iteration
            # after the final token) before drawing the measure boundary
            await asyncio.sleep(0.05)
            engine.reset_stats()
        # the engine clock kept ticking through warmup: schedule the
        # trace relative to "now" so at=0 still means "measure from an
        # unloaded server", and TTFT references follow automatically
        base = time.perf_counter() - fe.t_origin
        handles = [fe.submit(toks, gen, at=base + float(at))
                   for toks, gen, at in trace]
        for h in handles:
            await h.drain()
        results, stats = await fe.stop()
        if server is not None:
            await server.stop()
        return fe, handles, results, stats

    fe, handles, results, stats = asyncio.run(_main())
    slo = {"requests": len(handles),
           "ttft": summary_ms(fe._h_ttft),
           "itl": summary_ms(fe._h_itl)}
    out = {i: h.tokens for i, h in enumerate(handles)}
    return out, slo, stats
