"""optim subsystem."""
