"""AdamW + global-norm clipping, pure-pytree (no optax offline).

State is {m, v, count}; m/v mirror the param tree in f32 and shard with the
same PartitionSpecs as their parameters (ZeRO-style: FSDP shards optimizer
state together with the weights)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(m=zeros(params), v=zeros(params),
                          count=jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics)."""
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)) + 1e-20)
        scale = jnp.minimum(1.0, self.clip_norm / gnorm)
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        bc1 = 1 - self.b1 ** count.astype(jnp.float32)
        bc2 = 1 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, AdamWState(new_m, new_v, count), \
            {"grad_norm": gnorm, "lr": lr}


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 *
                      (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr
