"""SPARQ: the paper's technique as a composable, configurable JAX module.

`SparqConfig` selects every knob the paper evaluates (Tables 2/4): window
width (4/3/2 bits), placement options (5/3/2opt, 6opt, 7opt), rounding (±R),
vSPARQ (±vS), plus our signed extension for transformer activations.

`sparq_dot` / `sparq_linear` are the float-level reference path used by the
models on CPU; the Pallas kernel in `repro.kernels` implements the same
semantics fused into the matmul and is validated against these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import bsparq, vsparq
from repro.core.quantizer import QScale, quantize, weight_scale


@dataclasses.dataclass(frozen=True)
class SparqConfig:
    """Configuration of the SPARQ quantizer (paper §3, §5).

    bits/opts combinations evaluated in the paper:
      (4, 5) 5opt · (4, 3) 3opt · (4, 2) 2opt · (3, 6) 6opt · (2, 7) 7opt
    """
    bits: int = 4
    opts: int = 5
    rounding: bool = True          # +R
    vsparq: bool = True            # pair-level sparsity (Eq. 2)
    signed: bool = False           # signed magnitude extension (beyond paper)
    act_bits: int = 8              # base PTQ bit-width of activations
    weight_bits: int = 8           # per-channel weight bit-width
    enabled: bool = True           # False -> plain A8W8 (paper's baseline)

    @property
    def shifts(self) -> tuple[int, ...]:
        return bsparq.shifts_for(self.bits, self.opts)

    @property
    def max_val(self) -> int:
        return (1 << (self.act_bits - 1)) - 1 if self.signed \
            else (1 << self.act_bits) - 1

    @property
    def name(self) -> str:
        tag = f"{self.bits}b-{self.opts}opt"
        tag += "+R" if self.rounding else "-R"
        tag += "+vS" if self.vsparq else "-vS"
        return tag + ("(signed)" if self.signed else "")

    # Common named configurations
    @staticmethod
    def opt5(**kw) -> "SparqConfig":
        return SparqConfig(bits=4, opts=5, **kw)

    @staticmethod
    def opt3(**kw) -> "SparqConfig":
        return SparqConfig(bits=4, opts=3, **kw)

    @staticmethod
    def opt2(**kw) -> "SparqConfig":
        return SparqConfig(bits=4, opts=2, **kw)

    @staticmethod
    def opt6(**kw) -> "SparqConfig":  # 3-bit
        return SparqConfig(bits=3, opts=6, **kw)

    @staticmethod
    def opt7(**kw) -> "SparqConfig":  # 2-bit
        return SparqConfig(bits=2, opts=7, **kw)

    @staticmethod
    def a8w8() -> "SparqConfig":
        return SparqConfig(enabled=False)


def sparq_recon_int(q: jnp.ndarray, cfg: SparqConfig) -> jnp.ndarray:
    """Integer codes -> SPARQ-reconstructed integer codes (last axis = K)."""
    if not cfg.enabled:
        return q
    if cfg.vsparq:
        fn = vsparq.vsparq_recon_signed if cfg.signed else vsparq.vsparq_recon
    else:
        fn = bsparq.bsparq_recon_signed if cfg.signed else bsparq.bsparq_recon
    return fn(q, cfg.bits, cfg.shifts, cfg.rounding, cfg.max_val)


def sparq_fake_quant(x: jnp.ndarray, act_qs: QScale,
                     cfg: SparqConfig) -> jnp.ndarray:
    """Float activations -> float SPARQ reconstruction (reference path)."""
    q = quantize(x, act_qs)
    r = sparq_recon_int(q, cfg)
    return r.astype(x.dtype) * act_qs.scale


def sparq_dot(x: jnp.ndarray, w_q: jnp.ndarray, act_qs: QScale,
              w_qs: QScale, cfg: SparqConfig,
              keep_idx: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Quantized dot product: x [..., K] float, w_q [K, N] int codes.

    Matches the paper's datapath: activations quantized to act_bits, SPARQ'd
    dynamically (optionally through the STC 2:4 path when keep_idx is given),
    multiplied against integer weights, rescaled by act_scale * w_scale.
    """
    q = quantize(x, act_qs)
    if keep_idx is not None:
        r = vsparq.vsparq_recon_grouped(
            q, keep_idx, cfg.bits, cfg.shifts, cfg.rounding, cfg.max_val,
            signed=cfg.signed)
    else:
        r = sparq_recon_int(q, cfg)
    acc = jnp.matmul(r.astype(jnp.float32), w_q.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return acc * act_qs.scale * w_qs.scale


def sparq_linear(x: jnp.ndarray, w: jnp.ndarray, act_qs: QScale,
                 cfg: SparqConfig) -> jnp.ndarray:
    """Convenience: quantize weights on the fly (per-output-channel)."""
    w_qs = weight_scale(w, cfg.weight_bits)
    w_q = quantize(w, w_qs)
    return sparq_dot(x, w_q, act_qs, w_qs, cfg)


def sparq_dot_stc(x: jnp.ndarray, w: jnp.ndarray, act_qs: QScale,
                  cfg: SparqConfig, chunk: int = 32) -> jnp.ndarray:
    """Sparse-Tensor-Core simulation (paper §5.3): w is 2:4-pruned along its
    reduction axis; per *output channel*, the STC muxes the 2 surviving
    activations of each group of 4 and vSPARQ pairs them. Because the
    selection differs per output channel, reconstruction is per-channel —
    computed in channel chunks to bound memory."""
    from repro.core.pruning import keep_indices
    from repro.core.vsparq import vsparq_recon_grouped
    w_qs = weight_scale(w, cfg.weight_bits)
    w_q = quantize(w, w_qs)                       # [K, N]
    keep = keep_indices(w, axis=0)                # [N, K/4, 2]
    q = quantize(x, act_qs)                       # [..., K]
    N = w.shape[1]
    outs = []
    for c0 in range(0, N, chunk):
        kc = keep[c0:c0 + chunk]                  # [C, G, 2]
        qx = q[..., None, :]                      # [..., 1, K]
        recon = vsparq_recon_grouped(
            jnp.broadcast_to(qx, q.shape[:-1] + (kc.shape[0], q.shape[-1])),
            kc, cfg.bits, cfg.shifts, cfg.rounding, cfg.max_val,
            signed=cfg.signed)                    # [..., C, K]
        y = jnp.einsum("...ck,kc->...c", recon.astype(jnp.float32),
                       w_q[:, c0:c0 + chunk].astype(jnp.float32))
        outs.append(y * act_qs.scale * w_qs.scale[c0:c0 + chunk])
    return jnp.concatenate(outs, axis=-1)
