"""SPARQ core: the paper's contribution as composable JAX modules."""
from repro.core.bsparq import bsparq_encode, bsparq_recon, bsparq_recon_signed, shifts_for
from repro.core.vsparq import vsparq_recon, vsparq_recon_signed, vsparq_recon_grouped
from repro.core.sparq import SparqConfig, sparq_dot, sparq_fake_quant, sparq_linear, sparq_recon_int
from repro.core.quantizer import (
    QScale, MinMaxObserver, act_scale_from_stats, weight_scale, quantize,
    dequantize, fake_quant, quantize_weight)
from repro.core.aciq import aciq_fake_quant, aciq_act_scale
from repro.core.pruning import prune_2_4, keep_indices, sparsity
from repro.core.calibration import CalibBank, calibrate

__all__ = [
    "SparqConfig", "sparq_dot", "sparq_fake_quant", "sparq_linear",
    "sparq_recon_int", "bsparq_encode", "bsparq_recon", "bsparq_recon_signed",
    "shifts_for", "vsparq_recon", "vsparq_recon_signed", "vsparq_recon_grouped",
    "QScale", "MinMaxObserver", "act_scale_from_stats", "weight_scale",
    "quantize", "dequantize", "fake_quant", "quantize_weight",
    "aciq_fake_quant", "aciq_act_scale", "prune_2_4", "keep_indices",
    "sparsity", "CalibBank", "calibrate",
]
