"""Uniform min-max symmetric PTQ (paper §5 baseline quantizer).

Activations: per-tensor ("per-layer") symmetric. The paper uses *unsigned*
activations (post-ReLU CNNs); transformers need the signed variant
(DESIGN.md §3.5). Weights: per-output-channel ("per-kernel") symmetric signed.
Scales are plain floats/arrays carried in a small pytree so they shard and
checkpoint like any other state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QScale:
    """Quantization scale(s): x_int = clip(round(x / scale)). For unsigned
    tensors the integer range is [0, 2**bits - 1]; for signed,
    [-(2**(bits-1) - 1), 2**(bits-1) - 1] (symmetric, no -128)."""
    scale: jnp.ndarray  # scalar (per-tensor) or [out_features] (per-channel)
    bits: int
    signed: bool

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1

    @property
    def qmin(self) -> int:
        return -self.qmax if self.signed else 0


def act_scale_from_stats(max_val: jnp.ndarray, bits: int = 8,
                         signed: bool = False) -> QScale:
    """Per-tensor activation scale from calibrated max statistic.

    Unsigned (paper): scale = max / (2^bits - 1). Signed: max|x| / (2^(b-1)-1).
    """
    qmax = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    scale = jnp.maximum(jnp.asarray(max_val, jnp.float32), 1e-8) / qmax
    return QScale(scale=scale, bits=bits, signed=signed)


def weight_scale(w: jnp.ndarray, bits: int = 8) -> QScale:
    """Per-output-channel symmetric signed scale; w is [in, out]."""
    qmax = (1 << (bits - 1)) - 1
    absmax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)))
    scale = jnp.maximum(absmax, 1e-8) / qmax
    return QScale(scale=scale, bits=bits, signed=True)


def quantize(x: jnp.ndarray, qs: QScale) -> jnp.ndarray:
    """Float -> int32 codes (round-to-nearest-even, clipped)."""
    q = jnp.round(x / qs.scale)
    return jnp.clip(q, qs.qmin, qs.qmax).astype(jnp.int32)


def dequantize(q: jnp.ndarray, qs: QScale) -> jnp.ndarray:
    return q.astype(jnp.float32) * qs.scale


def fake_quant(x: jnp.ndarray, qs: QScale) -> jnp.ndarray:
    return dequantize(quantize(x, qs), qs)


def quantize_weight(w: jnp.ndarray, bits: int = 8) -> tuple[jnp.ndarray, QScale]:
    qs = weight_scale(w, bits)
    return quantize(w, qs), qs


@dataclasses.dataclass
class MinMaxObserver:
    """Running min/max collector for activation calibration (paper: 2K images).

    Functional: `update` returns a new observer; state is two scalars so it
    can live inside jit-carried pytrees.
    """
    max_val: float = 0.0
    min_val: float = 0.0
    count: int = 0

    def update(self, x: jnp.ndarray) -> "MinMaxObserver":
        mx = float(jnp.max(x))
        mn = float(jnp.min(x))
        if self.count == 0:
            return MinMaxObserver(mx, mn, 1)
        return MinMaxObserver(max(self.max_val, mx), min(self.min_val, mn),
                              self.count + 1)

    def scale(self, bits: int = 8, signed: Optional[bool] = None) -> QScale:
        if signed is None:
            signed = self.min_val < 0
        span = max(abs(self.max_val), abs(self.min_val)) if signed else self.max_val
        return act_scale_from_stats(span, bits=bits, signed=signed)
