"""vSPARQ: value-level sparsity over activation pairs (paper §3.2, Eq. 2).

Activations are grouped in pairs along the dot-product (reduction) axis.
If one member of the pair is zero, the other keeps its full 8-bit precision
(it borrows the partner's n-bit budget via Eq. 3); only when both are
non-zero is each trimmed by bSPARQ.

Functions operate on int32 arrays whose **last axis is the reduction axis**
(length must be even); they are the oracle for the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bsparq import bsparq_recon


def vsparq_recon(
    x: jnp.ndarray, n_bits: int, shifts: tuple[int, ...], rounding: bool,
    max_val: int = 255,
) -> jnp.ndarray:
    """Eq. (2) reconstruction for non-negative int32 values.

    x[..., K] with K even. Returns same-shape int32 reconstruction.
    """
    if x.shape[-1] % 2 != 0:
        raise ValueError(f"reduction axis must be even, got {x.shape[-1]}")
    pairs = x.reshape(*x.shape[:-1], -1, 2)
    a, b = pairs[..., 0], pairs[..., 1]
    trimmed_a = bsparq_recon(a, n_bits, shifts, rounding, max_val)
    trimmed_b = bsparq_recon(b, n_bits, shifts, rounding, max_val)
    # partner zero -> keep full precision; else bSPARQ (Eq. 2 cases).
    ra = jnp.where(b == 0, a, trimmed_a)
    rb = jnp.where(a == 0, b, trimmed_b)
    out = jnp.stack([ra, rb], axis=-1)
    return out.reshape(x.shape)


def vsparq_recon_signed(
    x: jnp.ndarray, n_bits: int, shifts: tuple[int, ...], rounding: bool,
    max_val: int = 127,
) -> jnp.ndarray:
    """Signed extension: pairing decision on |x| == 0; bSPARQ on magnitudes."""
    sign = jnp.sign(x).astype(jnp.int32)
    mag = jnp.abs(x).astype(jnp.int32)
    return sign * vsparq_recon(mag, n_bits, shifts, rounding, max_val)


def vsparq_recon_grouped(
    x: jnp.ndarray,
    keep_idx: jnp.ndarray,
    n_bits: int,
    shifts: tuple[int, ...],
    rounding: bool,
    max_val: int = 255,
    signed: bool = False,
) -> jnp.ndarray:
    """Sparse-Tensor-Core path (paper §5.3, Table 6).

    With 2:4 structured weight pruning, the STC muxes 2 of every 4 activations
    (those aligned with surviving weights); vSPARQ then pairs the two selected
    activations. `keep_idx[..., G, 2]` holds, per group of 4 along the last
    axis of x, the two selected positions (0..3). Returns the same-shape
    reconstruction with the *selected* lanes vSPARQ'd; unselected lanes are
    passed through untouched (they are multiplied by zero weights anyway).
    """
    if x.shape[-1] % 4 != 0:
        raise ValueError(f"reduction axis must be divisible by 4, got {x.shape[-1]}")
    g = x.reshape(*x.shape[:-1], -1, 4)
    while keep_idx.ndim < g.ndim:   # broadcast leading batch dims
        keep_idx = keep_idx[None]
    picked = jnp.take_along_axis(g, keep_idx, axis=-1)  # [..., G, 2]
    flat = picked.reshape(*picked.shape[:-2], -1)
    recon = (vsparq_recon_signed if signed else vsparq_recon)(
        flat, n_bits, shifts, rounding, max_val)
    recon = recon.reshape(picked.shape)
    scattered = g  # unselected lanes pass through (they meet zero weights)
    for j in range(2):
        scattered = jnp.where(
            jnp.arange(4) == keep_idx[..., j:j + 1], recon[..., j:j + 1], scattered)
    return scattered.reshape(x.shape)
