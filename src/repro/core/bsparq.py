"""bSPARQ: bit-level sparsity-aware dynamic quantization (paper §3.1).

An already-quantized integer value (8-bit unsigned in the paper; 7-bit
magnitude in our signed extension) is trimmed to `n_bits` by selecting the
most-significant consecutive n-bit window, skipping leading zero bits.
Optionally the value inside the window is rounded to nearest using the
residual LSBs (+R), with exact carry handling (a carry that overflows the
window re-encodes at the next window position; values beyond the
representable range saturate).

All functions are pure jnp over int32 arrays and are used both by the
reference (fake-quant) path and as the oracle for the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bitops import msb_pos, select_shift


def shifts_for(n_bits: int, opts: int) -> tuple[int, ...]:
    """Window placement (shift-left) options for a configuration.

    Full sets: n=4 -> 5opt = (0..4); n=3 -> 6opt = (0..5); n=2 -> 7opt = (0..6).
    Reduced sets (paper §3.1): 3opt = (0,2,4); 2opt = (0,4).
    """
    full = 8 - n_bits + 1
    if opts == full:
        return tuple(range(full))
    if n_bits == 4 and opts == 3:
        return (0, 2, 4)
    if n_bits == 4 and opts == 2:
        return (0, 4)
    raise ValueError(f"unsupported (n_bits={n_bits}, opts={opts})")


def _trim(x: jnp.ndarray, n_bits: int, shifts: tuple[int, ...]):
    """Trim-only window selection. Returns (q, s): window value and shift."""
    m = msb_pos(x)
    s = select_shift(m, n_bits, shifts)
    q = jnp.right_shift(x, s) & ((1 << n_bits) - 1)
    return q, s


def bsparq_encode(
    x: jnp.ndarray, n_bits: int, shifts: tuple[int, ...], rounding: bool,
    max_val: int = 255,
):
    """Encode non-negative int32 values into (window value q, shift s).

    Reconstruction is ``q << s``. With rounding, the residual LSB below the
    window rounds q to nearest; a carry out of the window (q == 2**n) is
    re-encoded exactly at a higher window position when one exists, else the
    value saturates at the largest representable code.
    """
    x = x.astype(jnp.int32)
    q, s = _trim(x, n_bits, shifts)
    if not rounding:
        return q, s
    rbit = jnp.where(s > 0, jnp.right_shift(x, jnp.maximum(s - 1, 0)) & 1, 0)
    q = q + rbit
    v = jnp.left_shift(q, s)
    # Carry handling: q == 2**n makes v a single toggled bit at position n+s,
    # which the trim rule re-encodes exactly when in range; clamping to
    # max_val first makes out-of-range carries saturate at the largest
    # representable code (trim(255) -> 240, trim(127) -> 120). For values
    # without carry the re-encode is an exact identity, so we apply it
    # unconditionally — branch-free, kernel-friendly.
    v = jnp.minimum(v, max_val)
    return _trim(v, n_bits, shifts)


def bsparq_recon(
    x: jnp.ndarray, n_bits: int, shifts: tuple[int, ...], rounding: bool,
    max_val: int = 255,
) -> jnp.ndarray:
    """Fake-quant reconstruction: encode then decode (q << s). int32 -> int32."""
    q, s = bsparq_encode(x, n_bits, shifts, rounding, max_val)
    return jnp.left_shift(q, s)


def bsparq_recon_signed(
    x: jnp.ndarray, n_bits: int, shifts: tuple[int, ...], rounding: bool,
    max_val: int = 127,
) -> jnp.ndarray:
    """Signed extension (beyond paper, DESIGN.md §3.5): sign-magnitude.

    bSPARQ windows the magnitude; the sign rides along as one metadata bit.
    Input values in [-max_val, max_val].
    """
    sign = jnp.sign(x).astype(jnp.int32)
    mag = jnp.abs(x).astype(jnp.int32)
    return sign * bsparq_recon(mag, n_bits, shifts, rounding, max_val)
