"""Calibration pass: collect per-layer activation statistics (paper §5).

The paper calibrates per-layer min/max on ~2K images and recalibrates
BatchNorm running statistics. Here the generic machinery: a `CalibBank`
mapping layer names -> MinMaxObserver, updated functionally during forward
passes run with `collect=...` plumbed through the model's quant hooks, plus
a BatchNorm recalibration helper for the paper-faithful CNN.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable

import jax.numpy as jnp

from repro.core.quantizer import MinMaxObserver, QScale


@dataclasses.dataclass
class CalibBank:
    """Named activation observers. Not a jit-carried structure: calibration
    runs eagerly (a handful of batches, per the paper)."""
    observers: Dict[str, MinMaxObserver] = dataclasses.field(default_factory=dict)

    def observe(self, name: str, x: jnp.ndarray) -> None:
        obs = self.observers.get(name, MinMaxObserver())
        self.observers[name] = obs.update(x)

    def scales(self, bits: int = 8) -> Dict[str, QScale]:
        return {k: o.scale(bits=bits) for k, o in self.observers.items()}

    def merge(self, other: "CalibBank") -> "CalibBank":
        out = dict(self.observers)
        for k, o in other.observers.items():
            if k in out:
                merged = MinMaxObserver(
                    max(out[k].max_val, o.max_val),
                    min(out[k].min_val, o.min_val),
                    out[k].count + o.count)
                out[k] = merged
            else:
                out[k] = o
        return CalibBank(out)


def calibrate(apply_fn: Callable, params, batches: Iterable) -> CalibBank:
    """Run `apply_fn(params, batch, collect=bank)` over calibration batches."""
    bank = CalibBank()
    for batch in batches:
        apply_fn(params, batch, collect=bank)
    return bank


def recalibrate_batchnorm(stats_fn: Callable, params, batches: Iterable,
                          momentum: float = 0.1):
    """Recompute BN running mean/var over calibration batches (paper §5,
    refs [29,33,35,36]). `stats_fn(params, batch)` returns
    {bn_name: (batch_mean, batch_var)}; we EMA them into fresh running stats
    and return the updated stats dict."""
    running = {}
    for batch in batches:
        for name, (mean, var) in stats_fn(params, batch).items():
            if name not in running:
                running[name] = (mean, var)
            else:
                m0, v0 = running[name]
                running[name] = ((1 - momentum) * m0 + momentum * mean,
                                 (1 - momentum) * v0 + momentum * var)
    return running
