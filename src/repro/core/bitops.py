"""Integer bit-level helpers used by bSPARQ.

All functions operate on int32 JAX arrays holding small non-negative integers
(magnitudes after symmetric quantization, i.e. values in [0, 255]).
They are pure jnp, shape-polymorphic, and jit-safe.
"""
from __future__ import annotations

import jax.numpy as jnp


def msb_pos(x: jnp.ndarray) -> jnp.ndarray:
    """Position (0-indexed) of the most-significant toggled bit.

    floor(log2(x)) for x >= 1, and 0 for x == 0 (callers treat x==0 as
    "no window shift needed"; the reconstruction of 0 is 0 regardless).
    Exact integer computation — no float log.
    """
    x = x.astype(jnp.int32)
    m = jnp.zeros_like(x)
    for k in range(1, 8):  # values are < 2**8
        m = m + (x >= (1 << k)).astype(jnp.int32)
    return m


def select_shift(m: jnp.ndarray, n_bits: int, shifts: tuple[int, ...]) -> jnp.ndarray:
    """Smallest allowed shift s in `shifts` such that the n-bit window
    [s+n-1 : s] covers bit position `m` (the paper's trim rule: the window is
    placed at the first most-significant toggled bit, restricted to the
    placement options of the configuration).

    `shifts` is a static, ascending tuple, e.g. (0,1,2,3,4) for 5opt,
    (0,2,4) for 3opt, (0,4) for 2opt. If m exceeds every window (cannot
    happen for in-range values), the max shift is used.
    """
    need = jnp.maximum(m - (n_bits - 1), 0)  # minimal shift that still covers m
    s = jnp.full_like(m, shifts[-1])
    for opt in reversed(shifts[:-1]):
        s = jnp.where(need <= opt, opt, s)
    return s
