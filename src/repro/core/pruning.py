"""2:4 structured weight pruning (paper §5.3, NVIDIA Sparse Tensor Cores).

Every group of 4 adjacent weights along the reduction axis keeps its 2
largest-magnitude members. `keep_indices` produces the coordinates the STC
stores; `vsparq_recon_grouped` (core.vsparq) consumes them to pair the two
surviving activations per group, exactly the paper's Figure 5 dataflow.
"""
from __future__ import annotations

import jax.numpy as jnp


def prune_2_4(w: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Zero the 2 smallest-|w| of every 4 adjacent weights along `axis`."""
    w_m = jnp.moveaxis(w, axis, -1)
    if w_m.shape[-1] % 4 != 0:
        raise ValueError(f"axis length must be divisible by 4: {w_m.shape[-1]}")
    g = w_m.reshape(*w_m.shape[:-1], -1, 4)
    # rank within each group: keep top-2 by |w|
    order = jnp.argsort(jnp.abs(g), axis=-1)  # ascending
    ranks = jnp.argsort(order, axis=-1)
    mask = ranks >= 2
    pruned = jnp.where(mask, g, 0.0).reshape(w_m.shape)
    return jnp.moveaxis(pruned, -1, axis)


def keep_indices(w: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Per group of 4 along `axis`, ascending positions (0..3) of the 2 kept
    weights — the STC's stored coordinates. Shape [..., K/4, 2] with the
    grouped axis moved last."""
    w_m = jnp.moveaxis(w, axis, -1)
    g = w_m.reshape(*w_m.shape[:-1], -1, 4)
    top2 = jnp.argsort(-jnp.abs(g), axis=-1)[..., :2]
    return jnp.sort(top2, axis=-1)


def sparsity(w: jnp.ndarray) -> float:
    return float(jnp.mean(w == 0.0))
