"""ACIQ analytic clipping (Banner et al., NeurIPS 2019) — Table 3 baseline.

ACIQ derives the MSE-optimal clipping value for a bell-shaped distribution
analytically: alpha* = c(bits) * b, with b the Laplace scale E|x - mu| (or
c'(bits) * sigma for Gaussian). We implement the Laplace variant the paper
compares against, with the published constants.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantizer import QScale, act_scale_from_stats

# alpha*/b for Laplace(0, b), per bit-width (Banner et al., Table 1).
_LAPLACE_ALPHA_OVER_B = {2: 2.83, 3: 3.89, 4: 5.03, 5: 6.20, 6: 7.41,
                         7: 8.64, 8: 9.89}
# alpha*/sigma for Gaussian, per bit-width.
_GAUSS_ALPHA_OVER_SIGMA = {2: 1.71, 3: 2.15, 4: 2.55, 5: 2.93, 6: 3.28,
                           7: 3.61, 8: 3.92}


def aciq_clip_laplace(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Optimal symmetric clip value for Laplace-distributed x."""
    b = jnp.mean(jnp.abs(x - jnp.mean(x)))
    return _LAPLACE_ALPHA_OVER_B[bits] * b


def aciq_clip_gauss(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    sigma = jnp.std(x)
    return _GAUSS_ALPHA_OVER_SIGMA[bits] * sigma


def aciq_act_scale(x: jnp.ndarray, bits: int, signed: bool,
                   dist: str = "laplace") -> QScale:
    """Activation scale with ACIQ clipping instead of min-max."""
    clip = aciq_clip_laplace(x, bits) if dist == "laplace" \
        else aciq_clip_gauss(x, bits)
    return act_scale_from_stats(clip, bits=bits, signed=signed)


def aciq_fake_quant(x: jnp.ndarray, bits: int, signed: bool,
                    dist: str = "laplace") -> jnp.ndarray:
    qs = aciq_act_scale(x, bits, signed, dist)
    q = jnp.clip(jnp.round(x / qs.scale), qs.qmin, qs.qmax)
    return q * qs.scale
