"""Pure-jnp oracles for the Pallas kernels (single source of truth is
repro.core; these wrappers match the kernels' exact signatures/dtypes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bsparq import bsparq_encode
from repro.core.sparq import SparqConfig, sparq_recon_int


def _cfg(bits, shifts, rounding, vsparq, signed, max_val, enabled=True):
    opts = len(shifts)
    return SparqConfig(bits=bits, opts=opts, rounding=rounding, vsparq=vsparq,
                       signed=signed, enabled=enabled,
                       act_bits=8)


def ref_sparq_matmul(x, w_codes, act_scale, chan_scale, *, bits=4,
                     opts_shifts=(0, 1, 2, 3, 4), rounding=True, vsparq=True,
                     signed=False, max_val=255, enabled=True):
    """Oracle for sparq_matmul_pallas: float x, int8 weight codes."""
    qmin = -max_val if signed else 0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / act_scale), qmin, max_val)
    q = q.astype(jnp.int32)
    cfg = _cfg(bits, opts_shifts, rounding, vsparq, signed, max_val, enabled)
    r = sparq_recon_int(q, cfg) if enabled else q
    if signed and max_val <= 127:
        # native int8 x int8 -> int32 dot (the v5e MXU path). Keeping both
        # operands int8 also keeps the FSDP weight all-gather at 1 byte —
        # int32 operands made GSPMD gather 4x the bytes (§Perf iteration 4).
        acc = jax.lax.dot_general(
            r.astype(jnp.int8), w_codes.astype(jnp.int8),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    else:
        acc = jax.lax.dot_general(  # exact int32 accumulation (unsigned)
            r, w_codes.astype(jnp.int32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * act_scale * chan_scale[None, :])


def ref_sparq_quant(x, act_scale, *, bits=4, opts_shifts=(0, 1, 2, 3, 4),
                    rounding=True, vsparq=True, signed=True, max_val=127,
                    enabled=True):
    """Oracle for sparq_quant_pallas: returns (codes int8, meta int8)."""
    qmin = -max_val if signed else 0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / act_scale), qmin, max_val)
    q = q.astype(jnp.int32)
    if not enabled:
        # plain int8 PTQ (paper baseline): full codes, empty meta
        return q.astype(jnp.int8), jnp.zeros_like(q, dtype=jnp.int8)
    sign = jnp.sign(q)
    mag = jnp.abs(q)
    qq, ss = bsparq_encode(mag, bits, opts_shifts, rounding, max_val)
    trimmed = jnp.left_shift(qq, ss)
    if vsparq:
        pairs = mag.reshape(*mag.shape[:-1], -1, 2)
        a, b = pairs[..., 0], pairs[..., 1]
        partner = jnp.stack([b, a], axis=-1).reshape(mag.shape)
        full = partner == 0
        recon = jnp.where(full, mag, trimmed)
        shift_code = jnp.where(full, 0, ss)
        mux = full
    else:
        recon = trimmed
        shift_code = ss
        mux = jnp.zeros_like(mag, dtype=jnp.bool_)
    codes = (sign * recon).astype(jnp.int8)
    mux_i = mux.astype(jnp.int32).reshape(*mag.shape[:-1], -1, 2)
    s_pair = shift_code.reshape(*mag.shape[:-1], -1, 2)
    mux_any = jnp.minimum(mux_i[..., 0] + mux_i[..., 1], 1)
    meta_pair = mux_any * 64 + s_pair[..., 0] * 8 + s_pair[..., 1]
    meta = jnp.repeat(meta_pair, 2, axis=-1).astype(jnp.int8)
    return codes, meta


def meta_shifts(meta: jnp.ndarray) -> jnp.ndarray:
    """Per-lane ShiftCtrl from the packed per-pair meta byte (§5.1):
    [mux(1) | shift_even(3) | shift_odd(3)], mirrored to both lanes."""
    m = meta.astype(jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, m.shape, m.ndim - 1)
    return jnp.where(lane % 2 == 0, jnp.right_shift(m, 3) & 7, m & 7)


def ref_sparq_dequant(store: jnp.ndarray, meta: jnp.ndarray) -> jnp.ndarray:
    """Oracle for sparq_dequant_pallas: int8 window codes + packed meta ->
    int8 SPARQ-reconstructed codes (codes[i] = sign * (|store[i]| << s_i))."""
    q = store.astype(jnp.int32)
    shift = meta_shifts(meta)
    return (jnp.sign(q) * jnp.left_shift(jnp.abs(q), shift)).astype(jnp.int8)


def _meta_decode32(store, meta, scale):
    """§5.1 meta-decode of one packed tile, in int32 (no int8 narrowing) —
    the exact datapath of the fused decode kernels."""
    q32 = store.astype(jnp.int32)
    shift = meta_shifts(meta)
    recon = jnp.sign(q32) * jnp.left_shift(jnp.abs(q32), shift)
    return recon.astype(jnp.float32) * scale


def ref_sparq_decode_attn(q, k_data, k_meta, k_scale, v_data, v_meta,
                          v_scale, kpos, cur, *, window: int = 0,
                          bk: int = 128):
    """Tiled oracle for sparq_decode_attn_pallas: same Tk-tile loop, same
    per-tile meta-decode + online-softmax update order, expressed in jnp
    with a lax.scan over tiles — so it never materializes the dequantized
    K/V planes either, and (running the identical op sequence) matches the
    interpret-mode kernel bit for bit.

    q [B,KV,G,hd] float; k/v planes [B,Tk,KV,hd] int8; kpos [B,Tk] int32
    slot positions (-1 = empty); cur scalar int32. Returns f32 [B,KV,G,hd].
    """
    B, KV, G, hd = q.shape
    Tk = k_data.shape[1]
    assert Tk % bk == 0, (Tk, bk)
    qf = q.astype(jnp.float32)
    sm_scale = hd ** -0.5

    _decode = _meta_decode32

    def tile(carry, t):
        m, l, acc = carry
        kd = jax.lax.dynamic_slice_in_dim(k_data, t * bk, bk, 1)
        km = jax.lax.dynamic_slice_in_dim(k_meta, t * bk, bk, 1)
        vd = jax.lax.dynamic_slice_in_dim(v_data, t * bk, bk, 1)
        vm = jax.lax.dynamic_slice_in_dim(v_meta, t * bk, bk, 1)
        kp = jax.lax.dynamic_slice_in_dim(kpos, t * bk, bk, 1)  # [B, bk]
        k = _decode(kd, km, k_scale)                   # [B, bk, KV, hd]
        s = jnp.einsum("bkgh,bskh->bkgs", qf, k,
                       preferred_element_type=jnp.float32) * sm_scale
        ok = (kp >= 0) & (kp <= cur)
        if window:
            ok &= kp > cur - window
        okb = ok[:, None, None, :]                     # [B, 1, 1, bk]
        s = jnp.where(okb, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(okb, p, 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = _decode(vd, vm, v_scale)
        pv = jnp.einsum("bkgs,bskh->bkgh", p, v,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr + pv), None

    m0 = jnp.full((B, KV, G, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, 1), jnp.float32)
    a0 = jnp.zeros((B, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(tile, (m0, l0, a0), jnp.arange(Tk // bk))
    return acc / jnp.maximum(l, 1e-30)


def ref_sparq_chunked_prefill_attn(q, k_chunk, v_chunk, k_data, k_meta,
                                   k_scale, v_data, v_meta, v_scale,
                                   block_table, seq_id, pos, hist,
                                   tile_seq, *, window: int = 0):
    """Tiled oracle for sparq_chunked_prefill_attn_pallas: ragged chunked
    prefill over a packed token stream.

    One fixed-shape chunk of C prompt tokens (possibly from several
    sequences, possibly only a slice of a long prompt) attends to

      1. its own sequence's *already-written* §5.1 packed pages — every
         position below the token's history boundary `hist` — gathered
         through the per-slot block table and meta-decoded tile by tile
         (one page == one Tk tile, same `_meta_decode32` datapath as the
         decode kernels), and
      2. the float K/V of its own history window [hist, pos]: causal
         attention over the chunk, segment-masked by per-token sequence
         id AND bounded below by `hist`.

    `hist` is per token: the scheduler sets it to the token's *segment*
    start ((pos // seg) * seg), and packs whole segments only — so a
    prompt's float-vs-packed attention split depends only on the prompt
    and the segment quantum, never on how chunks happened to be packed
    (this is what keeps chunked prefill deterministic per request and
    requeue-replay bit-exact). Tokens in [hist, pos) are guaranteed to be
    in the same chunk; positions below hist are guaranteed already
    written (possibly by this very chunk program — writes precede reads).

    Page tiles run first (ascending kpos), the in-chunk stage last; the
    pallas kernel walks the identical stage order with the identical f32
    update arithmetic (interpret-mode agreement is exact for the in-chunk
    stage and within a couple of f32 ulps over page tiles, where XLA's
    fusion of this scanned oracle reorders the multiply-add chain).

    q           [C, KV, G, hd] float — chunk queries, GQA via grouping
    k/v_chunk   [C, KV, hd] float — the chunk's own (pre-quantization) K/V
    k/v planes  [P, ps, KV, hd] int8 — the global §5.1 page pools
    k/v scale   [S] f32 — per-slot site scales (frozen at first write)
    block_table [S, NB] int32 — physical page per logical block (-1 unset)
    seq_id      [C] int32 — sequence slot per stream token (-1 = padding)
    pos         [C] int32 — absolute position of each token in its prompt
    hist        [C] int32 — per-token history boundary: packed pages for
                kpos < hist, float in-chunk keys for kpos in [hist, pos]
    tile_seq    [C/bq] int32 — slot owning each aligned query tile (-1 =
                padding tile); the stream packs each sequence's run
                aligned to bq so one tile gathers one block-table row
    Returns f32 [C, KV, G, hd]; fully-masked (padding) rows are zeros.
    """
    C, KV, G, hd = q.shape
    ps = k_data.shape[1]
    NB = block_table.shape[1]
    nt = tile_seq.shape[0]
    assert C % nt == 0, (C, nt)
    bq = C // nt
    qf = q.astype(jnp.float32)
    sm_scale = hd ** -0.5
    tseq = jnp.repeat(jnp.asarray(tile_seq, jnp.int32), bq)        # [C]
    s_safe = jnp.maximum(tseq, 0)
    ksc = jnp.asarray(k_scale, jnp.float32)[s_safe]                # [C]
    vsc = jnp.asarray(v_scale, jnp.float32)[s_safe]
    qhist = jnp.asarray(hist, jnp.int32)                           # [C]
    sid = jnp.asarray(seq_id, jnp.int32)
    qpos = jnp.asarray(pos, jnp.int32)
    qvalid = sid >= 0

    def upd(m, l, s, ok):
        """Shared online-softmax statistics update. Returns the new
        (m, l), the correction factor for the running accumulator, and
        the masked probabilities p (the caller contracts p @ V — the two
        stages gather V with different shapes)."""
        okb = ok[:, None, None, :]                 # [C, 1, 1, keys]
        s = jnp.where(okb, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(okb, p, 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        return m_new, l_new, corr, p

    def tile(carry, t):
        m, l, acc = carry
        pages = block_table[s_safe, t]             # [C]
        pg = jnp.maximum(pages, 0)
        k = _meta_decode32(k_data[pg], k_meta[pg],
                           ksc[:, None, None, None])   # [C, ps, KV, hd]
        s = jnp.einsum("ckgh,cskh->ckgs", qf, k,
                       preferred_element_type=jnp.float32) * sm_scale
        kp = t * ps + jnp.arange(ps, dtype=jnp.int32)[None]    # [1, ps]
        ok = (pages >= 0)[:, None] & qvalid[:, None] & (kp < qhist[:, None])
        if window:
            ok &= kp > qpos[:, None] - window
        m, l, corr, p = upd(m, l, s, ok)
        v = _meta_decode32(v_data[pg], v_meta[pg], vsc[:, None, None, None])
        pv = jnp.einsum("ckgs,cskh->ckgh", p, v,
                        preferred_element_type=jnp.float32)
        return (m, l, acc * corr + pv), None

    m0 = jnp.full((C, KV, G, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((C, KV, G, 1), jnp.float32)
    a0 = jnp.zeros((C, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(tile, (m0, l0, a0), jnp.arange(NB))

    # in-chunk causal stage: float K/V, segment mask by sequence id
    kcf = k_chunk.astype(jnp.float32)
    vcf = v_chunk.astype(jnp.float32)
    s = jnp.einsum("ckgh,jkh->ckgj", qf, kcf,
                   preferred_element_type=jnp.float32) * sm_scale
    ok = (sid[None, :] == sid[:, None]) & qvalid[:, None] \
        & (qpos[None, :] <= qpos[:, None]) \
        & (qpos[None, :] >= qhist[:, None])
    if window:
        ok &= qpos[None, :] > qpos[:, None] - window
    m, l, corr, p = upd(m, l, s, ok)
    pv = jnp.einsum("ckgj,jkh->ckgh", p, vcf,
                    preferred_element_type=jnp.float32)
    acc = acc * corr + pv
    return acc / jnp.maximum(l, 1e-30)


def ref_sparq_paged_decode_attn(q, k_data, k_meta, k_scale, v_data, v_meta,
                                v_scale, block_table, cur, *,
                                window: int = 0):
    """Tiled oracle for sparq_paged_decode_attn_pallas: the block-table
    gather path over a global page pool. One Tk tile == one fixed-size page,
    fetched through the per-sequence block table; everything else (per-tile
    §5.1 meta-decode, online-softmax update order, masking arithmetic) is
    the contiguous oracle's, so with page_size == bk and identical packed
    bytes the two paths agree bit for bit.

    q           [B, KV, G, hd] float — one query token per sequence
    k/v planes  [P, ps, KV, hd] int8 — the global page pool (any page the
                block table never names, e.g. a trash page, is simply dead)
    k/v scale   [B] f32 — per-sequence site scales
    block_table [B, NB] int32 — physical page per logical block (-1 = not
                allocated; masked out, gather index clamped to 0)
    cur         [B] int32 — per-sequence position of the decoded token
                (-1/-2 = inactive slot: fully masked, output 0)
    Returns f32 [B, KV, G, hd].
    """
    B, KV, G, hd = q.shape
    ps = k_data.shape[1]
    NB = block_table.shape[1]
    qf = q.astype(jnp.float32)
    sm_scale = hd ** -0.5
    k_scale = jnp.asarray(k_scale, jnp.float32).reshape(B, 1, 1, 1)
    v_scale = jnp.asarray(v_scale, jnp.float32).reshape(B, 1, 1, 1)
    cur_b = jnp.asarray(cur, jnp.int32).reshape(B, 1)

    def tile(carry, t):
        m, l, acc = carry
        pages = jax.lax.dynamic_slice_in_dim(block_table, t, 1, 1)[:, 0]
        safe = jnp.maximum(pages, 0)                   # [B]
        k = _meta_decode32(k_data[safe], k_meta[safe], k_scale)
        s = jnp.einsum("bkgh,bskh->bkgs", qf, k,
                       preferred_element_type=jnp.float32) * sm_scale
        kp = t * ps + jnp.arange(ps, dtype=jnp.int32)[None]    # [1, ps]
        ok = (pages >= 0)[:, None] & (kp <= cur_b)
        if window:
            ok &= kp > cur_b - window
        okb = ok[:, None, None, :]                     # [B, 1, 1, ps]
        s = jnp.where(okb, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(okb, p, 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = _meta_decode32(v_data[safe], v_meta[safe], v_scale)
        pv = jnp.einsum("bkgs,bskh->bkgh", p, v,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr + pv), None

    m0 = jnp.full((B, KV, G, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, 1), jnp.float32)
    a0 = jnp.zeros((B, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(tile, (m0, l0, a0), jnp.arange(NB))
    return acc / jnp.maximum(l, 1e-30)
