"""Standalone SPARQ quantization Pallas kernel (KV-cache / storage path).

Quantizes a float tile to SPARQ codes and emits (a) the reconstructed
integer codes as int8 ready for an integer matmul, and (b) packed metadata:
for each pair of lanes one byte holding [mux(1) | shift_hi(3) | shift_lo(3)]
— the paper's MuxCtrl + ShiftCtrl (§5.1 footprint discussion). The data
nibbles themselves would pack 2-per-byte on real hardware; we keep recon
codes unpacked int8 here because the MXU consumes 8-bit operands anyway
(the packed format only matters for HBM residency, which `bytes_per_value`
in ops.py models for the roofline analysis).

Grid is 1-D over row tiles; the lane (last) axis is the pairing axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import MemorySpace as _MemorySpace

from repro.core.bsparq import bsparq_encode


def _kernel(x_ref, ascale_ref, codes_ref, meta_ref, *,
            bits, shifts, rounding, vsparq, signed, max_val, enabled):
    a = ascale_ref[0, 0]
    x = x_ref[...]
    qmin = -max_val if signed else 0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / a), qmin, max_val)
    q = q.astype(jnp.int32)
    if not enabled:
        # plain int8 PTQ (paper baseline): full codes, empty meta
        codes_ref[...] = q.astype(jnp.int8)
        meta_ref[...] = jnp.zeros_like(q, dtype=jnp.int8)
        return
    sign = jnp.sign(q)
    mag = jnp.abs(q)
    qq, ss = bsparq_encode(mag, bits, shifts, rounding, max_val)
    trimmed = jnp.left_shift(qq, ss)
    if vsparq:
        sz = mag.shape[1]
        left = pltpu.roll(mag, sz - 1, axis=1)  # lane i -> holds mag[i+1]
        right = pltpu.roll(mag, 1, axis=1)      # lane i -> holds mag[i-1]
        lane = jax.lax.broadcasted_iota(jnp.int32, mag.shape, dimension=1)
        even = lane % 2 == 0
        partner = jnp.where(even, left, right)
        full = partner == 0
        recon = jnp.where(full, mag, trimmed)
        shift_code = jnp.where(full, 0, ss)
        mux = full
    else:
        recon = trimmed
        shift_code = ss
        mux = jnp.zeros_like(mag, dtype=jnp.bool_)
    codes_ref[...] = (sign * recon).astype(jnp.int8)
    # pack per-pair meta byte: [mux_any(1) | shift_even(3) | shift_odd(3)],
    # computed on even lanes and mirrored to odd lanes (storage would keep
    # even lanes only: 7 meta bits per pair, the paper's §5.1 footprint).
    lane = jax.lax.broadcasted_iota(jnp.int32, mag.shape, dimension=1)
    even = lane % 2 == 0
    mux_i = mux.astype(jnp.int32)
    szk = mag.shape[1]
    mux_any = jnp.minimum(mux_i + pltpu.roll(mux_i, szk - 1, axis=1), 1)
    s_next = pltpu.roll(shift_code, szk - 1, axis=1)  # lane i: shift[i+1]
    meta_even = mux_any * 64 + shift_code * 8 + s_next
    meta = jnp.where(even, meta_even, pltpu.roll(meta_even, 1, axis=1))
    meta_ref[...] = meta.astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "opts_shifts", "rounding", "vsparq", "signed",
                     "max_val", "enabled", "bm", "interpret"))
def sparq_quant_pallas(
    x: jnp.ndarray,           # (M, K) float
    act_scale: jnp.ndarray,   # scalar f32
    *,
    bits: int = 4,
    opts_shifts: tuple[int, ...] = (0, 1, 2, 3, 4),
    rounding: bool = True,
    vsparq: bool = True,
    signed: bool = True,
    max_val: int = 127,
    enabled: bool = True,
    bm: int = 256,
    interpret: bool = False,
):
    """Returns (codes int8 [M,K] — SPARQ-reconstructed integer values,
    meta int8 [M,K] — per-lane packed ShiftCtrl/MuxCtrl byte)."""
    M, K = x.shape
    assert M % bm == 0 and K % 2 == 0, (M, K, bm)
    kernel = functools.partial(
        _kernel, bits=bits, shifts=opts_shifts, rounding=rounding,
        vsparq=vsparq, signed=signed, max_val=max_val, enabled=enabled)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda m: (m, 0)),
            pl.BlockSpec((1, 1), lambda m: (0, 0),
                         memory_space=_MemorySpace.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((bm, K), lambda m: (m, 0)),
            pl.BlockSpec((bm, K), lambda m: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M, K), jnp.int8),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, act_scale.reshape(1, 1))
