"""jax version compatibility for Pallas TPU symbols.

jax renamed TPUCompilerParams/TPUMemorySpace -> CompilerParams/MemorySpace
around 0.5; resolve whichever spelling this jax provides, in one place.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
MemorySpace = getattr(pltpu, "MemorySpace",
                      getattr(pltpu, "TPUMemorySpace", None))

if CompilerParams is None or MemorySpace is None:  # pragma: no cover
    raise ImportError(
        f"jax {jax.__version__}: pallas.tpu exposes neither the new "
        "(CompilerParams/MemorySpace) nor the old (TPU*) spellings; "
        "update repro.kernels._compat for this version")
