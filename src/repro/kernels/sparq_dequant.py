"""Standalone SPARQ meta-decode Pallas kernel (KV-cache read path).

Inverse of `sparq_quant.sparq_quant_pallas` + `ops.sparq_pack`: takes the
stored int8 window codes (sign-magnitude data nibbles; full 8-bit magnitude
on vSPARQ mux'd lanes, whose ShiftCtrl is 0) and the packed per-pair meta
byte [mux(1) | shift_hi(3) | shift_lo(3)] mirrored to both lanes, and
reconstructs the SPARQ integer codes:

    codes[i] = sign(store[i]) * (|store[i]| << shift[i]),
    shift[i] = meta[i]>>3 & 7 on even lanes, meta[i] & 7 on odd lanes.

This is the §5.1 decode datapath the paper's memory-footprint argument
rests on — the cache holds (n + 3 + ½)-bit values, the MXU consumes 8-bit
reconstructions. Grid is 1-D over row tiles; lane axis is the pair axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(store_ref, meta_ref, codes_ref):
    q = store_ref[...].astype(jnp.int32)
    m = meta_ref[...].astype(jnp.int32)
    lane = jax.lax.broadcasted_iota(jnp.int32, m.shape, dimension=1)
    shift = jnp.where(lane % 2 == 0, jnp.right_shift(m, 3) & 7, m & 7)
    recon = jnp.left_shift(jnp.abs(q), shift)
    codes_ref[...] = (jnp.sign(q) * recon).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def sparq_dequant_pallas(
    store: jnp.ndarray,       # (M, K) int8 window codes
    meta: jnp.ndarray,        # (M, K) int8 packed ShiftCtrl/MuxCtrl bytes
    *,
    bm: int = 256,
    interpret: bool = False,
):
    """Returns int8 (M, K): SPARQ-reconstructed integer codes."""
    M, K = store.shape
    assert store.shape == meta.shape, (store.shape, meta.shape)
    assert M % bm == 0 and K % 2 == 0, (M, K, bm)
    return pl.pallas_call(
        _kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda m: (m, 0)),
            pl.BlockSpec((bm, K), lambda m: (m, 0)),
        ],
        out_specs=pl.BlockSpec((bm, K), lambda m: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((M, K), jnp.int8),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(store, meta)
