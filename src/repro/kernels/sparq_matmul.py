"""Fused SPARQ quantize + matmul Pallas TPU kernel.

This is the TPU-native adaptation of the paper's PE datapath (Fig. 2,
DESIGN.md §3): the dynamic quantization chain (min-max quantize ->
vSPARQ pair test -> bSPARQ window select -> round) runs on the VPU over
VMEM-resident tiles, immediately before the MXU contraction, so the
activation tensor is read from HBM exactly once and SPARQ costs no extra
memory traffic. Products accumulate in an int32 VMEM scratch (the psum
register of the paper's PE); per-output-channel weight scales and the
per-tensor activation scale are applied once on the final K step.

vSPARQ pairing is implemented with a lane roll instead of a reshape:
partner(i) = x[i+1] for even lanes, x[i-1] for odd lanes — a pure
elementwise select after `pltpu.roll`, which keeps the tile in its native
(sublane, lane) layout (no relayout between the VPU chain and the MXU).

Tile sizes default to (128, 128, 512): MXU-aligned 128s, and a K tile
chosen so x(128x512 f32) + w(512x128 int8) + acc(128x128 i32) + recon
(128x512 i32) stay well under VMEM (~16 MiB on v5e).

Semantics notes:
  * The reduction (K) axis must be even (vSPARQ pairs adjacent K lanes) and
    the K tile must be even so pairs never straddle tiles.
  * Zero padding of K is safe only in whole pairs (handled by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import MemorySpace as _MemorySpace

from repro.core.bsparq import bsparq_recon


def _recon_tile(q: jnp.ndarray, *, bits: int, shifts: tuple[int, ...],
                rounding: bool, vsparq: bool, signed: bool,
                max_val: int) -> jnp.ndarray:
    """SPARQ reconstruction of an int32 code tile (sublane, lane=K)."""
    if signed:
        sign = jnp.sign(q)
        mag = jnp.abs(q)
    else:
        sign, mag = None, q
    trimmed = bsparq_recon(mag, bits, shifts, rounding, max_val)
    if vsparq:
        # partner(i) = mag[i+1] on even lanes, mag[i-1] on odd lanes
        sz = mag.shape[1]
        left = pltpu.roll(mag, sz - 1, axis=1)  # lane i -> holds mag[i+1]
        right = pltpu.roll(mag, 1, axis=1)      # lane i -> holds mag[i-1]
        lane = jax.lax.broadcasted_iota(jnp.int32, mag.shape, dimension=1)
        partner = jnp.where(lane % 2 == 0, left, right)
        recon = jnp.where(partner == 0, mag, trimmed)  # Eq. (2)
    else:
        recon = trimmed
    return recon if sign is None else sign * recon


def _kernel(x_ref, w_ref, ascale_ref, cscale_ref, o_ref, acc_ref, *,
            bits, shifts, rounding, vsparq, signed, max_val, enabled):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = ascale_ref[0, 0]
    x = x_ref[...]
    qmax = max_val
    qmin = -max_val if signed else 0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / a), qmin, qmax)
    q = q.astype(jnp.int32)
    if enabled:
        q = _recon_tile(q, bits=bits, shifts=shifts, rounding=rounding,
                        vsparq=vsparq, signed=signed, max_val=max_val)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        q, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _emit():
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * a *
                      cscale_ref[...].astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("bits", "opts_shifts", "rounding", "vsparq", "signed",
                     "max_val", "enabled", "bm", "bn", "bk", "interpret"))
def sparq_matmul_pallas(
    x: jnp.ndarray,            # (M, K) float32/bfloat16 activations
    w_codes: jnp.ndarray,      # (K, N) int8 weight codes
    act_scale: jnp.ndarray,    # scalar f32
    chan_scale: jnp.ndarray,   # (N,) f32 per-output-channel weight scales
    *,
    bits: int = 4,
    opts_shifts: tuple[int, ...] = (0, 1, 2, 3, 4),
    rounding: bool = True,
    vsparq: bool = True,
    signed: bool = False,
    max_val: int = 255,
    enabled: bool = True,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = w_codes.shape
    assert K == K2, (K, K2)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"pad to tiles first: {(M, K, N)} vs {(bm, bk, bn)}"
    assert bk % 2 == 0, "K tile must be even (vSPARQ pairs adjacent lanes)"

    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(
        _kernel, bits=bits, shifts=opts_shifts, rounding=rounding,
        vsparq=vsparq, signed=signed, max_val=max_val, enabled=enabled)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0),
                         memory_space=_MemorySpace.SMEM),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_codes, act_scale.reshape(1, 1), chan_scale.reshape(1, N))
