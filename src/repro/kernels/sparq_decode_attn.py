"""Fused flash-decode attention over the packed SPARQ KV cache.

This is the kernel the §5.1 footprint argument needs to be *true*: the
decode hot path streams the cache's raw storage — int8 window codes plus
the packed per-pair meta byte [mux(1) | shift_hi(3) | shift_lo(3)] — from
HBM and performs the meta-decode (|code| << ShiftCtrl, sign reapplied;
mux'd vSPARQ lanes pass through at shift 0) *inside* the Tk-tile loop,
fused with the online-softmax QK/PV accumulation. The fp32 K/V planes are
never materialized: each tile is decoded in VMEM, contracted, and dropped.
`CachedTensor.read()` (the full-plane dequantize) remains only as the
prefill/debug fallback.

Shapes and grid:
  q        [B, KV, G, hd]   one query token, GQA via head grouping
  k/v data [B, Tk, KV, hd]  int8 window codes (§5.1 data plane)
  k/v meta [B, Tk, KV, hd]  int8 packed ShiftCtrl/MuxCtrl bytes
  kpos     [B, Tk]          absolute position per cache slot (-1 = empty)
  cur      scalar int32     position of the token being decoded

grid = (B, KV, Tk/bk); the Tk axis is sequential ("arbitrary") and carries
flash statistics (m, l, acc) in VMEM scratch; B and KV are parallel. The
same kernel serves the linear cache (kpos = arange, masked by kpos <= cur)
and the sliding-window ring cache (kpos = slot_pos, plus the static
`window` bound) — masking is pure position arithmetic, so ring slot order
never needs unrotating.

The lane (last) axis is hd — the vSPARQ pairing axis of the cache planes —
so ShiftCtrl extraction is a parity select on the lane index, exactly as in
`sparq_dequant._kernel`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import MemorySpace as _MemorySpace
from repro.kernels.ref import meta_shifts


def _meta_decode_f32(store, meta, scale):
    """int8 (codes, meta) tile -> f32 values tile (lane axis = pair axis).
    Pure jnp (meta_shifts is shared with the ref oracle and sparq_pack),
    so it traces inside the Pallas kernel body unchanged."""
    q = store.astype(jnp.int32)
    recon = jnp.sign(q) * jnp.left_shift(jnp.abs(q), meta_shifts(meta))
    return recon.astype(jnp.float32) * scale


def _flash_tile_body(q_ref, o_ref, m_ref, l_ref, acc_ref, k, v, ok, *,
                     sm_scale: float):
    """One Tk-tile online-softmax update, shared by the contiguous and
    paged kernels (which differ only in how they fetch the K/V tile and
    build the `ok` mask). Grid axis 2 is the sequential tile axis; the
    flash statistics (m, l, acc) persist in VMEM scratch across tiles.
    Keeping this arithmetic in one place is what keeps the two kernels'
    bit-identity guarantee honest — the f32 op sequence cannot drift."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [G, hd]
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale     # [G, bk]
    s = jnp.where(ok, s, -jnp.inf)

    m_prev = m_ref[...]                                    # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)

    pv = jax.lax.dot_general(
        p, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [G, hd]
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(t == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _kernel(q_ref, kd_ref, km_ref, vd_ref, vm_ref, kpos_ref, cur_ref,
            kscale_ref, vscale_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, sm_scale: float):
    k = _meta_decode_f32(kd_ref[0, :, 0], km_ref[0, :, 0],
                         kscale_ref[0, 0])                 # [bk, hd]
    v = _meta_decode_f32(vd_ref[0, :, 0], vm_ref[0, :, 0],
                         vscale_ref[0, 0])
    kpos = kpos_ref[...]                                   # [1, bk]
    cur = cur_ref[0, 0]
    ok = (kpos >= 0) & (kpos <= cur)
    if window:
        ok &= kpos > cur - window
    _flash_tile_body(q_ref, o_ref, m_ref, l_ref, acc_ref, k, v, ok,
                     sm_scale=sm_scale)


@functools.partial(jax.jit,
                   static_argnames=("window", "bk", "interpret"))
def sparq_decode_attn_pallas(
    q: jnp.ndarray,           # (B, KV, G, hd) float
    k_data: jnp.ndarray,      # (B, Tk, KV, hd) int8 window codes
    k_meta: jnp.ndarray,      # (B, Tk, KV, hd) int8 packed meta bytes
    k_scale: jnp.ndarray,     # scalar f32 per-site scale
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    kpos: jnp.ndarray,        # (B, Tk) int32 slot positions (-1 empty)
    cur: jnp.ndarray,         # scalar int32 query-token position
    *,
    window: int = 0,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns f32 (B, KV, G, hd) attention output."""
    B, KV, G, hd = q.shape
    Tk = k_data.shape[1]
    assert k_data.shape == (B, Tk, KV, hd), (q.shape, k_data.shape)
    assert Tk % bk == 0 and hd % 2 == 0, (Tk, bk, hd)
    kernel = functools.partial(_kernel, window=window,
                               sm_scale=hd ** -0.5)
    plane = pl.BlockSpec((1, bk, 1, hd), lambda b, kv, t: (b, t, kv, 0))
    smem = pl.BlockSpec((1, 1), lambda b, kv, t: (0, 0),
                        memory_space=_MemorySpace.SMEM)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, t: (b, kv, 0, 0)),
            plane, plane, plane, plane,
            pl.BlockSpec((1, bk), lambda b, kv, t: (b, t)),
            smem, smem, smem,
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kv, t: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m: running max
            pltpu.VMEM((G, 1), jnp.float32),    # l: running denominator
            pltpu.VMEM((G, hd), jnp.float32),   # acc: running numerator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k_data, k_meta, v_data, v_meta, kpos,
      cur.reshape(1, 1), k_scale.reshape(1, 1), v_scale.reshape(1, 1))


# ----------------------------------------------------------------------
# paged variant: block-table gather over a global page pool
# ----------------------------------------------------------------------

def _paged_kernel(bt_ref, cur_ref, ks_ref, vs_ref,       # scalar prefetch
                  q_ref, kd_ref, km_ref, vd_ref, vm_ref,  # tensor inputs
                  o_ref, m_ref, l_ref, acc_ref, *,
                  window: int, sm_scale: float, ps: int):
    b = pl.program_id(0)
    t = pl.program_id(2)
    k = _meta_decode_f32(kd_ref[0, :, 0], km_ref[0, :, 0],
                         ks_ref[b])                        # [ps, hd]
    v = _meta_decode_f32(vd_ref[0, :, 0], vm_ref[0, :, 0],
                         vs_ref[b])
    # logical slot positions of this page: block t covers [t*ps, (t+1)*ps)
    kpos = t * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    cur = cur_ref[b]
    ok = (bt_ref[b, t] >= 0) & (kpos <= cur)
    if window:
        ok &= kpos > cur - window
    _flash_tile_body(q_ref, o_ref, m_ref, l_ref, acc_ref, k, v, ok,
                     sm_scale=sm_scale)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def sparq_paged_decode_attn_pallas(
    q: jnp.ndarray,           # (B, KV, G, hd) float, one token per sequence
    k_data: jnp.ndarray,      # (P, ps, KV, hd) int8 window-code page pool
    k_meta: jnp.ndarray,      # (P, ps, KV, hd) int8 packed meta-byte pool
    k_scale: jnp.ndarray,     # (B,) f32 per-sequence site scales
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_table: jnp.ndarray,  # (B, NB) int32 page per block (-1 = unset)
    cur: jnp.ndarray,         # (B,) int32 per-sequence decoded position
    *,
    window: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged variant of `sparq_decode_attn_pallas`: the K/V planes live in a
    global pool of fixed-size pages and each sequence's Tk tiles are fetched
    through its block table, prefetched as scalars so the BlockSpec index
    maps can name the physical page each grid step streams from HBM. The
    Tk-tile loop runs over logical blocks (one page == one tile); slot
    positions are computed from the block index, so masking/GQA/window logic
    is unchanged from the contiguous kernel — with page_size == bk the two
    are bit-identical on identical packed bytes.

    Per-sequence `cur` and `k/v_scale` (continuous batching: every active
    slot has its own length and its own calibration) ride along as scalar-
    prefetch arguments; unallocated block-table entries are clamped to page
    0 for the gather and masked out by `bt >= 0`. Returns f32 (B,KV,G,hd).
    """
    B, KV, G, hd = q.shape
    P, ps = k_data.shape[:2]
    NB = block_table.shape[1]
    assert k_data.shape == (P, ps, KV, hd), (q.shape, k_data.shape)
    assert hd % 2 == 0, hd
    kernel = functools.partial(_paged_kernel, window=window,
                               sm_scale=hd ** -0.5, ps=ps)
    plane = pl.BlockSpec(
        (1, ps, 1, hd),
        lambda b, kv, t, bt, cur, ks, vs: (jnp.maximum(bt[b, t], 0), 0,
                                           kv, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,      # block_table, cur, k_scale, v_scale
        grid=(B, KV, NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, kv, t, bt, cur, ks, vs: (b, kv, 0, 0)),
            plane, plane, plane, plane,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, kv, t, bt, cur, ks, vs: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m: running max
            pltpu.VMEM((G, 1), jnp.float32),    # l: running denominator
            pltpu.VMEM((G, hd), jnp.float32),   # acc: running numerator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), cur.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      q, k_data, k_meta, v_data, v_meta)
