"""Fused flash-decode attention over the packed SPARQ KV cache.

This is the kernel the §5.1 footprint argument needs to be *true*: the
decode hot path streams the cache's raw storage — int8 window codes plus
the packed per-pair meta byte [mux(1) | shift_hi(3) | shift_lo(3)] — from
HBM and performs the meta-decode (|code| << ShiftCtrl, sign reapplied;
mux'd vSPARQ lanes pass through at shift 0) *inside* the Tk-tile loop,
fused with the online-softmax QK/PV accumulation. The fp32 K/V planes are
never materialized: each tile is decoded in VMEM, contracted, and dropped.
`CachedTensor.read()` (the full-plane dequantize) remains only as the
prefill/debug fallback.

Shapes and grid:
  q        [B, KV, G, hd]   one query token, GQA via head grouping
  k/v data [B, Tk, KV, hd]  int8 window codes (§5.1 data plane)
  k/v meta [B, Tk, KV, hd]  int8 packed ShiftCtrl/MuxCtrl bytes
  kpos     [B, Tk]          absolute position per cache slot (-1 = empty)
  cur      scalar int32     position of the token being decoded

grid = (B, KV, Tk/bk); the Tk axis is sequential ("arbitrary") and carries
flash statistics (m, l, acc) in VMEM scratch; B and KV are parallel. The
same kernel serves the linear cache (kpos = arange, masked by kpos <= cur)
and the sliding-window ring cache (kpos = slot_pos, plus the static
`window` bound) — masking is pure position arithmetic, so ring slot order
never needs unrotating.

The lane (last) axis is hd — the vSPARQ pairing axis of the cache planes —
so ShiftCtrl extraction is a parity select on the lane index, exactly as in
`sparq_dequant._kernel`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels._compat import MemorySpace as _MemorySpace
from repro.kernels.ref import meta_shifts


def _meta_decode_f32(store, meta, scale):
    """int8 (codes, meta) tile -> f32 values tile (lane axis = pair axis).
    Pure jnp (meta_shifts is shared with the ref oracle and sparq_pack),
    so it traces inside the Pallas kernel body unchanged."""
    q = store.astype(jnp.int32)
    recon = jnp.sign(q) * jnp.left_shift(jnp.abs(q), meta_shifts(meta))
    return recon.astype(jnp.float32) * scale


def _kernel(q_ref, kd_ref, km_ref, vd_ref, vm_ref, kpos_ref, cur_ref,
            kscale_ref, vscale_ref, o_ref, m_ref, l_ref, acc_ref, *,
            window: int, sm_scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # [G, hd]
    k = _meta_decode_f32(kd_ref[0, :, 0], km_ref[0, :, 0],
                         kscale_ref[0, 0])                 # [bk, hd]
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale     # [G, bk]

    kpos = kpos_ref[...]                                   # [1, bk]
    cur = cur_ref[0, 0]
    ok = (kpos >= 0) & (kpos <= cur)
    if window:
        ok &= kpos > cur - window
    s = jnp.where(ok, s, -jnp.inf)

    m_prev = m_ref[...]                                    # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)

    v = _meta_decode_f32(vd_ref[0, :, 0], vm_ref[0, :, 0],
                         vscale_ref[0, 0])                 # [bk, hd]
    pv = jax.lax.dot_general(
        p, v, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [G, hd]
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(t == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit,
                   static_argnames=("window", "bk", "interpret"))
def sparq_decode_attn_pallas(
    q: jnp.ndarray,           # (B, KV, G, hd) float
    k_data: jnp.ndarray,      # (B, Tk, KV, hd) int8 window codes
    k_meta: jnp.ndarray,      # (B, Tk, KV, hd) int8 packed meta bytes
    k_scale: jnp.ndarray,     # scalar f32 per-site scale
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    kpos: jnp.ndarray,        # (B, Tk) int32 slot positions (-1 empty)
    cur: jnp.ndarray,         # scalar int32 query-token position
    *,
    window: int = 0,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns f32 (B, KV, G, hd) attention output."""
    B, KV, G, hd = q.shape
    Tk = k_data.shape[1]
    assert k_data.shape == (B, Tk, KV, hd), (q.shape, k_data.shape)
    assert Tk % bk == 0 and hd % 2 == 0, (Tk, bk, hd)
    kernel = functools.partial(_kernel, window=window,
                               sm_scale=hd ** -0.5)
    plane = pl.BlockSpec((1, bk, 1, hd), lambda b, kv, t: (b, t, kv, 0))
    smem = pl.BlockSpec((1, 1), lambda b, kv, t: (0, 0),
                        memory_space=_MemorySpace.SMEM)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, t: (b, kv, 0, 0)),
            plane, plane, plane, plane,
            pl.BlockSpec((1, bk), lambda b, kv, t: (b, t)),
            smem, smem, smem,
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, kv, t: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m: running max
            pltpu.VMEM((G, 1), jnp.float32),    # l: running denominator
            pltpu.VMEM((G, hd), jnp.float32),   # acc: running numerator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k_data, k_meta, v_data, v_meta, kpos,
      cur.reshape(1, 1), k_scale.reshape(1, 1), v_scale.reshape(1, 1))
