"""Pallas TPU kernels for SPARQ's compute hot-spot (the quantized matmul)
and the §5.1 packed KV-cache storage path (quantize + meta-decode + fused
packed-cache decode attention)."""
from repro.kernels.ops import (bytes_per_value, ctrl_bytes_per_value,
                               data_bytes_per_value, quantized_matmul,
                               sparq_decode_attention, sparq_dequantize,
                               sparq_pack, sparq_quantize)
from repro.kernels.sparq_decode_attn import sparq_decode_attn_pallas
from repro.kernels.sparq_dequant import sparq_dequant_pallas
from repro.kernels.sparq_matmul import sparq_matmul_pallas
from repro.kernels.sparq_quant import sparq_quant_pallas

__all__ = ["quantized_matmul", "sparq_quantize", "sparq_dequantize",
           "sparq_pack", "sparq_decode_attention", "bytes_per_value",
           "data_bytes_per_value", "ctrl_bytes_per_value",
           "sparq_matmul_pallas", "sparq_quant_pallas",
           "sparq_dequant_pallas", "sparq_decode_attn_pallas"]
