"""Pallas TPU kernels for SPARQ's compute hot-spot (the quantized matmul)."""
from repro.kernels.ops import quantized_matmul, sparq_quantize, bytes_per_value
from repro.kernels.sparq_matmul import sparq_matmul_pallas
from repro.kernels.sparq_quant import sparq_quant_pallas

__all__ = ["quantized_matmul", "sparq_quantize", "bytes_per_value",
           "sparq_matmul_pallas", "sparq_quant_pallas"]
