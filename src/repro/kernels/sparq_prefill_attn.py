"""Ragged chunked-prefill flash attention over the packed SPARQ page pool.

This is the kernel behind `--prefill chunked`: admission packs ragged
pending prompts into a fixed-shape token stream (per-token (seq_id, pos)
metadata, each sequence's run aligned to the `bq` query tile), and ONE
jitted program processes every chunk — no per-prompt-length retraces.
Each chunk token attends to

  1. its sequence's already-written §5.1 packed pages — every position
     below the token's per-token history boundary `hist` — gathered
     through the per-slot block table with the same scalar-prefetch
     pattern as the paged decode kernel: one page == one Tk tile, §5.1
     meta-decode (window << ShiftCtrl, mux'd-lane passthrough, per-slot
     scale) fused inside the tile loop; and
  2. the float K/V of its history window [hist, pos] inside the chunk:
     causal attention segment-masked by sequence id (tokens of different
     prompts never see each other) and bounded below by hist.

The scheduler sets hist to the token's *segment* start ((pos // seg) *
seg) and packs whole segments only, so a prompt's float-vs-packed
attention split depends only on the prompt and the segment quantum —
never on how the stream happened to be packed. That invariance is what
keeps chunked prefill deterministic per request (and requeue-replay
resume bit-exact) under any join pattern, pool size, or preemption
schedule.

Shapes and grid:
  q          [C, KV, G, hd]  chunk queries, GQA via head grouping
  k/v_chunk  [C, KV, hd]     the chunk's own float K/V
  k/v pools  [P, ps, KV, hd] int8 §5.1 planes (global page pool)
  seq_id/pos [1, C]          per-token stream metadata (-1 = padding)
  hist       [1, C]          per-token history boundary (pages < hist)
  tile_seq   [nt]            slot owning each bq-aligned query tile

grid = (C/bq, KV, NB + 1): stages 0..NB-1 stream the tile's sequence's
pages (ascending kpos), stage NB is the in-chunk causal stage; the stage
axis is sequential ("arbitrary") and carries the flash statistics
(m, l, acc) in VMEM scratch, with one row per (token, group) pair. The
stage order and f32 update arithmetic mirror
`kernels.ref.ref_sparq_chunked_prefill_attn` op for op. Interpret-mode
outputs agree with the oracle to within a couple of f32 ulps (XLA fuses
the oracle's scanned multiply-add chain differently from the
interpreter's op-by-op execution); the in-chunk stage alone is exact,
and each engine run uses one impl throughout, so the serving-level
greedy-token-equality guarantees are unaffected.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.sparq_decode_attn import _meta_decode_f32


def _kernel(tile_seq_ref, bt_ref, ks_ref, vs_ref,          # scalar pref.
            q_ref, qseq_ref, qpos_ref, qhist_ref, kseq_ref, kpos_ref,
            kc_ref, vc_ref, kd_ref, km_ref, vd_ref, vm_ref,
            o_ref, m_ref, l_ref, acc_ref, *,
            window: int, sm_scale: float, ps: int, nb: int):
    qt = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s_tile = jnp.maximum(tile_seq_ref[qt], 0)
    q = q_ref[:, 0].astype(jnp.float32)                # [bq, G, hd]
    bq, G, hd = q.shape
    q2 = q.reshape(bq * G, hd)
    qseq = qseq_ref[0]                                 # [bq]
    qpos = qpos_ref[0]
    qhist = qhist_ref[0]
    qvalid = qseq >= 0

    def update(k, v, ok):
        """One online-softmax tile update on [bq*G] rows; the mask `ok`
        is per (token, key) and fans out over the G group rows. Identical
        op order to the oracle's `upd` (and the decode kernels')."""
        ok2 = jnp.repeat(ok, G, axis=0)                # [bq*G, keys]
        s = jax.lax.dot_general(
            q2, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = jnp.where(ok2, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(ok2, p, 0.0)
        corr = jnp.where(jnp.isneginf(m_prev), 0.0,
                         jnp.exp(m_prev - m_safe))
        l_new = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(t < nb)
    def _page_stage():
        k = _meta_decode_f32(kd_ref[0, :, 0], km_ref[0, :, 0],
                             ks_ref[s_tile])           # [ps, hd]
        v = _meta_decode_f32(vd_ref[0, :, 0], vm_ref[0, :, 0],
                             vs_ref[s_tile])
        kp = t * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        ok = (bt_ref[s_tile, t] >= 0) & qvalid[:, None] \
            & (kp < qhist[:, None])                    # [bq, ps]
        if window:
            ok &= kp > qpos[:, None] - window
        update(k, v, ok)

    @pl.when(t == nb)
    def _chunk_stage():
        k = kc_ref[:, 0].astype(jnp.float32)           # [C, hd]
        v = vc_ref[:, 0].astype(jnp.float32)
        kseq = kseq_ref[0]                             # [C]
        kpos = kpos_ref[0]
        ok = (kseq[None, :] == qseq[:, None]) & qvalid[:, None] \
            & (kpos[None, :] <= qpos[:, None]) \
            & (kpos[None, :] >= qhist[:, None])        # [bq, C]
        if window:
            ok &= kpos[None, :] > qpos[:, None] - window
        update(k, v, ok)
        o_ref[:, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).reshape(bq, G, hd)


@functools.partial(jax.jit,
                   static_argnames=("window", "bq", "interpret"))
def sparq_chunked_prefill_attn_pallas(
    q: jnp.ndarray,            # (C, KV, G, hd) float — chunk queries
    k_chunk: jnp.ndarray,      # (C, KV, hd) float — chunk K (pre-quant)
    v_chunk: jnp.ndarray,      # (C, KV, hd) float
    k_data: jnp.ndarray,       # (P, ps, KV, hd) int8 window-code pool
    k_meta: jnp.ndarray,       # (P, ps, KV, hd) int8 meta-byte pool
    k_scale: jnp.ndarray,      # (S,) f32 per-slot site scales
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_table: jnp.ndarray,  # (S, NB) int32 page per block (-1 unset)
    seq_id: jnp.ndarray,       # (C,) int32 slot per token (-1 padding)
    pos: jnp.ndarray,          # (C,) int32 position per token
    hist: jnp.ndarray,         # (C,) int32 per-token history boundary
    tile_seq: jnp.ndarray,     # (C/bq,) int32 slot per query tile
    *,
    window: int = 0,
    bq: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns f32 (C, KV, G, hd) attention output (padding rows zero)."""
    C, KV, G, hd = q.shape
    P, ps = k_data.shape[:2]
    NB = block_table.shape[1]
    assert C % bq == 0 and hd % 2 == 0, (C, bq, hd)
    assert tile_seq.shape == (C // bq,), tile_seq.shape
    kernel = functools.partial(_kernel, window=window,
                               sm_scale=hd ** -0.5, ps=ps, nb=NB)
    seq2d = seq_id.astype(jnp.int32).reshape(1, C)
    pos2d = pos.astype(jnp.int32).reshape(1, C)
    hist2d = hist.astype(jnp.int32).reshape(1, C)

    def page_idx(qt, kv, t, ts, bt, ks, vs):
        # stage t streams the tile's sequence's page t; the chunk stage
        # (t == NB) and unallocated blocks clamp to page 0 (masked out)
        s = jnp.maximum(ts[qt], 0)
        return (jnp.maximum(bt[s, jnp.minimum(t, NB - 1)], 0), 0, kv, 0)

    plane = pl.BlockSpec((1, ps, 1, hd), page_idx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # tile_seq, block_table, k/v scales
        grid=(C // bq, KV, NB + 1),
        in_specs=[
            pl.BlockSpec((bq, 1, G, hd),
                         lambda qt, kv, t, *s: (qt, kv, 0, 0)),
            pl.BlockSpec((1, bq), lambda qt, kv, t, *s: (0, qt)),
            pl.BlockSpec((1, bq), lambda qt, kv, t, *s: (0, qt)),
            pl.BlockSpec((1, bq), lambda qt, kv, t, *s: (0, qt)),
            pl.BlockSpec((1, C), lambda qt, kv, t, *s: (0, 0)),
            pl.BlockSpec((1, C), lambda qt, kv, t, *s: (0, 0)),
            pl.BlockSpec((C, 1, hd), lambda qt, kv, t, *s: (0, kv, 0)),
            pl.BlockSpec((C, 1, hd), lambda qt, kv, t, *s: (0, kv, 0)),
            plane, plane, plane, plane,
        ],
        out_specs=pl.BlockSpec((bq, 1, G, hd),
                               lambda qt, kv, t, *s: (qt, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq * G, 1), jnp.float32),   # m: running max
            pltpu.VMEM((bq * G, 1), jnp.float32),   # l: running denom
            pltpu.VMEM((bq * G, hd), jnp.float32),  # acc: running numer
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, KV, G, hd), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile_seq.astype(jnp.int32), block_table.astype(jnp.int32),
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32),
      q, seq2d, pos2d, hist2d, seq2d, pos2d, k_chunk, v_chunk,
      k_data, k_meta, v_data, v_meta)
