"""Public jit'd wrappers around the SPARQ kernels.

`quantized_matmul` is what the model layers call;
`sparq_decode_attention` / `sparq_paged_decode_attention` are the fused
packed-cache decode reads (contiguous planes vs block-table-gathered
pages). Dispatch everywhere:
  impl="pallas"     — the fused TPU kernel (interpret=True off-TPU);
  impl="reference"  — pure-jnp oracle semantics via an int dot_general
                      (what the XLA int8 MXU path lowers to on TPU);
  impl="auto"       — pallas on TPU backends, reference elsewhere.

Handles padding to tile multiples (K is padded in whole pairs so vSPARQ
decisions are unchanged; M/N zero-padding is dropped from the result).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.quantizer import QScale
from repro.core.sparq import SparqConfig
from repro.kernels import ref as _ref
from repro.kernels.sparq_decode_attn import (sparq_decode_attn_pallas,
                                             sparq_paged_decode_attn_pallas)
from repro.kernels.sparq_dequant import sparq_dequant_pallas
from repro.kernels.sparq_prefill_attn import sparq_chunked_prefill_attn_pallas
from repro.kernels.sparq_matmul import sparq_matmul_pallas
from repro.kernels.sparq_quant import sparq_quant_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# default Tk-tile size of the fused decode-attention kernels; callers pass
# bk=None to defer here (CachedTensor.bk overrides per cache config)
DEFAULT_BK = 128

# ----------------------------------------------------------------------
# repro.analysis registration: which code is *allowed* to turn packed
# §5.1 planes back into floats, and which dispatchers the jaxpr auditor
# traces as standalone hot programs.
# ----------------------------------------------------------------------

#: source-path fragments whose int->float conversions are the blessed
#: meta-decode. Everything under repro/kernels/ qualifies: the fused
#: pallas kernels decode tile-by-tile in-loop, and the ref.py oracles
#: are their bit-exact jnp counterparts. A float cast of a packed plane
#: anywhere else is a whole-plane dequantize the format exists to avoid
#: (analysis check JX102).
META_DECODE_SOURCES = ("repro/kernels/",)

#: public dispatcher names the analysis registry audits as hot programs
#: (each is traced abstractly with engine-shaped packed planes).
HOT_DISPATCHERS = (
    "quantized_matmul",
    "sparq_quantize",
    "sparq_dequantize",
    "sparq_decode_attention",
    "sparq_chunked_prefill_attention",
    "sparq_paged_decode_attention",
)


# ----------------------------------------------------------------------
# tensor parallelism. The attention dispatchers shard along the KV-head
# axis of the packed planes (GQA head order is KV-major, so H splits at
# head-group boundaries whenever KV does): each mesh "model" shard holds
# KV/tp head groups of every page and computes its heads' attention
# locally — per-head flash accumulation never crosses heads, so shard
# outputs are bit-identical to the same head slice of the TP=1 program.
# Collectives happen only outside, at the QKV/output projections (the
# caller re-replicates before the wo matmul; see models/attention.py).
# ----------------------------------------------------------------------

TP_AXIS = "model"


def tp_size(mesh: Optional[Mesh]) -> int:
    """Model-parallel degree of `mesh` (1 = no tensor parallelism)."""
    if mesh is None or TP_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[TP_AXIS]


def _tp_guard(kv_heads: int, tp: int) -> None:
    assert kv_heads % tp == 0, (
        f"{kv_heads} KV heads do not split over tp={tp}: a head group "
        f"(one KV head + its G query heads) never splits")


def _pad_to(x: jnp.ndarray, mult: int, axis: int,
            value: float = 0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ----------------------------------------------------------------------
# §5.1 footprint accounting — the single source of truth. models/cache.py
# delegates here, so the roofline (combined figure) and the cache reports
# (data plane vs ShiftCtrl side-band) can never drift apart.
# ----------------------------------------------------------------------

def data_bytes_per_value(cfg: SparqConfig) -> float:
    """Data-plane HBM residency: n data bits per value + 1 MuxCtrl bit per
    vSPARQ pair. Plain int8 (trimming disabled) is one full byte."""
    if not cfg.enabled:
        return 1.0
    mux = 0.5 if cfg.vsparq else 0.0
    return (cfg.bits + mux) / 8.0


def ctrl_bytes_per_value(cfg: SparqConfig) -> float:
    """ShiftCtrl side-band residency: 3 bits per value when trimming."""
    return 3.0 / 8.0 if cfg.enabled else 0.0


def bytes_per_value(cfg: SparqConfig) -> float:
    """Combined HBM residency of the packed SPARQ format (paper §5.1):
    n data bits + 3-bit ShiftCtrl per value + 1 MuxCtrl bit per vSPARQ
    pair (charged only when vSPARQ is on). Used by the roofline."""
    return data_bytes_per_value(cfg) + ctrl_bytes_per_value(cfg)


def quantized_matmul(
    x: jnp.ndarray,            # (..., K) float activations
    w_codes: jnp.ndarray,      # (K, N) int8 weight codes
    act_qs: QScale,
    chan_scale: jnp.ndarray,   # (N,) f32
    cfg: SparqConfig,
    impl: str = "auto",
    block: tuple[int, int, int] = (128, 128, 512),
) -> jnp.ndarray:
    """SPARQ-quantized x @ dequant(w). Leading dims of x are flattened."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w_codes.shape[1]
    assert K % 2 == 0, "vSPARQ pairs adjacent K lanes; K must be even"
    x2 = x.reshape(-1, K)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    if impl == "reference":
        out = _ref.ref_sparq_matmul(x2, w_codes, act_qs.scale, chan_scale, **kw)
    elif impl == "pallas":
        bm, bn, bk = block
        M = x2.shape[0]
        xp = _pad_to(_pad_to(x2, bm, 0), bk, 1)
        wp = _pad_to(_pad_to(w_codes, bk, 0), bn, 1)
        cp = _pad_to(chan_scale, bn, 0)
        out = sparq_matmul_pallas(
            xp, wp, jnp.asarray(act_qs.scale, jnp.float32), cp,
            bm=bm, bn=bn, bk=bk, interpret=not _on_tpu(), **kw)
        out = out[:M, :N]
    else:
        raise ValueError(impl)
    return out.reshape(*lead, N)


def sparq_quantize(
    x: jnp.ndarray,           # (..., K) float
    act_qs: QScale,
    cfg: SparqConfig,
    impl: str = "auto",
    bm: int = 256,
):
    """Standalone SPARQ quantization (KV-cache write path).

    Args:
      x:      float (..., K); the last axis is the vSPARQ pairing axis
              (K even).
      act_qs: QScale whose f32 `scale` is the quantization step (already
              resolved/frozen by the cache — see CachedTensor).
      cfg:    codec; `cfg.enabled=False` is plain int8 (empty meta).
    Returns (codes int8, meta int8), both with x's shape. `codes` are the
    *reconstructed* values (window << shift, sign applied) ready for an
    int matmul; `sparq_pack` shifts them down to the §5.1 stored form."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    if impl == "reference":
        codes, meta = _ref.ref_sparq_quant(x2, act_qs.scale, **kw)
    else:
        M = x2.shape[0]
        xp = _pad_to(x2, bm, 0)
        codes, meta = sparq_quant_pallas(
            xp, jnp.asarray(act_qs.scale, jnp.float32),
            bm=bm, interpret=not _on_tpu(), **kw)
        codes, meta = codes[:M], meta[:M]
    return codes.reshape(*lead, K), meta.reshape(*lead, K)


def sparq_pack(codes: jnp.ndarray, meta: jnp.ndarray) -> jnp.ndarray:
    """Reconstructed int8 codes -> stored window codes (§5.1 data nibbles).

    Inverse of the decode path: |codes| >> shift is the n-bit window value
    (or the full magnitude on mux'd lanes, whose shift is 0). Exact because
    codes were built as (window << shift). Pure jnp — runs at cache-write
    time right after `sparq_quantize`.
    """
    q = codes.astype(jnp.int32)
    shift = _ref.meta_shifts(meta)
    return (jnp.sign(q) * jnp.right_shift(jnp.abs(q), shift)).astype(jnp.int8)


def sparq_dequantize(
    store: jnp.ndarray,       # (..., K) int8 window codes
    meta: jnp.ndarray,        # (..., K) int8 packed meta bytes
    impl: str = "auto",
    bm: int = 256,
) -> jnp.ndarray:
    """Meta-decode (KV-cache read fallback): (store, meta) -> int8 codes.

    store/meta: int8 (..., K) §5.1 planes (see docs/packed_format.md).
    Returns the reconstructed int8 codes (sign * (|store| << ShiftCtrl));
    multiply by the plane's scale for floats. The decode *hot* path never
    calls this — the fused decode-attention kernels do the same decode
    tile-by-tile in-loop."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    lead = store.shape[:-1]
    K = store.shape[-1]
    s2 = store.reshape(-1, K)
    m2 = meta.reshape(-1, K)
    if impl == "reference":
        codes = _ref.ref_sparq_dequant(s2, m2)
    else:
        M = s2.shape[0]
        codes = sparq_dequant_pallas(
            _pad_to(s2, bm, 0), _pad_to(m2, bm, 0),
            bm=bm, interpret=not _on_tpu())[:M]
    return codes.reshape(*lead, K)


def sparq_decode_attention(
    q: jnp.ndarray,           # (B, 1, H, hd) float query, one decode token
    k_data: jnp.ndarray,      # (B, Tk, KV, hd) int8 window codes
    k_meta: jnp.ndarray,      # (B, Tk, KV, hd) int8 packed meta bytes
    k_scale: jnp.ndarray,     # scalar f32 per-site scale
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    kpos: jnp.ndarray,        # (B, Tk) int32 slot positions (-1 = empty)
    cur: jnp.ndarray,         # scalar int32: position of the decoded token
    window: int = 0,
    impl: str = "auto",
    bk: Optional[int] = None,
    mesh: Optional[Mesh] = None,
) -> jnp.ndarray:
    """Fused flash-decode attention over the raw packed SPARQ cache planes
    (§5.1 meta-decode inside the Tk-tile loop; no full-plane dequantize).

    Serves both the linear cache (kpos = arange, masked by kpos <= cur) and
    the sliding-window ring cache (kpos = slot_pos + static `window`).

    Args:
      q:       f32/bf16 [B, 1, H, hd] — one query token per sequence.
      k_data:  int8 [B, Tk, KV, hd] window codes (§5.1 data plane).
      k_meta:  int8 [B, Tk, KV, hd] packed [mux|shift_hi|shift_lo] bytes.
      k_scale: f32 scalar per-site scale (v_* likewise for the V plane).
      kpos:    int32 [B, Tk] absolute position per cache slot (-1 = empty).
      cur:     int32 scalar — position of the token being decoded.
      window:  static sliding-window bound (0 = full causal).
      impl:    reference | pallas | auto (pallas on TPU, else reference).
      bk:      Tk-tile size (None -> DEFAULT_BK, clamped to Tk). Tile
               decomposition determines f32 summation order; match it
               (bk == page_size) when comparing against the paged path
               bit for bit.
      mesh:    optional ("data","model") Mesh — shard the head axis over
               the "model" axis via shard_map (KV % tp must be 0).
    Returns f32 [B, 1, H, hd]."""
    tp = tp_size(mesh)
    if tp > 1:
        _tp_guard(k_data.shape[2], tp)
        head = P(None, None, TP_AXIS, None)
        body = functools.partial(
            sparq_decode_attention, window=window, impl=impl, bk=bk)
        return shard_map(
            body, mesh=mesh,
            in_specs=(head, head, head, P(), head, head, P(), P(), P()),
            out_specs=head, check_rep=False,
        )(q, k_data, k_meta, k_scale, v_data, v_meta, v_scale, kpos, cur)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    B, Tq, H, hd = q.shape
    assert Tq == 1, f"decode attention takes one query token, got Tq={Tq}"
    Tk, KV = k_data.shape[1], k_data.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    bk = DEFAULT_BK if bk is None else bk
    assert bk >= 1, f"bk must be >= 1, got {bk}"
    bk = min(bk, Tk)
    # pad Tk to a tile multiple in the packed domain (int8 planes + the
    # kpos vector, padded with -1 so padding is masked out) — still ~7x
    # cheaper than padding a dequantized fp32 plane would be
    kd = _pad_to(k_data, bk, 1)
    km = _pad_to(k_meta, bk, 1)
    vd = _pad_to(v_data, bk, 1)
    vm = _pad_to(v_meta, bk, 1)
    kp = _pad_to(kpos.astype(jnp.int32), bk, 1, value=-1)
    cur = jnp.asarray(cur, jnp.int32)
    ks = jnp.asarray(k_scale, jnp.float32)
    vs = jnp.asarray(v_scale, jnp.float32)
    if impl == "reference":
        out = _ref.ref_sparq_decode_attn(
            qg, kd, km, ks, vd, vm, vs, kp, cur, window=window, bk=bk)
    elif impl == "pallas":
        out = sparq_decode_attn_pallas(
            qg, kd, km, ks, vd, vm, vs, kp, cur, window=window, bk=bk,
            interpret=not _on_tpu())
    else:
        raise ValueError(impl)
    return out.reshape(B, 1, H, hd)


def sparq_chunked_prefill_attention(
    q: jnp.ndarray,            # (C, H, hd) float — one chunk of queries
    k_chunk: jnp.ndarray,      # (C, KV, hd) float — chunk K (pre-quant)
    v_chunk: jnp.ndarray,      # (C, KV, hd) float
    k_data: jnp.ndarray,       # (P, ps, KV, hd) int8 window-code pool
    k_meta: jnp.ndarray,       # (P, ps, KV, hd) int8 meta-byte pool
    k_scale: jnp.ndarray,      # (S,) f32 per-slot site scales
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_table: jnp.ndarray,  # (S, NB) int32 page per block (-1 unset)
    seq_id: jnp.ndarray,       # (C,) int32 slot per stream token (-1 pad)
    pos: jnp.ndarray,          # (C,) int32 position per token
    hist: jnp.ndarray,         # (C,) int32 per-token history boundary
    tile_seq: jnp.ndarray,     # (C/bq,) int32 slot per aligned query tile
    window: int = 0,
    impl: str = "auto",
    bq: int = 8,
    mesh: Optional[Mesh] = None,
) -> jnp.ndarray:
    """Ragged chunked-prefill flash attention over the §5.1 page pool.

    One fixed-shape token stream carries a chunk of ragged pending
    prompts (per-token (seq_id, pos) metadata; each sequence's run is
    packed contiguously and aligned to `bq`). Every token attends to its
    sequence's already-written packed pages for positions below its
    history boundary `hist` (block-table gather + in-loop meta-decode)
    followed by causal segment-masked attention over the chunk's float
    K/V in [hist, pos]. One compiled program serves every prompt length
    and join pattern — the point of the chunked prefill path. `hist` is
    the token's segment start, so per-prompt numerics are independent of
    stream packing (see kernels.ref.ref_sparq_chunked_prefill_attn).

    Returns f32 (C, H, hd); padding rows (seq_id < 0) are zeros.
    With `mesh`, heads/pools shard over the "model" axis (see tp_size)."""
    tp = tp_size(mesh)
    if tp > 1:
        _tp_guard(k_data.shape[2], tp)
        h2 = P(None, TP_AXIS, None)       # (C, H, hd) streams
        h3 = P(None, None, TP_AXIS, None)  # (P, ps, KV, hd) pools
        body = functools.partial(
            sparq_chunked_prefill_attention, window=window, impl=impl, bq=bq)
        return shard_map(
            body, mesh=mesh,
            in_specs=(h2, h2, h2, h3, h3, P(), h3, h3, P(),
                      P(), P(), P(), P(), P()),
            out_specs=h2, check_rep=False,
        )(q, k_chunk, v_chunk, k_data, k_meta, k_scale, v_data, v_meta,
          v_scale, block_table, seq_id, pos, hist, tile_seq)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    C, H, hd = q.shape
    KV = k_data.shape[2]
    G = H // KV
    assert C % bq == 0, (C, bq)
    qg = q.reshape(C, KV, G, hd)
    bt = block_table.astype(jnp.int32)
    S = bt.shape[0]
    ks = jnp.broadcast_to(jnp.asarray(k_scale, jnp.float32), (S,))
    vs = jnp.broadcast_to(jnp.asarray(v_scale, jnp.float32), (S,))
    args = (qg, k_chunk, v_chunk, k_data, k_meta, ks, v_data, v_meta, vs,
            bt, seq_id.astype(jnp.int32), pos.astype(jnp.int32),
            hist.astype(jnp.int32), tile_seq.astype(jnp.int32))
    if impl == "reference":
        out = _ref.ref_sparq_chunked_prefill_attn(*args, window=window)
    elif impl == "pallas":
        out = sparq_chunked_prefill_attn_pallas(
            *args, window=window, bq=bq, interpret=not _on_tpu())
    else:
        raise ValueError(impl)
    return out.reshape(C, H, hd)


def sparq_paged_decode_attention(
    q: jnp.ndarray,            # (B, 1, H, hd) float, one token per sequence
    k_data: jnp.ndarray,       # (P, ps, KV, hd) int8 window-code page pool
    k_meta: jnp.ndarray,       # (P, ps, KV, hd) int8 packed meta-byte pool
    k_scale: jnp.ndarray,      # (B,) f32 per-sequence site scale
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_table: jnp.ndarray,  # (B, NB) int32 page per logical block (-1 =
                               # unallocated; masked out)
    cur: jnp.ndarray,          # (B,) int32 per-sequence decoded position
                               # (< 0 = inactive slot, output is zeros)
    window: int = 0,
    impl: str = "auto",
    mesh: Optional[Mesh] = None,
) -> jnp.ndarray:
    """Fused flash-decode attention over a *paged* packed SPARQ cache.

    Same §5.1 in-loop meta-decode as `sparq_decode_attention`, but the K/V
    planes live in one global pool of fixed-size pages shared by all
    sequences; each sequence reads its own pages through `block_table`
    (one Tk tile == one page, gathered by scalar-prefetched page index).
    Slot positions are computed from the logical block index, so the
    masking/GQA/window arithmetic is the contiguous kernel's — with
    page_size == bk the two paths are bit-identical on identical bytes.

    `cur` and the site scales are per-sequence: a continuous-batching step
    serves slots of different lengths (and different calibrations) in one
    traced call. No padding is needed — the pool geometry is static.
    Returns f32 (B, 1, H, hd). With `mesh`, pools and heads shard over
    the "model" axis; block table / cur / scales stay replicated."""
    tp = tp_size(mesh)
    if tp > 1:
        _tp_guard(k_data.shape[2], tp)
        head = P(None, None, TP_AXIS, None)
        body = functools.partial(
            sparq_paged_decode_attention, window=window, impl=impl)
        return shard_map(
            body, mesh=mesh,
            in_specs=(head, head, head, P(), head, head, P(), P(), P()),
            out_specs=head, check_rep=False,
        )(q, k_data, k_meta, k_scale, v_data, v_meta, v_scale,
          block_table, cur)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    B, Tq, H, hd = q.shape
    assert Tq == 1, f"decode attention takes one query token, got Tq={Tq}"
    KV = k_data.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    bt = block_table.astype(jnp.int32)
    cur = jnp.broadcast_to(jnp.asarray(cur, jnp.int32), (B,))
    ks = jnp.broadcast_to(jnp.asarray(k_scale, jnp.float32), (B,))
    vs = jnp.broadcast_to(jnp.asarray(v_scale, jnp.float32), (B,))
    if impl == "reference":
        out = _ref.ref_sparq_paged_decode_attn(
            qg, k_data, k_meta, ks, v_data, v_meta, vs, bt, cur,
            window=window)
    elif impl == "pallas":
        out = sparq_paged_decode_attn_pallas(
            qg, k_data, k_meta, ks, v_data, v_meta, vs, bt, cur,
            window=window, interpret=not _on_tpu())
    else:
        raise ValueError(impl)
    return out.reshape(B, 1, H, hd)
