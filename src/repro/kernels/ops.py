"""Public jit'd wrappers around the SPARQ kernels.

`quantized_matmul` is what the model layers call. Dispatch:
  impl="pallas"     — the fused TPU kernel (interpret=True off-TPU);
  impl="reference"  — pure-jnp oracle semantics via an int dot_general
                      (what the XLA int8 MXU path lowers to on TPU);
  impl="auto"       — pallas on TPU backends, reference elsewhere.

Handles padding to tile multiples (K is padded in whole pairs so vSPARQ
decisions are unchanged; M/N zero-padding is dropped from the result).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantizer import QScale
from repro.core.sparq import SparqConfig
from repro.kernels import ref as _ref
from repro.kernels.sparq_decode_attn import sparq_decode_attn_pallas
from repro.kernels.sparq_dequant import sparq_dequant_pallas
from repro.kernels.sparq_matmul import sparq_matmul_pallas
from repro.kernels.sparq_quant import sparq_quant_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int,
            value: float = 0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ----------------------------------------------------------------------
# §5.1 footprint accounting — the single source of truth. models/cache.py
# delegates here, so the roofline (combined figure) and the cache reports
# (data plane vs ShiftCtrl side-band) can never drift apart.
# ----------------------------------------------------------------------

def data_bytes_per_value(cfg: SparqConfig) -> float:
    """Data-plane HBM residency: n data bits per value + 1 MuxCtrl bit per
    vSPARQ pair. Plain int8 (trimming disabled) is one full byte."""
    if not cfg.enabled:
        return 1.0
    mux = 0.5 if cfg.vsparq else 0.0
    return (cfg.bits + mux) / 8.0


def ctrl_bytes_per_value(cfg: SparqConfig) -> float:
    """ShiftCtrl side-band residency: 3 bits per value when trimming."""
    return 3.0 / 8.0 if cfg.enabled else 0.0


def bytes_per_value(cfg: SparqConfig) -> float:
    """Combined HBM residency of the packed SPARQ format (paper §5.1):
    n data bits + 3-bit ShiftCtrl per value + 1 MuxCtrl bit per vSPARQ
    pair (charged only when vSPARQ is on). Used by the roofline."""
    return data_bytes_per_value(cfg) + ctrl_bytes_per_value(cfg)


def quantized_matmul(
    x: jnp.ndarray,            # (..., K) float activations
    w_codes: jnp.ndarray,      # (K, N) int8 weight codes
    act_qs: QScale,
    chan_scale: jnp.ndarray,   # (N,) f32
    cfg: SparqConfig,
    impl: str = "auto",
    block: tuple[int, int, int] = (128, 128, 512),
) -> jnp.ndarray:
    """SPARQ-quantized x @ dequant(w). Leading dims of x are flattened."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w_codes.shape[1]
    assert K % 2 == 0, "vSPARQ pairs adjacent K lanes; K must be even"
    x2 = x.reshape(-1, K)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    if impl == "reference":
        out = _ref.ref_sparq_matmul(x2, w_codes, act_qs.scale, chan_scale, **kw)
    elif impl == "pallas":
        bm, bn, bk = block
        M = x2.shape[0]
        xp = _pad_to(_pad_to(x2, bm, 0), bk, 1)
        wp = _pad_to(_pad_to(w_codes, bk, 0), bn, 1)
        cp = _pad_to(chan_scale, bn, 0)
        out = sparq_matmul_pallas(
            xp, wp, jnp.asarray(act_qs.scale, jnp.float32), cp,
            bm=bm, bn=bn, bk=bk, interpret=not _on_tpu(), **kw)
        out = out[:M, :N]
    else:
        raise ValueError(impl)
    return out.reshape(*lead, N)


def sparq_quantize(
    x: jnp.ndarray,           # (..., K) float
    act_qs: QScale,
    cfg: SparqConfig,
    impl: str = "auto",
    bm: int = 256,
):
    """Standalone SPARQ quantization (KV-cache path). Returns
    (codes int8, meta int8) with x's shape."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    if impl == "reference":
        codes, meta = _ref.ref_sparq_quant(x2, act_qs.scale, **kw)
    else:
        M = x2.shape[0]
        xp = _pad_to(x2, bm, 0)
        codes, meta = sparq_quant_pallas(
            xp, jnp.asarray(act_qs.scale, jnp.float32),
            bm=bm, interpret=not _on_tpu(), **kw)
        codes, meta = codes[:M], meta[:M]
    return codes.reshape(*lead, K), meta.reshape(*lead, K)


def sparq_pack(codes: jnp.ndarray, meta: jnp.ndarray) -> jnp.ndarray:
    """Reconstructed int8 codes -> stored window codes (§5.1 data nibbles).

    Inverse of the decode path: |codes| >> shift is the n-bit window value
    (or the full magnitude on mux'd lanes, whose shift is 0). Exact because
    codes were built as (window << shift). Pure jnp — runs at cache-write
    time right after `sparq_quantize`.
    """
    q = codes.astype(jnp.int32)
    shift = _ref.meta_shifts(meta)
    return (jnp.sign(q) * jnp.right_shift(jnp.abs(q), shift)).astype(jnp.int8)


def sparq_dequantize(
    store: jnp.ndarray,       # (..., K) int8 window codes
    meta: jnp.ndarray,        # (..., K) int8 packed meta bytes
    impl: str = "auto",
    bm: int = 256,
) -> jnp.ndarray:
    """Meta-decode (KV-cache read path): (store, meta) -> int8 codes."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    lead = store.shape[:-1]
    K = store.shape[-1]
    s2 = store.reshape(-1, K)
    m2 = meta.reshape(-1, K)
    if impl == "reference":
        codes = _ref.ref_sparq_dequant(s2, m2)
    else:
        M = s2.shape[0]
        codes = sparq_dequant_pallas(
            _pad_to(s2, bm, 0), _pad_to(m2, bm, 0),
            bm=bm, interpret=not _on_tpu())[:M]
    return codes.reshape(*lead, K)


def sparq_decode_attention(
    q: jnp.ndarray,           # (B, 1, H, hd) float query, one decode token
    k_data: jnp.ndarray,      # (B, Tk, KV, hd) int8 window codes
    k_meta: jnp.ndarray,      # (B, Tk, KV, hd) int8 packed meta bytes
    k_scale: jnp.ndarray,     # scalar f32 per-site scale
    v_data: jnp.ndarray,
    v_meta: jnp.ndarray,
    v_scale: jnp.ndarray,
    kpos: jnp.ndarray,        # (B, Tk) int32 slot positions (-1 = empty)
    cur: jnp.ndarray,         # scalar int32: position of the decoded token
    window: int = 0,
    impl: str = "auto",
    bk: int = 128,
) -> jnp.ndarray:
    """Fused flash-decode attention over the raw packed SPARQ cache planes
    (§5.1 meta-decode inside the Tk-tile loop; no full-plane dequantize).

    Serves both the linear cache (kpos = arange, masked by kpos <= cur) and
    the sliding-window ring cache (kpos = slot_pos + static `window`).
    Returns f32 (B, 1, H, hd)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    B, Tq, H, hd = q.shape
    assert Tq == 1, f"decode attention takes one query token, got Tq={Tq}"
    Tk, KV = k_data.shape[1], k_data.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    bk = min(bk, Tk)
    # pad Tk to a tile multiple in the packed domain (int8 planes + the
    # kpos vector, padded with -1 so padding is masked out) — still ~7x
    # cheaper than padding a dequantized fp32 plane would be
    kd = _pad_to(k_data, bk, 1)
    km = _pad_to(k_meta, bk, 1)
    vd = _pad_to(v_data, bk, 1)
    vm = _pad_to(v_meta, bk, 1)
    kp = _pad_to(kpos.astype(jnp.int32), bk, 1, value=-1)
    cur = jnp.asarray(cur, jnp.int32)
    ks = jnp.asarray(k_scale, jnp.float32)
    vs = jnp.asarray(v_scale, jnp.float32)
    if impl == "reference":
        out = _ref.ref_sparq_decode_attn(
            qg, kd, km, ks, vd, vm, vs, kp, cur, window=window, bk=bk)
    elif impl == "pallas":
        out = sparq_decode_attn_pallas(
            qg, kd, km, ks, vd, vm, vs, kp, cur, window=window, bk=bk,
            interpret=not _on_tpu())
    else:
        raise ValueError(impl)
    return out.reshape(B, 1, H, hd)
