"""Whisper-base [arXiv:2212.04356; unverified]: enc-dec; conv frontend is a
STUB per assignment (input_specs provides precomputed frame embeddings).
6+6L d_model=512 8H d_ff=2048 vocab=51865, sinusoidal positions, GELU."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec", n_layers=6, n_enc_layers=6,
        d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
        mlp_type="gelu", norm_type="layernorm", use_rope=False,
        frontend="audio", tie_embeddings=True, logit_chunk=512, tensor_parallel=False)


def reduced() -> ModelConfig:
    return config().replace(name="whisper-reduced", n_layers=2,
                            n_enc_layers=2, d_model=128, n_heads=4,
                            n_kv_heads=4, d_ff=256, vocab_size=512,
                            logit_chunk=0, attn_chunk=64)
