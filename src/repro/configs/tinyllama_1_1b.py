"""TinyLlama-1.1B [arXiv:2401.02385; hf]: llama2-arch small.
22L d_model=2048 32H GQA(kv=4) d_ff=5632 vocab=32000, SwiGLU, RoPE."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=5632, vocab_size=32000,
        mlp_type="swiglu", norm_type="rmsnorm", tie_embeddings=False,
        logit_chunk=512, tensor_parallel=False)


def reduced() -> ModelConfig:
    return config().replace(name="tinyllama-reduced", n_layers=2,
                            d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
                            vocab_size=512, logit_chunk=0, attn_chunk=64)
