"""Config registry + assigned input shapes + ShapeDtypeStruct input specs.

Each architecture module registers its exact published config; smoke tests
instantiate `reduced()` variants. The four assigned LM shapes:

  train_4k     seq=4096   global_batch=256   (training, train_step)
  prefill_32k  seq=32768  global_batch=32    (inference prefill)
  decode_32k   seq=32768  global_batch=128   (one token, 32k KV cache)
  long_500k    seq=524288 global_batch=1     (one token, 500k state) —
               runs only for sub-quadratic archs (rwkv6, recurrentgemma);
               skipped for pure full-attention archs per DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs for which long_500k is runnable (sub-quadratic sequence mixing)
SUBQUADRATIC = ("rwkv6-7b", "recurrentgemma-9b")

_REGISTRY: Dict[str, str] = {
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "granite-20b": "repro.configs.granite_20b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "whisper-base": "repro.configs.whisper_base",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "paper-resnet": "repro.configs.paper_resnet",  # paper's own family
}

ARCHS = tuple(k for k in _REGISTRY if k != "paper-resnet")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(_REGISTRY[name])
    return mod.config()


def get_reduced_config(name: str) -> ModelConfig:
    mod = importlib.import_module(_REGISTRY[name])
    return mod.reduced()


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) dry-run cell."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("full-attention arch: 512k dense KV cache exceeds HBM "
                       "and published context; see DESIGN.md §4")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a step —
    weak-type-correct, shardable, no device allocation (dry-run contract)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    tok = lambda *s: jax.ShapeDtypeStruct(s, i32)
    emb = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)

    if shape.kind == "train":
        if cfg.family == "vlm":
            P = cfg.frontend_len
            return {"image_embeds": emb(B, P, cfg.d_model),
                    "tokens": tok(B, S - P), "labels": tok(B, S - P)}
        if cfg.is_encdec:
            return {"frames": emb(B, S, cfg.d_model),
                    "tokens": tok(B, S), "labels": tok(B, S)}
        return {"tokens": tok(B, S), "labels": tok(B, S)}
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            P = cfg.frontend_len
            return {"image_embeds": emb(B, P, cfg.d_model),
                    "tokens": tok(B, S - P)}
        if cfg.is_encdec:
            return {"frames": emb(B, S, cfg.d_model), "tokens": tok(B, S)}
        return {"tokens": tok(B, S)}
    # decode: one new token against a cache of S tokens
    return {"tokens": tok(B, 1)}
