"""Architecture configs: one module per assigned architecture."""
from repro.configs.base import (ARCHS, SHAPES, SUBQUADRATIC, ShapeSpec,
                                cell_is_runnable, get_config,
                                get_reduced_config, input_specs)

__all__ = ["ARCHS", "SHAPES", "SUBQUADRATIC", "ShapeSpec",
           "cell_is_runnable", "get_config", "get_reduced_config",
           "input_specs"]
