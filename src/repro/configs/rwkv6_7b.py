"""RWKV6 "Finch" 7B [arXiv:2404.05892; hf]: attention-free, data-dependent
decay. 32L d_model=4096 d_ff=14336 (channel-mix) vocab=65536, head_size=64
(64 wkv heads)."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="rwkv6", n_layers=32, d_model=4096,
        n_heads=64, n_kv_heads=64, d_ff=14336, vocab_size=65536,
        head_size=64, decay_lora=64, use_rope=False, norm_type="layernorm",
        tie_embeddings=True, logit_chunk=512, train_microbatches=2)


def reduced() -> ModelConfig:
    return config().replace(name="rwkv6-reduced", n_layers=2, d_model=128,
                            n_heads=4, n_kv_heads=4, head_size=32,
                            decay_lora=16, d_ff=256, vocab_size=512,
                            logit_chunk=0, train_microbatches=1, mixer_chunk=8)
