"""PaliGemma-3B [arXiv:2407.07726; hf]: SigLIP vision frontend (STUB per
assignment — input_specs provides precomputed patch embeddings) + gemma
decoder. 18L d_model=2048 8H GQA(kv=1) d_ff=16384 vocab=257216."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, d_ff=16384, vocab_size=257216,
        mlp_type="geglu", norm_type="rmsnorm",
        frontend="vision", frontend_len=256,
        tie_embeddings=True, logit_chunk=256)


def reduced() -> ModelConfig:
    return config().replace(name="paligemma-reduced", n_layers=2,
                            d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
                            vocab_size=512, frontend_len=16, logit_chunk=0,
                            attn_chunk=64)
