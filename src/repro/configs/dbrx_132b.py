"""DBRX-132B [hf:databricks/dbrx-base; unverified]: fine-grained MoE.
40L d_model=6144 48H GQA(kv=8) 16 experts top-4 expert_ff=10752
vocab=100352, GLU experts, RoPE."""
import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=10752, vocab_size=100352,
        mlp_type="swiglu", norm_type="layernorm",
        n_experts=16, experts_per_token=4, moe_d_ff=10752,
        rope_theta=5e5, tie_embeddings=True, logit_chunk=512, train_microbatches=8,
        param_dtype=jnp.bfloat16)


def reduced() -> ModelConfig:
    return config().replace(name="dbrx-reduced", n_layers=2, d_model=128,
                            n_heads=8, n_kv_heads=2, d_ff=256, moe_d_ff=256,
                            n_experts=4, experts_per_token=2, vocab_size=512,
                            logit_chunk=0, train_microbatches=1, attn_chunk=64)
