"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified].
88L d_model=12288 96H GQA(kv=8) d_ff=28672 vocab=32768, SwiGLU, RoPE."""
import jax.numpy as jnp

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense", n_layers=88,
        d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
        vocab_size=32768, mlp_type="swiglu", norm_type="rmsnorm",
        rope_theta=1e6, tie_embeddings=False, logit_chunk=512, train_microbatches=8,
        param_dtype=jnp.bfloat16)


def reduced() -> ModelConfig:
    return config().replace(name="mistral-large-reduced", n_layers=2,
                            d_model=192, n_heads=12, n_kv_heads=2, d_ff=448,
                            vocab_size=512, logit_chunk=0, train_microbatches=1, attn_chunk=64)
