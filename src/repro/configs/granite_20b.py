"""Granite-20B-Code [arXiv:2405.04324; hf]: code model, MQA.
52L d_model=6144 48H GQA(kv=1) d_ff=24576 (4x, non-gated GELU) vocab=49152."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense", n_layers=52, d_model=6144,
        n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
        mlp_type="gelu", norm_type="layernorm", tie_embeddings=True,
        logit_chunk=512, train_microbatches=4)


def reduced() -> ModelConfig:
    return config().replace(name="granite-reduced", n_layers=2, d_model=128,
                            n_heads=8, n_kv_heads=1, d_ff=512, vocab_size=512,
                            logit_chunk=0, train_microbatches=1, attn_chunk=64)
