"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf]: MLA (kv_lora=512) + MoE
(2 shared + 64 routed, top-6, expert_ff=1408). 27L d_model=2048 16H
vocab=102400. First layer uses a dense FFN (d_ff=10944), as published."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", n_layers=27,
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
        vocab_size=102400, mlp_type="swiglu", norm_type="rmsnorm",
        n_experts=64, n_shared_experts=2, experts_per_token=6,
        moe_d_ff=1408, first_dense_layers=1,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        tie_embeddings=True, logit_chunk=512, train_microbatches=4)


def reduced() -> ModelConfig:
    return config().replace(name="deepseek-reduced", n_layers=3, d_model=128,
                            n_heads=4, n_kv_heads=4, d_ff=256, moe_d_ff=64,
                            n_experts=8, n_shared_experts=1,
                            experts_per_token=2, kv_lora_rank=32,
                            qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
                            vocab_size=512, logit_chunk=0, train_microbatches=1, attn_chunk=64)
