"""The paper's own model family: a mini-ResNet (ReLU + BatchNorm) used for
the faithful reproduction of Tables 1/2/3/4/6 on a synthetic task
(DESIGN.md §7 — no ImageNet offline)."""
from repro.models.cnn import CNNConfig


def config() -> CNNConfig:
    return CNNConfig(name="paper-resnet", num_classes=16, width=32,
                     stages=(2, 2, 2), img_size=32)


def reduced() -> CNNConfig:
    return CNNConfig(name="paper-resnet-reduced", num_classes=8, width=16,
                     stages=(1, 1), img_size=16)
