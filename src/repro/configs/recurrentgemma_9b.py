"""RecurrentGemma-9B [arXiv:2402.19427; unverified]: Griffin hybrid —
RG-LRU recurrent blocks + local attention, 1 attention : 2 recurrent.
38L d_model=4096 16H MQA(kv=1) d_ff=12288 window=2048 vocab=256000."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="rglru", n_layers=38,
        d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
        vocab_size=256000, mlp_type="geglu", norm_type="rmsnorm",
        block_pattern=("rg_rec", "rg_rec", "rg_attn"), lru_width=4096,
        local_window=2048, conv_width=4,
        tie_embeddings=True, logit_chunk=256, train_microbatches=8)


def reduced() -> ModelConfig:
    return config().replace(name="recurrentgemma-reduced", n_layers=3,
                            d_model=128, n_heads=4, n_kv_heads=1, d_ff=256,
                            lru_width=128, local_window=32, vocab_size=512,
                            logit_chunk=0, train_microbatches=1, attn_chunk=64)
