"""StarCoder2-3B [arXiv:2402.19173; hf]: GQA, RoPE, code.
30L d_model=3072 24H GQA(kv=2) d_ff=12288 (4x GELU) vocab=49152."""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
        n_heads=24, n_kv_heads=2, d_ff=12288, vocab_size=49152,
        mlp_type="gelu", norm_type="layernorm", rope_theta=1e5,
        tie_embeddings=True, logit_chunk=512, train_microbatches=1,
        tensor_parallel=False)


def reduced() -> ModelConfig:
    return config().replace(name="starcoder2-reduced", n_layers=2,
                            d_model=128, n_heads=8, n_kv_heads=2, d_ff=512,
                            vocab_size=512, logit_chunk=0, train_microbatches=1, attn_chunk=64)
