"""Block assembly and layer stacking for all 10 architectures.

One generic `block_apply` dispatches on a *kind* string; homogeneous runs
of layers execute under jax.lax.scan with stacked params (+ stacked caches
and stacked per-layer quant scales as scan xs), wrapped in jax.checkpoint
for training remat. Heterogeneous stacks (deepseek's first dense layer,
recurrentgemma's (rec, rec, attn) pattern tail) unroll only the leftovers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.cache import CacheConfig, CachedTensor, CacheStore
from repro.models.common import ModelConfig, QuantCtx, norm, norm_init
from repro.models.quantize import as_weight


class RingKVCache(NamedTuple):
    """Sliding-window KV ring buffer (local attention decode). The k/v
    planes are CachedTensors, so the ring stores fp or sparq layout."""
    k: CachedTensor         # [B, W, KV, hd]
    v: CachedTensor
    slot_pos: jnp.ndarray   # [B, W] absolute position per slot (-1 empty)
    pos: jnp.ndarray        # scalar: next absolute position


def ring_init(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16,
              cache_cfg: Optional[CacheConfig] = None) -> RingKVCache:
    cc = cache_cfg or CacheConfig(layout="fp", dtype=dtype)
    W = cfg.local_window
    shape = (batch, W, cfg.n_kv_heads, cfg.head_dim)
    return RingKVCache(CachedTensor.init(shape, cc),
                       CachedTensor.init(shape, cc),
                       jnp.full((batch, W), -1, jnp.int32),
                       jnp.zeros((), jnp.int32))


def ring_insert(cache: RingKVCache, k_new, v_new) -> RingKVCache:
    """Insert T_new tokens (T_new <= W) at rolling slots."""
    T_new = k_new.shape[1]
    W = cache.k.data.shape[1]
    slots = (cache.pos + jnp.arange(T_new)) % W
    k = cache.k.write_slots(k_new, slots)
    v = cache.v.write_slots(v_new, slots)
    sp = cache.slot_pos.at[:, slots].set(
        (cache.pos + jnp.arange(T_new))[None, :])
    return RingKVCache(k, v, sp, cache.pos + T_new)


def ring_decode_attention(q, cache: RingKVCache, window: int):
    """q [B,1,H,hd] against the ring. Mask by per-slot absolute position.

    sparq layout: the raw packed planes go to the fused flash-decode kernel
    (windowed variant — slot_pos doubles as the kernel's kpos input, so the
    ring's rotation never needs undoing); fp layout: full-plane read."""
    if cache.k.is_sparq:
        from repro.kernels.ops import sparq_decode_attention
        out = sparq_decode_attention(
            q, cache.k.data, cache.k.meta, cache.k.scale,
            cache.v.data, cache.v.meta, cache.v.scale,
            cache.slot_pos, cache.pos - 1, window=window,
            impl=cache.k.impl, bk=cache.k.bk)
        return out.astype(q.dtype)
    B, _, H, hd = q.shape
    k, v = cache.k.read(), cache.v.read()
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    cur = cache.pos - 1  # position of the token being decoded
    ok = (cache.slot_pos >= 0) & (cache.slot_pos <= cur) & \
         (cache.slot_pos > cur - window)
    s = jnp.where(ok[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ----------------------------------------------------------------------
# block init / apply, dispatched on kind
# ----------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, kind: str, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    nt, d = cfg.norm_type, cfg.d_model
    if kind == "dense":
        return {"ln1": norm_init(d, nt),
                "attn": attn_mod.attention_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "ffn": ffn_mod.ffn_init(ks[1], d, cfg.d_ff, cfg.mlp_type,
                                        cfg.n_layers, dtype)}
    if kind == "moe":
        return {"ln1": norm_init(d, nt),
                "attn": attn_mod.attention_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "moe": moe_mod.moe_init(ks[1], cfg, dtype)}
    if kind == "mla_dense":
        return {"ln1": norm_init(d, nt),
                "attn": mla_mod.mla_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "ffn": ffn_mod.ffn_init(ks[1], d, cfg.d_ff, cfg.mlp_type,
                                        cfg.n_layers, dtype)}
    if kind == "mla_moe":
        return {"ln1": norm_init(d, nt),
                "attn": mla_mod.mla_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "moe": moe_mod.moe_init(ks[1], cfg, dtype)}
    if kind == "rwkv":
        return {"ln1": norm_init(d, nt),
                "ln2": norm_init(d, nt),
                **rwkv_mod.rwkv_block_init(ks[0], cfg, dtype)}
    if kind == "rg_rec":
        return {"ln1": norm_init(d, nt),
                "rec": rg_mod.rglru_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "ffn": ffn_mod.ffn_init(ks[1], d, cfg.d_ff, "geglu",
                                        cfg.n_layers, dtype)}
    if kind == "rg_attn":
        return {"ln1": norm_init(d, nt),
                "attn": attn_mod.attention_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "ffn": ffn_mod.ffn_init(ks[1], d, cfg.d_ff, "geglu",
                                        cfg.n_layers, dtype)}
    if kind == "enc":
        return {"ln1": norm_init(d, nt),
                "attn": attn_mod.attention_init(ks[0], cfg, dtype),
                "ln2": norm_init(d, nt),
                "ffn": ffn_mod.ffn_init(ks[1], d, cfg.d_ff, cfg.mlp_type,
                                        cfg.n_layers, dtype)}
    if kind == "dec":
        return {"ln1": norm_init(d, nt),
                "attn": attn_mod.attention_init(ks[0], cfg, dtype),
                "ln_x": norm_init(d, nt),
                "xattn": attn_mod.attention_init(ks[1], cfg, dtype),
                "ln2": norm_init(d, nt),
                "ffn": ffn_mod.ffn_init(ks[2], d, cfg.d_ff, cfg.mlp_type,
                                        cfg.n_layers, dtype)}
    raise ValueError(kind)


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16,
                     cache_cfg: Optional[CacheConfig] = None, mesh=None):
    cc = cache_cfg or CacheConfig(layout="fp", dtype=dtype)
    state_dtype = cc.dtype if cc.layout == "fp" else dtype
    if kind in ("dense", "moe", "enc"):
        return attn_mod.cache_init(cfg, batch, max_len, cache_cfg=cc,
                                   mesh=mesh)
    if kind in ("mla_dense", "mla_moe"):
        return mla_mod.mla_cache_init(cfg, batch, max_len, cache_cfg=cc)
    if kind == "rwkv":
        # O(1) recurrent state, overwritten every step — quantized storage
        # would accumulate error, so the sparq layout doesn't apply here;
        # the cache config still controls the fp storage dtype.
        H = cfg.d_model // cfg.head_size
        return rwkv_mod.RWKVCache(
            state=jnp.zeros((batch, H, cfg.head_size, cfg.head_size),
                            state_dtype),
            tm_last=jnp.zeros((batch, cfg.d_model), state_dtype),
            cm_last=jnp.zeros((batch, cfg.d_model), state_dtype))
    if kind == "rg_rec":
        return rg_mod.rglru_cache_init(cfg, batch, state_dtype)
    if kind == "rg_attn":
        return ring_init(cfg, batch, cache_cfg=cc)
    if kind == "dec":
        # self-attention cache + cross k/v (filled at prefill)
        return {"self": attn_mod.cache_init(cfg, batch, max_len,
                                            cache_cfg=cc),
                "cross_k": jnp.zeros(
                    (batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                    state_dtype),
                "cross_v": jnp.zeros(
                    (batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                    state_dtype)}
    raise ValueError(kind)


def _res(x, y):
    return x + y.astype(x.dtype)


def block_apply(params: Dict, x: jnp.ndarray, cfg: ModelConfig, kind: str, *,
                positions: jnp.ndarray,
                cache=None,
                mode: str = "train",
                ctx: Optional[QuantCtx] = None,
                prefix_len: int = 0,
                enc_out: Optional[jnp.ndarray] = None,
                chunk=None):
    """Returns (x, new_cache, aux) — aux carries MoE losses (or {}).

    mode "chunk_prefill" (standard-KV kinds over paged caches only):
    x is one packed ragged-prompt chunk with `chunk` ChunkMeta; the
    attention sub-block writes §5.1 pages directly and attends
    chunk+pages (see attention.attention_block)."""
    if chunk is not None and kind not in ("dense", "moe"):
        raise ValueError(f"chunked prefill serves standard-KV attention "
                         f"kinds only (got {kind!r})")
    from repro.distributed.sharding import constrain_batch
    aux = {}
    nt, eps = cfg.norm_type, cfg.norm_eps

    if kind in ("dense", "moe", "rg_attn", "enc"):
        window = cfg.local_window if kind == "rg_attn" else 0
        h = constrain_batch(norm(params["ln1"], x, nt, eps))
        if kind == "rg_attn" and mode == "decode":
            q, k, v = attn_mod.qkv_proj(params["attn"], h, cfg, positions, ctx)
            cache = ring_insert(cache, k, v)
            o = ring_decode_attention(q, cache, window)
            o = jnp.matmul(o.reshape(*o.shape[:2], -1),
                           as_weight(params["attn"]["wo"], x.dtype))
            new_cache = cache
        elif kind == "rg_attn" and mode == "prefill":
            q, k, v = attn_mod.qkv_proj(params["attn"], h, cfg, positions, ctx)
            o = attn_mod.local_attention(q, k, v, window=window)
            o = jnp.matmul(o.reshape(*o.shape[:2], -1),
                           as_weight(params["attn"]["wo"], x.dtype))
            # fill the ring with the last W tokens at their absolute slots
            W = min(window, k.shape[1])
            primed = cache._replace(
                pos=jnp.asarray(k.shape[1] - W, jnp.int32))
            new_cache = ring_insert(primed, k[:, -W:], v[:, -W:])
        else:
            if kind == "enc" and mode != "decode":
                o = attn_mod.flash_attention(
                    *attn_mod.qkv_proj(params["attn"], h, cfg, positions, ctx),
                    causal=False, q_chunk=cfg.attn_chunk,
                    kv_chunk=cfg.attn_chunk)
                o = jnp.matmul(o.reshape(*o.shape[:2], -1),
                               as_weight(params["attn"]["wo"], x.dtype))
                new_cache = cache
            else:
                o, new_cache = attn_mod.attention_block(
                    params["attn"], h, cfg, positions=positions, cache=cache,
                    mode=mode, window=window, prefix_len=prefix_len, ctx=ctx,
                    chunk=chunk)
        x = _res(x, o)
        h = constrain_batch(norm(params["ln2"], x, nt, eps))
        if kind == "moe":
            # chunk_prefill mixes tokens of several sequences in one
            # stream: exact capacity (like decode) so capacity-based
            # dropping can never couple one prompt's routing to another's
            o, aux = moe_mod.moe_apply(
                params["moe"], h, cfg, ctx,
                exact_capacity=(mode in ("decode", "chunk_prefill")))
        else:
            o = ffn_mod.ffn_apply(params["ffn"], h, cfg.mlp_type
                                  if kind != "rg_attn" else "geglu", ctx)
        x = _res(x, o)
        return x, new_cache, aux

    if kind in ("mla_dense", "mla_moe"):
        h = constrain_batch(norm(params["ln1"], x, nt, eps))
        o, new_cache = mla_mod.mla_block(
            params["attn"], h, cfg, positions=positions, cache=cache,
            mode=mode, ctx=ctx)
        x = _res(x, o)
        h = constrain_batch(norm(params["ln2"], x, nt, eps))
        if kind == "mla_moe":
            o, aux = moe_mod.moe_apply(params["moe"], h, cfg, ctx,
                                       exact_capacity=(mode == "decode"))
        else:
            o = ffn_mod.ffn_apply(params["ffn"], h, cfg.mlp_type, ctx)
        x = _res(x, o)
        return x, new_cache, aux

    if kind == "rwkv":
        h = constrain_batch(norm(params["ln1"], x, nt, eps))
        o, new_cache = rwkv_mod.time_mix(params, h, cfg, cache=cache,
                                         mode=mode, ctx=ctx)
        x = _res(x, o)
        h = constrain_batch(norm(params["ln2"], x, nt, eps))
        cm_last = cache.cm_last if cache is not None else None
        o = rwkv_mod.channel_mix(params, h, cfg, last=cm_last, ctx=ctx)
        if new_cache is not None:
            new_cache = new_cache._replace(
                cm_last=h[:, -1].astype(new_cache.cm_last.dtype))
        x = _res(x, o)
        return x, new_cache, aux

    if kind == "rg_rec":
        h = constrain_batch(norm(params["ln1"], x, nt, eps))
        o, new_cache = rg_mod.rglru_block(params["rec"], h, cfg, cache=cache,
                                          mode=mode, ctx=ctx)
        x = _res(x, o)
        h = constrain_batch(norm(params["ln2"], x, nt, eps))
        x = _res(x, ffn_mod.ffn_apply(params["ffn"], h, "geglu", ctx))
        return x, new_cache, aux

    if kind == "dec":
        h = constrain_batch(norm(params["ln1"], x, nt, eps))
        o, self_cache = attn_mod.attention_block(
            params["attn"], h, cfg, positions=positions,
            cache=cache["self"] if cache else None, mode=mode, ctx=ctx)
        x = _res(x, o)
        h = constrain_batch(norm(params["ln_x"], x, nt, eps))
        # cross-attention: K/V from encoder output (cached after prefill)
        if mode == "train" or enc_out is not None:
            ck = attn_mod._split_heads(
                jnp.matmul(enc_out, as_weight(params["xattn"]["wk"], x.dtype)),
                cfg.n_kv_heads)
            cv = attn_mod._split_heads(
                jnp.matmul(enc_out, as_weight(params["xattn"]["wv"], x.dtype)),
                cfg.n_kv_heads)
        else:
            ck, cv = cache["cross_k"], cache["cross_v"]
        q = attn_mod._split_heads(
            jnp.matmul(h, as_weight(params["xattn"]["wq"], x.dtype)), cfg.n_heads)
        if mode == "decode":
            o = attn_mod.decode_attention(
                q, CacheStore.from_kv(ck, cv, ck.shape[1]))
        else:
            o = attn_mod.flash_attention(q, ck, cv, causal=False,
                                         q_chunk=cfg.attn_chunk,
                                         kv_chunk=cfg.attn_chunk)
        o = jnp.matmul(o.reshape(*o.shape[:2], -1),
                       as_weight(params["xattn"]["wo"], x.dtype))
        x = _res(x, o)
        h = constrain_batch(norm(params["ln2"], x, nt, eps))
        x = _res(x, ffn_mod.ffn_apply(params["ffn"], h, cfg.mlp_type, ctx))
        new_cache = None
        if cache is not None:
            new_cache = {"self": self_cache, "cross_k": ck, "cross_v": cv}
        return x, new_cache, aux

    raise ValueError(kind)


# ----------------------------------------------------------------------
# layer stacks: scan over homogeneous runs
# ----------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> list[str]:
    """Per-layer kind list for the decoder stack."""
    fam = cfg.family
    if fam == "dense" or fam == "vlm":
        return ["dense"] * cfg.n_layers
    if fam == "moe":
        if cfg.kv_lora_rank:
            kinds = ["mla_dense"] * cfg.first_dense_layers
            kinds += ["mla_moe"] * (cfg.n_layers - cfg.first_dense_layers)
            return kinds
        return ["moe"] * cfg.n_layers
    if fam == "rwkv6":
        return ["rwkv"] * cfg.n_layers
    if fam == "rglru":
        pattern = cfg.block_pattern or ("rg_rec", "rg_rec", "rg_attn")
        return [pattern[i % len(pattern)] for i in range(cfg.n_layers)]
    if fam == "encdec":
        return ["dec"] * cfg.n_layers
    raise ValueError(fam)


def stack_init(key, cfg: ModelConfig, kinds: list[str],
               dtype=jnp.float32) -> list:
    """Group consecutive same-kind layers; stack each group's params.
    Returns a list of stacked param pytrees (pure arrays — the (kind, count)
    metadata lives in Model.groups_meta, outside the jitted tree)."""
    out = []
    for i, (kind, count) in enumerate(_group_runs(kinds)):
        keys = jax.random.split(jax.random.fold_in(key, i), count)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[block_init(k, cfg, kind, dtype) for k in keys])
        out.append(stacked)
    return out


def _group_runs(kinds: list[str]) -> list[tuple[str, int]]:
    groups = []
    for k in kinds:
        if groups and groups[-1][0] == k:
            groups[-1] = (k, groups[-1][1] + 1)
        else:
            groups.append((k, 1))
    return groups


def stack_cache_init(cfg: ModelConfig, kinds: list[str], batch: int,
                     max_len: int, dtype=jnp.bfloat16,
                     cache_cfg: Optional[CacheConfig] = None,
                     mesh=None) -> list:
    out = []
    for kind, count in _group_runs(kinds):
        one = block_cache_init(cfg, kind, batch, max_len, dtype, cache_cfg,
                               mesh=mesh)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (count,) + x.shape).copy()
            if x.ndim else jnp.broadcast_to(x, (count,)).copy(), one))
    return out


def stack_apply(groups_meta: list, blocks: list, x: jnp.ndarray,
                cfg: ModelConfig, *,
                positions: jnp.ndarray,
                caches: Optional[list] = None,
                mode: str = "train",
                ctx: Optional[QuantCtx] = None,
                scales_groups: Optional[list] = None,
                prefix_len: int = 0,
                enc_out: Optional[jnp.ndarray] = None,
                chunk=None):
    """Apply every layer group with lax.scan. groups_meta is the static
    [(kind, count)] list; blocks the parallel stacked-params list.
    `chunk` (ChunkMeta, mode "chunk_prefill" only) rides along as a scan
    constant — the same stream metadata serves every layer.
    Returns (x, new_caches, aux)."""
    new_caches = []
    lb = jnp.float32(0)
    zl = jnp.float32(0)
    for gi, ((kind, count), stacked) in enumerate(zip(groups_meta, blocks)):
        cache_g = caches[gi] if caches is not None else None
        scales_g = scales_groups[gi] if scales_groups is not None else None

        def body(carry, xs, kind=kind):
            from repro.distributed.sharding import constrain
            h, lb_a, zl_a = carry
            p_l, cache_l, scales_l = xs
            bctx = ctx
            if ctx is not None and scales_l is not None:
                bctx = dataclasses.replace(ctx, scales=scales_l)
            h, new_cache_l, aux = block_apply(
                p_l, h, cfg, kind, positions=positions, cache=cache_l,
                mode=mode, ctx=bctx, prefix_len=prefix_len, enc_out=enc_out,
                chunk=chunk)
            h = constrain(h)  # pin residual stream (DP/SP) at layer boundary
            lb_a += aux.get("lb_loss", 0.0)
            zl_a += aux.get("z_loss", 0.0)
            return (h, lb_a, zl_a), new_cache_l

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body)
        xs = (stacked, cache_g, scales_g)
        if count == 1:
            sq = jax.tree.map(lambda a: a[0], (stacked, cache_g, scales_g))
            (x, lb, zl), nc = body((x, lb, zl), sq)
            new_caches.append(jax.tree.map(lambda a: a[None], nc))
        else:
            (x, lb, zl), nc = jax.lax.scan((lambda c, s: body(c, s)),
                                           (x, lb, zl), xs)
            new_caches.append(nc)
    return x, new_caches, {"lb_loss": lb, "z_loss": zl}
