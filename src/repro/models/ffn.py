"""Feed-forward blocks: SwiGLU (llama/mistral/deepseek), GELU (granite/
starcoder2/whisper), GeGLU (gemma/dbrx). All matmuls route through the
SPARQ quant hook."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx, dense, init_dense


def ffn_apply(params: Dict, x: jnp.ndarray, mlp_type: str,
              ctx: Optional[QuantCtx] = None) -> jnp.ndarray:
    if mlp_type == "swiglu":
        g = dense(params["w_gate"], x, "ffn_gate", ctx)
        u = dense(params["w_up"], x, "ffn_up", ctx)
        h = jax.nn.silu(g) * u
    elif mlp_type == "geglu":
        g = dense(params["w_gate"], x, "ffn_gate", ctx)
        u = dense(params["w_up"], x, "ffn_up", ctx)
        h = jax.nn.gelu(g, approximate=True) * u
    elif mlp_type == "gelu":
        h = jax.nn.gelu(dense(params["w_up"], x, "ffn_up", ctx),
                        approximate=True)
    else:
        raise ValueError(mlp_type)
    return dense(params["w_down"], h, "ffn_down", ctx)


def ffn_init(key, d_model: int, d_ff: int, mlp_type: str, n_layers: int,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / (2 * n_layers) ** 0.5
    p = {"w_up": init_dense(ks[0], d_model, d_ff, dtype=dtype),
         "w_down": init_dense(ks[1], d_ff, d_model, scale=out_scale,
                              dtype=dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = init_dense(ks[2], d_model, d_ff, dtype=dtype)
    return p
