"""Paged SPARQ KV-cache: one global pool of fixed-size packed pages.

The contiguous `CacheStore` gives every sequence `max_len` slots up front,
so short sequences strand capacity long ones need. `PagedCacheStore`
instead owns one pool of fixed-size pages per layer, each page holding
`page_size` slots of the raw §5.1 packed planes — int8 window codes, the
packed `[mux|shift_hi|shift_lo]` meta byte, and per-*sequence* site scales.
A sequence's cache is a *block table*: `block_table[s, b]` names the
physical page that backs logical slots `[b*page_size, (b+1)*page_size)` of
sequence-slot `s`. Because the fused decode kernel (PR 2) masks by slot
*position*, not slot order, attention over paged storage is the same
kernel with a gather: `kernels.ops.sparq_paged_decode_attention` prefetches
the block table as scalars and streams each sequence's pages straight from
the pool — the pool stores only packed bytes and a dequantized copy is
never materialized.

Division of labor:

  PagedCacheStore   device state (pools, scales, block tables, positions);
                    jit/scan-transparent pytree, one per attention layer
                    (stacked along layer 0 by the engine). `update()` is
                    the traced per-token write; attention reads go through
                    `paged_decode_attention`.
  PageAllocator     host-side free list. Allocation and eviction are
                    scheduling decisions, so they live with the engine
                    (`launch.serve.ContinuousBatchingEngine`) and happen
                    *between* traced steps; exhaustion raises here, before
                    any tracing, mirroring the contiguous engine's
                    host-side capacity check.
  adopt_prefill /   engine-level transitions: copy a freshly prefill'd
  evict_slot        contiguous sparq cache's packed planes into pool pages
                    (no re-quantization — the bytes and the calibrated
                    scale transfer verbatim), and clear a finished slot.

Pool geometry: every layer's pool has `n_pages` usable pages plus one
*trash page* at index `n_pages`, the write target for inactive sequence
slots — their (masked, garbage) decode writes land there instead of
corrupting live pages, keeping the traced step free of conditionals.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparq import SparqConfig
from repro.models.cache import CacheConfig, CacheStore

# host/device topology for the static analyzer (repro.analysis.host_lint;
# see docs/analysis.md). Pure literal — parsed with ast.literal_eval.
__analysis__ = {
    "traced": (
        "PagedCacheStore.update",
        "PagedCacheStore.write_chunk",
        "PagedCacheStore._resolve_scale",
        "PagedCacheStore._resolve_chunk_scale",
        "PagedCacheStore._encode",
        "paged_decode_attention",
        "chunked_prefill_attention",
        "adopt_prefill",
        "copy_page",
        "adopt_prefix_scales",
        "evict_slot",
        "gather_slot_pages",
        "restore_slot_pages",
    ),
    "host_loop": ("SwapStore.put", "SwapStore._to_host", "SwapStore.pop"),
    "device_returning": (),
    "device_params": ("SwapStore.put.groups", "SwapStore._to_host.groups"),
    # repro.obs metric handles: host-side floats only
    "host_objects": ("registry",),
}


class PoolExhausted(RuntimeError):
    """Raised host-side (before tracing) when the page pool runs dry."""


class ChunkMeta(NamedTuple):
    """Per-chunk metadata for the chunked ragged prefill path.

    A chunk is one fixed-shape slice of the packed token stream the
    `PrefillScheduler` (launch.prefill) builds from ragged pending
    prompts: every stream token carries its sequence slot and absolute
    position, sequence runs are contiguous and aligned to the kernel's
    query-tile size (derivable as C // tile_seq.shape[0]), and padding
    tokens are seq_id == -1. All fields are device arrays (the ChunkMeta
    is a pytree leaf-carrier traced through the jitted chunk program).

      seq_id        [C] int32 — sequence slot per token (-1 = padding)
      pos           [C] int32 — absolute prompt position per token
      hist          [C] int32 — per-token history boundary (the token's
                    segment start): attention reads packed pages for
                    kpos < hist and the chunk's float K/V for
                    kpos in [hist, pos]. Segment-granular packing makes
                    this split — and hence every prompt's numerics —
                    independent of how chunks were packed.
      tile_seq      [C/bq] int32 — slot owning each query tile (-1 pad)
      seq_pos_after [S] int32 — device seq_pos to install after the
                    chunk's writes: the prompt length for slots whose
                    prefill completes here, -1 for slots still mid-
                    prefill (keeps them inactive for interleaved decode
                    steps), and the current position for everyone else.
    """
    seq_id: jnp.ndarray
    pos: jnp.ndarray
    hist: jnp.ndarray
    tile_seq: jnp.ndarray
    seq_pos_after: jnp.ndarray


class PageAllocator:
    """Host-side refcounted free-list allocator for the shared page pool.

    Page ids are shared across layers: allocating page `p` for a sequence
    reserves physical page `p` in every layer's pool (the block table is
    one table, not per-layer). All methods are plain-Python and run between
    traced steps; `alloc` raises `PoolExhausted` *before* any tracing when
    the request cannot be satisfied.

    Pages carry **refcounts** so immutable full pages can back several
    sequences at once (shared-prefix reuse): `alloc` hands out pages at
    refcount 1, `share` adds a reference to an already-allocated page, and
    `release` drops one — a page returns to the free list only when its
    count reaches zero (`release` reports exactly which pages did, so the
    caller can invalidate any prefix-index entries naming them).
    `free` is strict release: it asserts every page was exclusively owned,
    which preserves the old guard semantics (double frees, frees of
    foreign pages, and frees of shared pages all trip it).

    `alloc` is atomic: a failing call takes nothing off the free list, so
    an exhausted multi-page request never leaks pages.
    `assert_consistent` re-checks free/refcount conservation after every
    mutation. `peak_used` is the pool's high watermark (distinct pages).
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages))
        self._ref: Dict[int, int] = {}          # page -> reference count
        self.peak_used = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        """Distinct allocated pages (each counted once however shared)."""
        return len(self._ref)

    @property
    def shared_count(self) -> int:
        """Allocated pages with more than one reference."""
        return sum(1 for c in self._ref.values() if c > 1)

    @property
    def total_refs(self) -> int:
        """Sum of all refcounts (== block-table references held)."""
        return sum(self._ref.values())

    @property
    def free_pages(self) -> Tuple[int, ...]:
        """Snapshot of the free list (copy; safe to hold across mutations)."""
        return tuple(self._free)

    def refcount(self, page: int) -> int:
        """Current reference count (0 = free / never allocated)."""
        return self._ref.get(page, 0)

    def reset_peak(self) -> None:
        """Restart the high watermark at the *current* residency — the
        warmup/measure boundary (engine.reset_stats): the peak reported
        afterwards reflects only allocations from now on."""
        self.peak_used = len(self._ref)

    @property
    def refcounts(self) -> Dict[int, int]:
        """Snapshot of page -> refcount (copy; for invariant checks)."""
        return dict(self._ref)

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"page pool exhausted: need {n} page(s), {len(self._free)} "
                f"of {self.n_pages} free ({self.used_count} resident, of "
                f"which {self.shared_count} shared across "
                f"{self.total_refs} references) — grow --n-pages, shrink "
                f"the admitted batch, enable --preempt, or wait for "
                f"evictions")
        pages, self._free = self._free[:n], self._free[n:]
        for p in pages:
            self._ref[p] = 1
        self.peak_used = max(self.peak_used, len(self._ref))
        self.assert_consistent()
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference to each (already-allocated) page — the
        shared-prefix adoption path: the new sequence's block table now
        also names these pages."""
        for p in pages:
            assert self._ref.get(p, 0) > 0, \
                f"page {p} shared while not allocated"
            self._ref[p] += 1
        self.assert_consistent()

    def release(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; pages reaching zero return to the
        free list. Returns the pages actually freed (refcount hit zero) so
        the caller can invalidate prefix-index entries naming them."""
        freed: List[int] = []
        for p in pages:
            assert 0 <= p < self.n_pages, f"page {p} outside the pool"
            assert p in self._ref, \
                f"page {p} released while not allocated (double free / " \
                f"foreign)"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                freed.append(p)
        self.assert_consistent()
        return freed

    def free(self, pages: Sequence[int]) -> None:
        """Strict release: every page must have been exclusively owned
        (refcount exactly 1). Shared pages must go through `release`."""
        pages = list(pages)
        for p in pages:
            assert self._ref.get(p, 0) <= 1, \
                f"page {p} freed while shared (refcount " \
                f"{self._ref.get(p, 0)}) — use release()"
        freed = self.release(pages)
        assert len(freed) == len(pages)

    def assert_consistent(self) -> None:
        """Refcount conservation: every page is free xor allocated with a
        positive refcount, exactly once. O(n_pages); cheap next to a
        traced decode step."""
        assert len(self._free) == len(set(self._free)), \
            "duplicate pages on the free list"
        assert not set(self._ref).intersection(self._free), \
            "page simultaneously free and allocated"
        assert all(c > 0 for c in self._ref.values()), \
            "allocated page with non-positive refcount"
        assert len(self._free) + len(self._ref) == self.n_pages, \
            "pages leaked: free + used != pool size"


# ----------------------------------------------------------------------
# shared-prefix index (host-side, non-owning)
# ----------------------------------------------------------------------

_HASH_MOD = (1 << 61) - 1       # Mersenne prime: cheap mod, no collisions
_HASH_BASE = 1_000_003          # > any token id we hash


def _segment_hash(tokens) -> int:
    """Rolling polynomial hash of one token segment (child-bucket key in
    the radix index; exact token comparison guards collisions)."""
    h = 0
    for t in tokens:
        h = (h * _HASH_BASE + int(t) + 1) % _HASH_MOD
    return h


class _PrefixNode:
    """One radix-tree node: a `quantum`-token prompt segment and the full
    pages that hold its packed K/V. Children bucket by segment hash."""
    __slots__ = ("tokens", "pages", "scales", "children", "parent", "key")

    def __init__(self, tokens, pages, scales, parent, key):
        self.tokens = tokens        # np.ndarray [quantum] token ids
        self.pages = pages          # tuple[int] physical pages, block order
        self.scales = scales        # per cache group: (k_scale, v_scale)
        self.children: Dict[int, List["_PrefixNode"]] = {}
        self.parent = parent        # None once dropped from the tree
        self.key = key              # _segment_hash(tokens)


class PrefixIndex:
    """Radix tree over prompt prefixes -> full-page runs (shared-prefix
    reuse, host-side).

    Nodes are `quantum`-token segments — `quantum = lcm(page_size,
    chunk_seg)`, so every node covers whole pages *and* whole prefill
    segments: page-whole because only fully-written, never-again-written
    pages are shareable; segment-whole because the chunked prefill packer
    resumes a tail only at a segment boundary. Children are bucketed by a
    rolling hash of the segment with exact token comparison on lookup, so
    hash collisions cost a compare, never a false match.

    The index does **not** own page references — entries are valid only
    while some sequence still holds the pages (PR 5's scheduling
    invariance makes the bytes a pure function of the prompt prefix, so
    any holder's pages are interchangeable). The engine must call
    `invalidate(freed)` with every page whose refcount reached zero
    (`PageAllocator.release`'s return value): the node naming it — and
    its whole subtree, whose prefixes include the dead pages — drop out.

    Each node also carries the donor's frozen per-layer scales: the
    §5.1 scale is frozen from the prompt's *first segment* (contained in
    every node's prefix), so every donor on a match path froze the same
    scale and a borrower adopting it decodes the shared pages
    bit-identically.
    """

    def __init__(self, quantum: int, page_size: int):
        assert quantum > 0 and quantum % page_size == 0, \
            f"quantum {quantum} must cover whole pages of {page_size}"
        self.quantum = quantum
        self.page_size = page_size
        self._root = _PrefixNode(None, (), None, None, None)
        self._by_page: Dict[int, List[_PrefixNode]] = {}

    # ----------------------------------------------------------- lookup
    @staticmethod
    def _find(node: _PrefixNode, seg: np.ndarray) -> Optional[_PrefixNode]:
        for child in node.children.get(_segment_hash(seg), ()):
            if np.array_equal(child.tokens, seg):
                return child
        return None

    def match(self, tokens) -> Tuple[int, List[int], Optional[list]]:
        """Longest indexed prefix of `tokens`, in whole quanta.

        Returns (n_matched_tokens, pages, scales): the pages backing
        prompt positions [0, n) in block order and the deepest matched
        node's frozen scales (None on a miss). n is always a multiple of
        `quantum`; 0 means no match."""
        tokens = np.asarray(tokens)
        q = self.quantum
        node, pages, scales, n = self._root, [], None, 0
        for d in range(len(tokens) // q):
            child = self._find(node, tokens[d * q:(d + 1) * q])
            if child is None:
                break
            node = child
            pages.extend(child.pages)
            scales = child.scales
            n += q
        return n, pages, scales

    # ----------------------------------------------------------- insert
    def insert(self, tokens, pages: Sequence[int], scales) -> int:
        """Index the whole-quantum prefix of a freshly prefilled prompt.

        `pages`: the sequence's pages in block order (at least the blocks
        covering the indexed prefix); `scales`: its frozen per-layer
        scales, per cache group. Segments already present keep their
        existing pages (first donor wins — both copies are bit-identical
        by scheduling invariance, and the existing entry may already be
        shared). Returns the number of tokens indexed."""
        tokens = np.asarray(tokens)
        q, ps = self.quantum, self.page_size
        ppn = q // ps                       # pages per node
        depth = len(tokens) // q
        assert len(pages) >= depth * ppn, "pages do not cover the prefix"
        node = self._root
        for d in range(depth):
            seg = tokens[d * q:(d + 1) * q]
            child = self._find(node, seg)
            if child is None:
                child = _PrefixNode(
                    np.array(seg), tuple(int(p) for p in
                                         pages[d * ppn:(d + 1) * ppn]),
                    scales, node, _segment_hash(seg))
                node.children.setdefault(child.key, []).append(child)
                for p in child.pages:
                    self._by_page.setdefault(p, []).append(child)
            node = child
        return depth * q

    # ------------------------------------------------------- invalidate
    def invalidate(self, pages: Sequence[int]) -> int:
        """Drop every entry naming any of `pages` (they were released to
        zero and may be reallocated with different bytes), including
        subtrees — a deeper node's prefix contains its ancestors' pages.
        Returns the number of nodes dropped."""
        dropped = 0
        for p in pages:
            for node in list(self._by_page.get(p, ())):
                dropped += self._drop(node)
        return dropped

    def _drop(self, node: _PrefixNode) -> int:
        if node.parent is None:             # root, or already dropped
            return 0
        bucket = node.parent.children.get(node.key)
        if bucket is not None and node in bucket:
            bucket.remove(node)
            if not bucket:
                del node.parent.children[node.key]
        node.parent = None
        for p in node.pages:
            b = self._by_page.get(p)
            if b is not None and node in b:
                b.remove(node)
                if not b:
                    del self._by_page[p]
        dropped = 1
        for bucket in list(node.children.values()):
            for child in list(bucket):
                dropped += self._drop(child)
        node.children = {}
        return dropped

    # ------------------------------------------------------------ stats
    @property
    def n_nodes(self) -> int:
        count, stack = 0, [self._root]
        while stack:
            n = stack.pop()
            for bucket in n.children.values():
                count += len(bucket)
                stack.extend(bucket)
        return count

    @property
    def indexed_pages(self) -> Tuple[int, ...]:
        """Distinct pages currently named by some entry (sorted)."""
        return tuple(sorted(self._by_page))


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("k_data", "k_meta", "v_data", "v_meta",
                                "k_scale", "v_scale", "block_table",
                                "seq_pos"),
                   meta_fields=("codec", "impl", "mesh"))
@dataclasses.dataclass
class PagedCacheStore:
    """Paged KV cache for one attention layer (sparq layout only).

    Shapes (S = sequence slots, P = n_pages + 1 trash, ps = page_size,
    NB = max logical blocks per sequence):

      k/v_data, k/v_meta  int8  [P, ps, KV, hd]   packed §5.1 page pools
      k/v_scale           f32   [S]               per-sequence site scales
                                                  (0 = uncalibrated; set by
                                                  adopt_prefill, frozen for
                                                  decode writes)
      block_table         int32 [S, NB]           physical page per logical
                                                  block (-1 = unallocated)
      seq_pos             int32 [S]               tokens written per slot
                                                  (-1 = inactive slot)
    """
    k_data: jnp.ndarray
    k_meta: jnp.ndarray
    v_data: jnp.ndarray
    v_meta: jnp.ndarray
    k_scale: jnp.ndarray
    v_scale: jnp.ndarray
    block_table: jnp.ndarray
    seq_pos: jnp.ndarray
    codec: Optional[SparqConfig] = None
    impl: str = "auto"
    #: optional ("data","model") jax Mesh. When set, attention reads run
    #: tensor-parallel via shard_map over the "model" axis (pools shard
    #: along the KV-head axis; see kernels.ops.tp_size) and the engine
    #: places the pool planes with a matching NamedSharding.
    mesh: Optional[jax.sharding.Mesh] = None

    # -------------------------------------------------------------- init
    @staticmethod
    def init(n_seqs: int, n_pages: int, page_size: int, n_blocks: int,
             kv_heads: int, head_dim: int, cc: CacheConfig,
             mesh: Optional[jax.sharding.Mesh] = None
             ) -> "PagedCacheStore":
        if cc.layout != "sparq":
            raise ValueError(
                "PagedCacheStore stores the packed §5.1 planes; use "
                "--kv-cache sparq (fp paging would just be fp paging — the "
                "point of the pool is that the hot loop reads packed bytes)")
        assert head_dim % 2 == 0, \
            f"sparq pairs adjacent lanes; head_dim must be even: {head_dim}"
        shp = (n_pages + 1, page_size, kv_heads, head_dim)  # +1: trash page
        return PagedCacheStore(
            k_data=jnp.zeros(shp, jnp.int8),
            k_meta=jnp.zeros(shp, jnp.int8),
            v_data=jnp.zeros(shp, jnp.int8),
            v_meta=jnp.zeros(shp, jnp.int8),
            k_scale=jnp.zeros((n_seqs,), jnp.float32),
            v_scale=jnp.zeros((n_seqs,), jnp.float32),
            block_table=jnp.full((n_seqs, n_blocks), -1, jnp.int32),
            seq_pos=jnp.full((n_seqs,), -1, jnp.int32),
            codec=cc.sparq, impl=cc.impl, mesh=mesh)

    # --------------------------------------------------------- geometry
    @property
    def n_seqs(self) -> int:
        return self.seq_pos.shape[-1]

    @property
    def page_size(self) -> int:
        return self.k_data.shape[-3]

    @property
    def n_pages(self) -> int:        # usable pages (excludes the trash page)
        return self.k_data.shape[-4] - 1

    @property
    def n_blocks(self) -> int:
        return self.block_table.shape[-1]

    # ------------------------------------------------------------- write
    def _resolve_scale(self, stored: jnp.ndarray, x: jnp.ndarray
                       ) -> jnp.ndarray:
        """Per-sequence scale: frozen once calibrated (> 0), else set from
        this write's dynamic range — same policy as CachedTensor, per slot."""
        dyn = jnp.maximum(
            jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 2, 3)), 1e-8) \
            / self.codec.max_val
        return jnp.where(stored > 0, stored, dyn)

    def _encode(self, x: jnp.ndarray, scale: jnp.ndarray):
        """float [S, KV, hd] -> (§5.1 window codes, meta bytes), int8.

        Same codec semantics as CachedTensor._encode but with a per-slot
        scale vector; the reference quantizer is elementwise over leading
        axes, so codes match the contiguous path's (scalar-scale) codes
        bit for bit slot-by-slot. Decode writes are S*KV*hd values — noise
        next to the attention reads, so no Pallas dispatch here.
        """
        from repro.kernels import ref as _ref
        from repro.kernels.ops import sparq_pack
        cfg = self.codec
        codes, meta = _ref.ref_sparq_quant(
            x.astype(jnp.float32), scale[:, None, None],
            bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
            vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
            enabled=cfg.enabled)
        return sparq_pack(codes, meta), meta

    def _pin_pools(self, store: "PagedCacheStore") -> "PagedCacheStore":
        """Re-assert the KV-head NamedSharding on freshly written pool
        planes. The scatter of a (replicated) token write into a sharded
        pool is exact per shard, but without the constraint GSPMD may
        pick a different output sharding — which would both break the
        jitted step's donation (in/out shardings must match) and force a
        reshard. No-op without a mesh."""
        if self.mesh is None:
            return store
        from repro.distributed.sharding import pool_plane_sharding
        sh = pool_plane_sharding(self.mesh, store.k_data.ndim)
        pin = lambda x: jax.lax.with_sharding_constraint(x, sh)
        return dataclasses.replace(
            store, k_data=pin(store.k_data), k_meta=pin(store.k_meta),
            v_data=pin(store.v_data), v_meta=pin(store.v_meta))

    def update(self, k_new: jnp.ndarray, v_new: jnp.ndarray
               ) -> "PagedCacheStore":
        """Write one decode token per sequence slot and advance positions.

        k_new/v_new: float [S, 1, KV, hd]. Slot `s` writes its token at
        logical position seq_pos[s] — physical page
        block_table[s, pos // ps], row pos % ps. Inactive slots (seq_pos
        < 0) and unallocated blocks write to the trash page, so the traced
        step needs no host-side masking; the engine guarantees active
        sequences always have their current block allocated.
        """
        S, T = k_new.shape[:2]
        assert T == 1, f"paged decode writes one token per step, got {T}"
        ps = self.page_size
        trash = self.k_data.shape[0] - 1
        pos = self.seq_pos
        active = pos >= 0
        eff = jnp.maximum(pos, 0)
        blk = jnp.minimum(eff // ps, self.n_blocks - 1)
        page = self.block_table[jnp.arange(S), blk]
        page = jnp.where(active & (page >= 0), page, trash)
        off = eff % ps

        k_scale = self._resolve_scale(self.k_scale, k_new)
        v_scale = self._resolve_scale(self.v_scale, v_new)
        kd, km = self._encode(k_new[:, 0], k_scale)
        vd, vm = self._encode(v_new[:, 0], v_scale)
        return self._pin_pools(dataclasses.replace(
            self,
            k_data=self.k_data.at[page, off].set(kd),
            k_meta=self.k_meta.at[page, off].set(km),
            v_data=self.v_data.at[page, off].set(vd),
            v_meta=self.v_meta.at[page, off].set(vm),
            k_scale=jnp.where(active, k_scale, self.k_scale),
            v_scale=jnp.where(active, v_scale, self.v_scale),
            seq_pos=jnp.where(active, pos + 1, pos)))

    def _resolve_chunk_scale(self, stored: jnp.ndarray, x: jnp.ndarray,
                             s_safe: jnp.ndarray,
                             first_seg: jnp.ndarray) -> jnp.ndarray:
        """Per-sequence scale for a chunk write: frozen once calibrated
        (> 0), else set from the dynamic range of this sequence's
        *first-segment* tokens (`first_seg`: valid tokens with hist == 0)
        — never from whatever later segments happened to share the
        chunk, so the frozen scale is a function of (prompt, seg) alone
        and identical under every stream packing (the §5.1
        scale-freeze-at-first-write policy, applied at the segment
        boundary). For a prompt that fits one segment this is exactly
        the contiguous prefill's whole-prompt range — bit-identical
        scale, hence bit-identical bytes. A sequence's first segment is
        always its first chunk appearance (jobs advance in order), so a
        chunk carrying only later segments finds `stored` already
        frozen; slots with no first-segment tokens are untouched."""
        tok_max = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 2))
        tok_max = jnp.where(first_seg, tok_max, 0.0)
        S = stored.shape[0]
        seq_max = jnp.zeros((S,), jnp.float32).at[s_safe].max(tok_max)
        dyn = jnp.maximum(seq_max, 1e-8) / self.codec.max_val
        has = jnp.zeros((S,), bool).at[s_safe].max(first_seg)
        return jnp.where(stored > 0, stored, jnp.where(has, dyn, stored))

    def write_chunk(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
                    meta: "ChunkMeta") -> "PagedCacheStore":
        """Scatter one prefill chunk's K/V directly into the page pool.

        k_new/v_new: float [C, KV, hd] — the chunk's freshly projected
        K/V in stream order. Token i quantizes with its sequence's scale
        (resolved per `_resolve_chunk_scale`) through the same §5.1 codec
        as every other write path and lands at physical page
        block_table[seq_id[i], pos[i] // ps], row pos[i] % ps — no
        contiguous staging cache and no adopt_prefill copy. Padding
        tokens and unallocated blocks write to the trash page. seq_pos is
        replaced wholesale by meta.seq_pos_after (the engine computes it
        host-side; mid-prefill slots stay at -1 so interleaved decode
        steps treat them as inactive)."""
        ps = self.page_size
        trash = self.k_data.shape[0] - 1
        sid = meta.seq_id
        valid = sid >= 0
        s_safe = jnp.maximum(sid, 0)
        first_seg = valid & (meta.hist == 0)
        k_scale = self._resolve_chunk_scale(self.k_scale, k_new,
                                            s_safe, first_seg)
        v_scale = self._resolve_chunk_scale(self.v_scale, v_new,
                                            s_safe, first_seg)
        kd, km = self._encode(k_new, k_scale[s_safe])
        vd, vm = self._encode(v_new, v_scale[s_safe])
        eff = jnp.maximum(meta.pos, 0)
        blk = jnp.minimum(eff // ps, self.n_blocks - 1)
        page = self.block_table[s_safe, blk]
        page = jnp.where(valid & (page >= 0), page, trash)
        off = eff % ps
        return self._pin_pools(dataclasses.replace(
            self,
            k_data=self.k_data.at[page, off].set(kd),
            k_meta=self.k_meta.at[page, off].set(km),
            v_data=self.v_data.at[page, off].set(vd),
            v_meta=self.v_meta.at[page, off].set(vm),
            k_scale=k_scale, v_scale=v_scale,
            seq_pos=meta.seq_pos_after))


# ----------------------------------------------------------------------
# attention read path
# ----------------------------------------------------------------------

def paged_decode_attention(q: jnp.ndarray, store: PagedCacheStore, *,
                           window: int = 0) -> jnp.ndarray:
    """Fused flash-decode over the page pool. q [S, 1, H, hd].

    Per-sequence `cur` comes from the store's positions (the token written
    by the preceding `update`), per-sequence scales from its calibration —
    one traced call serves slots of ragged lengths. Inactive slots are
    fully masked and return zeros."""
    from repro.kernels.ops import sparq_paged_decode_attention
    out = sparq_paged_decode_attention(
        q, store.k_data, store.k_meta, store.k_scale,
        store.v_data, store.v_meta, store.v_scale,
        store.block_table, store.seq_pos - 1, window=window,
        impl=store.impl, mesh=store.mesh)
    return out.astype(q.dtype)


def chunked_prefill_attention(q: jnp.ndarray, k_chunk: jnp.ndarray,
                              v_chunk: jnp.ndarray, store: PagedCacheStore,
                              meta: ChunkMeta, *,
                              window: int = 0) -> jnp.ndarray:
    """Ragged chunked-prefill attention for one layer. q [1, C, H, hd];
    k_chunk/v_chunk [C, KV, hd] float (this chunk's own projections,
    pre-quantization). Each stream token attends to its sequence's
    already-written packed pages for kpos < meta.hist (its per-token
    history boundary) plus the causally/segment-masked float window
    [hist, pos] of the chunk itself — so calling this on the
    post-`write_chunk` store is correct, and required: a token's earlier
    *segments* may have been written by this very chunk program. Padding
    rows return zeros."""
    from repro.kernels.ops import sparq_chunked_prefill_attention
    nt = meta.tile_seq.shape[0]
    C = q.shape[1]
    out = sparq_chunked_prefill_attention(
        q[0], k_chunk, v_chunk,
        store.k_data, store.k_meta, store.k_scale,
        store.v_data, store.v_meta, store.v_scale,
        store.block_table, meta.seq_id, meta.pos, meta.hist,
        meta.tile_seq, window=window, impl=store.impl, bq=C // nt,
        mesh=store.mesh)
    return out[None].astype(q.dtype)


# ----------------------------------------------------------------------
# engine-level transitions (operate on the layer-stacked store: every
# array leaf carries a leading layer axis, scales/pos one per layer)
# ----------------------------------------------------------------------

def adopt_prefill(store: PagedCacheStore, cs: CacheStore,
                  slot: jnp.ndarray, pages: jnp.ndarray) -> PagedCacheStore:
    """Move a prefill'd sequence into the pool at `slot`, backed by `pages`.

    `cs` is the layer-stacked contiguous sparq cache the model's prefill
    just filled for this one sequence (batch 1, capacity == len(pages) *
    page_size). Its packed planes are copied page-by-page into the pools
    and its calibrated per-layer scales become the slot's scales — no
    re-quantization, so the pool bytes are bit-identical to the contiguous
    cache's. Rows past the prompt are the contiguous cache's zero
    initialization; they are masked (position > cur) until decode writes
    overwrite them, which also makes page *reuse* after eviction exact:
    adoption rewrites every byte of every page it claims.

    slot: int32 scalar sequence-slot index; pages: int32 [n_blocks_prompt].
    """
    nbp = pages.shape[0]
    L = store.k_data.shape[0]
    ps = store.k_data.shape[-3]

    def put(pool, plane):        # plane [L, 1, nbp*ps, KV, hd]
        blocks = plane.reshape(L, nbp, ps, *plane.shape[3:])
        return pool.at[:, pages].set(blocks)

    bt_row = jnp.full((store.block_table.shape[-1],), -1,
                      jnp.int32).at[:nbp].set(pages)
    return dataclasses.replace(
        store,
        k_data=put(store.k_data, cs.k.data),
        k_meta=put(store.k_meta, cs.k.meta),
        v_data=put(store.v_data, cs.v.data),
        v_meta=put(store.v_meta, cs.v.meta),
        k_scale=store.k_scale.at[:, slot].set(cs.k.scale),
        v_scale=store.v_scale.at[:, slot].set(cs.v.scale),
        block_table=store.block_table.at[:, slot].set(bt_row),
        seq_pos=store.seq_pos.at[:, slot].set(cs.pos))


def copy_page(store: PagedCacheStore, src: jnp.ndarray,
              dst: jnp.ndarray) -> PagedCacheStore:
    """Copy one physical page's packed planes to another (layer-stacked
    store) — the copy-on-write step of shared-prefix admission: when a
    new sequence's unshared tail begins mid-page, the partially-covered
    boundary page is duplicated so the tail prefill rewrites a private
    copy and never a page another sequence reads (refcount > 1 pages are
    write-never). A raw byte copy of all four §5.1 planes: rows below
    the tail boundary stay bit-identical to the shared original; rows at
    and above it are stale bytes the tail chunk overwrites."""
    upd = {name: getattr(store, name).at[:, dst].set(
        getattr(store, name)[:, src]) for name in _SWAP_PLANES}
    return dataclasses.replace(store, **upd)


def adopt_prefix_scales(store: PagedCacheStore, slot: jnp.ndarray,
                        k_scale: jnp.ndarray, v_scale: jnp.ndarray
                        ) -> PagedCacheStore:
    """Install a donor's frozen per-layer scales on `slot` (layer-stacked
    store; k_scale/v_scale [L] f32). Shared-prefix admission must do this
    *before* the tail prefill runs: the slot's scale would otherwise
    still be 0 (uncalibrated) — the tail carries no first-segment tokens
    to freeze it from — and §5.1 decode of the shared pages needs exactly
    the scale their bytes were encoded with. The donor froze its scale
    from the prompt's first segment, which is inside the shared prefix,
    so the adopted scale equals the scale the borrower would have frozen
    itself: adoption changes nothing numerically, it only short-circuits
    recomputation."""
    return dataclasses.replace(
        store,
        k_scale=store.k_scale.at[:, slot].set(k_scale),
        v_scale=store.v_scale.at[:, slot].set(v_scale))


def evict_slot(store: PagedCacheStore, slot: jnp.ndarray) -> PagedCacheStore:
    """Clear a finished sequence slot (layer-stacked store).

    Drops the block-table row, deactivates the position, and zeroes the
    scales so the next occupant recalibrates. The pages themselves are
    returned to the free list by the engine (host side); their stale bytes
    are fully overwritten on next adoption."""
    return dataclasses.replace(
        store,
        block_table=store.block_table.at[:, slot].set(-1),
        seq_pos=store.seq_pos.at[:, slot].set(-1),
        k_scale=store.k_scale.at[:, slot].set(0.0),
        v_scale=store.v_scale.at[:, slot].set(0.0))


# ----------------------------------------------------------------------
# swap-out / swap-in (preemption support; operate on layer-stacked stores)
# ----------------------------------------------------------------------

_SWAP_PLANES = ("k_data", "k_meta", "v_data", "v_meta")


def gather_slot_pages(store: PagedCacheStore, slot: jnp.ndarray,
                      pages: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Collect the packed planes and scales backing one sequence slot.

    `store` is layer-stacked; `pages` ([nbp] int32) are the physical pages
    the slot owns, in block order. Returns a dict of device arrays — each
    pool plane gathered at `pages` ([L, nbp, ps, KV, hd] int8) plus the
    per-layer scales ([L] f32). A pure gather of the raw §5.1 bytes: no
    dequantization, no requantization — what leaves the pool is exactly
    what `restore_slot_pages` puts back, so a swap round trip is
    byte-verbatim by construction.
    """
    out = {name: getattr(store, name)[:, pages] for name in _SWAP_PLANES}
    out["k_scale"] = store.k_scale[:, slot]
    out["v_scale"] = store.v_scale[:, slot]
    return out


def restore_slot_pages(store: PagedCacheStore, planes: Dict[str, jnp.ndarray],
                       slot: jnp.ndarray, pages: jnp.ndarray,
                       pos: jnp.ndarray) -> PagedCacheStore:
    """Inverse of `gather_slot_pages`: scatter swapped planes back into the
    pool (any pages — swap-in need not land on the pages swapped out of),
    rebind the slot's block table, scales, and position. Every byte of
    every claimed page is overwritten, so swap-in onto recycled pages is
    exact for the same reason prefill adoption is."""
    upd = {name: getattr(store, name).at[:, pages].set(planes[name])
           for name in _SWAP_PLANES}
    nbp = pages.shape[0]
    bt_row = jnp.full((store.block_table.shape[-1],), -1,
                      jnp.int32).at[:nbp].set(pages)
    return dataclasses.replace(
        store, **upd,
        k_scale=store.k_scale.at[:, slot].set(planes["k_scale"]),
        v_scale=store.v_scale.at[:, slot].set(planes["v_scale"]),
        block_table=store.block_table.at[:, slot].set(bt_row),
        seq_pos=store.seq_pos.at[:, slot].set(pos))


class SwapStore:
    """Host-side swap space for preempted sequences' packed pages.

    One entry per preempted request: the verbatim §5.1 packed byte planes
    (data + meta for K and V) of every page the sequence owned, its
    per-layer calibrated scales, and its position — one dict per cache
    group (the engine serves a list of layer-stacked stores). `put`
    fetches the gathered device planes to numpy (the modeled §5.1
    traffic is 0.5625 B/value data + 0.375 B/value ctrl = 0.9375 B/value
    — ~4.3x less than swapping fp32 planes) and `pop` hands them back for
    `restore_slot_pages`. Byte counters track the swap traffic and
    residency so schedulers and benchmarks can report it.
    """

    def __init__(self, registry=None):
        """`registry`, when given, is a repro.obs MetricsRegistry the
        byte counters mirror into (`swap_bytes_total{dir=out|in}`,
        `swap_resident_bytes` / `swap_peak_bytes` gauges). The plain
        attributes below stay authoritative; the engine's reset_stats
        pairs registry.reset() with reset_counters() so the two views
        never diverge."""
        self._entries: Dict[int, dict] = {}
        self.bytes_out = 0          # cumulative device -> host
        self.bytes_in = 0           # cumulative host -> device
        self.peak_bytes = 0         # peak host residency
        self._c_out = self._c_in = None
        self._g_res = self._g_peak = None
        if registry is not None:
            c = registry.counter("swap_bytes_total",
                                 "packed swap traffic by direction",
                                 unit="bytes", labelnames=("dir",))
            self._c_out = c.series(dir="out")
            self._c_in = c.series(dir="in")
            self._g_res = registry.gauge(
                "swap_resident_bytes",
                "packed bytes parked host-side", unit="bytes").series()
            self._g_peak = registry.gauge(
                "swap_peak_bytes",
                "peak host-side swap residency", unit="bytes").series()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    @property
    def resident_bytes(self) -> int:
        return sum(e["nbytes"] for e in self._entries.values())

    @staticmethod
    def _to_host(groups) -> Tuple[List[dict], int]:
        # one explicit fetch of the whole pytree — per-plane np.asarray
        # is an implicit sync per plane on the scheduler path (HL202)
        host = jax.device_get([dict(planes) for planes in groups])
        nbytes = sum(int(a.nbytes) for hp in host for a in hp.values())
        return host, nbytes

    def put(self, key: int, groups: Sequence[dict], pos: int) -> int:
        """Swap a sequence out. `groups`: one gather_slot_pages dict per
        cache group (device arrays); `pos` its seq position. Returns the
        bytes moved to host."""
        assert key not in self._entries, f"request {key} already swapped"
        host, nbytes = self._to_host(groups)
        self._entries[key] = {"groups": host, "pos": int(pos),
                              "nbytes": nbytes}
        self.bytes_out += nbytes
        self.peak_bytes = max(self.peak_bytes, self.resident_bytes)
        if self._c_out is not None:
            self._c_out.inc(nbytes)
            self._g_res.set(self.resident_bytes)
            self._g_peak.set_max(self.peak_bytes)
        return nbytes

    def pos(self, key: int) -> int:
        return self._entries[key]["pos"]

    def n_pages(self, key: int) -> int:
        return int(self._entries[key]["groups"][0]["k_data"].shape[1])

    def pop(self, key: int) -> Tuple[List[dict], int]:
        """Swap a sequence back in: returns (host plane dicts per group,
        pos) and drops the entry."""
        entry = self._entries.pop(key)
        self.bytes_in += entry["nbytes"]
        if self._c_in is not None:
            self._c_in.inc(entry["nbytes"])
            self._g_res.set(self.resident_bytes)
        return entry["groups"], entry["pos"]

    def discard(self, key: int) -> int:
        """Drop a parked entry without restoring it (a cancelled
        request): the planes are simply forgotten, so no swap-in traffic
        is charged — `bytes_in` counts bytes that actually crossed back.
        Returns the bytes released from host residency."""
        nbytes = int(self._entries.pop(key)["nbytes"])
        if self._g_res is not None:
            self._g_res.set(self.resident_bytes)
        return nbytes

    def reset_counters(self) -> None:
        """Zero the traffic counters and restart the residency peak at
        the current footprint — the warmup/measure boundary
        (engine.reset_stats)."""
        self.bytes_out = 0
        self.bytes_in = 0
        self.peak_bytes = self.resident_bytes
        if self._g_res is not None:
            self._g_res.set(self.resident_bytes)
            self._g_peak.set(self.peak_bytes)


# ----------------------------------------------------------------------
# footprint accounting
# ----------------------------------------------------------------------

def modeled_pool_bytes(stores) -> dict:
    """Model the §5.1 HBM residency of the page pools.

    Walks a pytree of PagedCacheStore (stacked or not); the packed pools
    are charged the `kernels.ops` data/ctrl figures (one meta plane models
    the ShiftCtrl side-band + MuxCtrl already folded into the data-plane
    figure, so we charge values once), bookkeeping arrays (block tables,
    positions, scales) at their actual dtype sizes."""
    from repro.kernels.ops import ctrl_bytes_per_value, data_bytes_per_value
    tally = {"data_bytes": 0.0, "ctrl_bytes": 0.0, "values": 0,
             "other_bytes": 0.0}

    def visit(st):
        n = st.k_data.size + st.v_data.size
        tally["data_bytes"] += n * data_bytes_per_value(st.codec)
        tally["ctrl_bytes"] += n * ctrl_bytes_per_value(st.codec)
        tally["values"] += n
        for extra in (st.k_scale, st.v_scale, st.block_table, st.seq_pos):
            tally["other_bytes"] += extra.size * extra.dtype.itemsize
        return st

    jax.tree.map(visit, stores,
                 is_leaf=lambda n: isinstance(n, PagedCacheStore))
    tally["total_bytes"] = (tally["data_bytes"] + tally["ctrl_bytes"] +
                            tally["other_bytes"])
    return tally


def modeled_pool_bytes_per_device(stores) -> dict:
    """Per-device share of `modeled_pool_bytes` under tensor parallelism.

    The packed pool planes (and their ShiftCtrl side-band) shard along
    the KV-head axis over the mesh's "model" axis, so each device holds
    exactly 1/tp of the data+ctrl bytes; bookkeeping (block tables,
    positions, per-sequence scales) is replicated and charged in full.
    With no mesh (tp=1) this equals `modeled_pool_bytes`."""
    from repro.kernels.ops import tp_size
    meshes = set()

    def visit(st):
        meshes.add(st.mesh)
        return st

    jax.tree.map(visit, stores,
                 is_leaf=lambda n: isinstance(n, PagedCacheStore))
    assert len(meshes) == 1, f"stores disagree on mesh: {meshes}"
    tp = tp_size(next(iter(meshes)))
    tally = modeled_pool_bytes(stores)
    out = dict(tally)
    out["tp"] = tp
    out["data_bytes"] = tally["data_bytes"] / tp
    out["ctrl_bytes"] = tally["ctrl_bytes"] / tp
    out["total_bytes"] = (out["data_bytes"] + out["ctrl_bytes"] +
                          tally["other_bytes"])
    return out
