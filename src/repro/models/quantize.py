"""Offline weight quantization for serving (paper deployment + §Perf iter:
pre-quantized int8 weights quarter the per-layer FSDP weight-gather bytes
vs gathering f32 masters and quantizing in-step).

`quantize_params` replaces every matmul weight leaf `w` with
{"q": int8 codes, "s": f32 per-output-channel scales}; scan slicing, pjit
sharding and checkpointing all treat the dict as an ordinary pytree.
Excluded: embeddings/lm_head (the paper leaves boundary layers intact),
norms/vectors, routers (routing precision), 4-D stacked MoE expert banks
(einsum path — quantized via the activation side only), conv kernels.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.quantizer import quantize, weight_scale

_QUANT_KEYS = (
    "wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down",
    "w_r", "w_k", "w_v", "w_g", "w_o", "w_ck", "w_cr", "w_cv",
    "w_dkv", "w_uk", "w_uv", "w_y", "w_x", "w_a", "w_i", "w_out",
)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "name", None))
        if isinstance(key, str):
            return key
    return ""


def quantize_params(params: Any, weight_bits: int = 8) -> Any:
    """Float param tree -> serving tree with int8 weight codes."""
    def q(path, leaf):
        name = _leaf_name(path)
        if name not in _QUANT_KEYS or leaf.ndim not in (2, 3):
            return leaf
        if leaf.ndim == 2:
            qs = weight_scale(leaf, weight_bits)
            return {"q": quantize(leaf, qs).astype(jnp.int8),
                    "s": qs.scale.astype(jnp.float32)}
        # stacked [L, din, dout]: per-layer per-channel scales [L, dout]
        qs_scale = jnp.max(jnp.abs(leaf), axis=1) / \
            ((1 << (weight_bits - 1)) - 1)
        qs_scale = jnp.maximum(qs_scale, 1e-8)
        codes = jnp.clip(jnp.round(leaf / qs_scale[:, None, :]),
                         -127, 127).astype(jnp.int8)
        return {"q": codes, "s": qs_scale.astype(jnp.float32)}

    return jax.tree_util.tree_map_with_path(q, params)


def is_qweight(w) -> bool:
    return isinstance(w, dict) and "q" in w and "s" in w


def as_weight(w, dtype) -> jnp.ndarray:
    """Dequantize a (possibly) quantized weight leaf to a float array."""
    if is_qweight(w):
        s = w["s"]
        if w["q"].ndim == 3 and s.ndim == 2:
            s = s[:, None, :]   # stacked [L, din, dout] x scales [L, dout]
        return (w["q"].astype(jnp.float32) * s).astype(dtype)
    return w.astype(dtype)
