"""Paper-faithful CNN substrate (the paper evaluates ResNet-family CNNs).

Mini-ResNet with ReLU + BatchNorm. Convolutions execute through im2col +
`dense()` in calibrate/quantized modes, which is exactly the paper's setting
("standard practice to map the convolution operation to matrix
multiplication", §4): SPARQ sees the unsigned post-ReLU activation matrix.
The first conv is left intact (paper §5). BatchNorm running statistics are
recalibrated during calibration (paper §5, refs [29,33,35,36]).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import QuantCtx, dense


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "paper-resnet"
    num_classes: int = 16
    width: int = 32
    stages: tuple = (1, 1, 1)    # residual blocks per stage
    img_size: int = 32
    in_channels: int = 3
    noise: float = 0.45          # additive pixel noise (task difficulty)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def _conv(params_w, x, stride, site, ctx: Optional[QuantCtx]):
    """3x3 same conv; im2col+dense in quant paths (so SPARQ applies)."""
    kh, kw, cin, cout = params_w.shape
    if ctx is None or ctx.mode == "off":
        return jax.lax.conv_general_dilated(
            x, params_w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))  # [B,H,W,cin*kh*kw]
    w2 = params_w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    return dense(w2, patches, site, ctx)


def _bn(params, x, train: bool, eps=1e-5):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
    else:
        mean, var = params["mean"], params["var"]
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * params["scale"] + params["bias"], (mean, var)


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def init_params(key, cfg: CNNConfig) -> Dict:
    keys = iter(jax.random.split(key, 64))

    def conv_w(cin, cout):
        fan = 9 * cin
        return jax.random.truncated_normal(
            next(keys), -2, 2, (3, 3, cin, cout)) * (2.0 / fan) ** 0.5

    p = {"stem": {"w": conv_w(cfg.in_channels, cfg.width),
                  "bn": _bn_init(cfg.width)},
         "stages": [], "head": None}
    c = cfg.width
    for si, n_blocks in enumerate(cfg.stages):
        cout = cfg.width * (2 ** si)
        stage = []
        for bi in range(n_blocks):
            blk = {"w1": conv_w(c, cout), "bn1": _bn_init(cout),
                   "w2": conv_w(cout, cout), "bn2": _bn_init(cout)}
            if c != cout:
                blk["proj"] = conv_w(c, cout)
            stage.append(blk)
            c = cout
        p["stages"].append(stage)
    p["head"] = jax.random.truncated_normal(
        next(keys), -2, 2, (c, cfg.num_classes)) * (1.0 / c) ** 0.5
    return p


def forward(params, x, cfg: CNNConfig, ctx: Optional[QuantCtx] = None,
            train: bool = False, bn_stats: Optional[dict] = None):
    """Returns (logits, batch_bn_stats). The first conv is never quantized
    (paper §5); its site is 'stem' and is always in skip mode."""
    stem_ctx = None  # first layer left intact
    h = _conv(params["stem"]["w"], x, 1, "stem", stem_ctx)
    h, s = _bn(params["stem"]["bn"], h, train)
    stats = {"stem": s}
    h = jax.nn.relu(h)
    for si, stage in enumerate(params["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if (bi == 0 and si > 0) else 1
            pre = ctx
            if pre is not None:  # per-block site names (calibrate + eval)
                pre = dataclasses.replace(
                    pre, site_prefix=f"s{si}b{bi}/")
            hh = _conv(blk["w1"], h, stride, "conv1", pre)
            hh, s1 = _bn(blk["bn1"], hh, train)
            hh = jax.nn.relu(hh)
            hh = _conv(blk["w2"], hh, 1, "conv2", pre)
            hh, s2 = _bn(blk["bn2"], hh, train)
            skip = h
            if "proj" in blk:
                skip = _conv(blk["proj"], h, stride, "proj", pre)
            h = jax.nn.relu(hh + skip)
            stats[f"s{si}b{bi}"] = (s1, s2)
    pooled = jnp.mean(h, axis=(1, 2))
    return jnp.matmul(pooled, params["head"]), stats


def loss_fn(params, batch, cfg: CNNConfig, train=True):
    logits, _ = forward(params, batch["image"], cfg, train=train)
    labels = jax.nn.one_hot(batch["label"], cfg.num_classes)
    ce = -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))
    return ce


def accuracy(params, batch, cfg: CNNConfig,
             ctx: Optional[QuantCtx] = None) -> jnp.ndarray:
    logits, _ = forward(params, batch["image"], cfg, ctx=ctx, train=False)
    return jnp.mean((jnp.argmax(logits, -1) == batch["label"]))


def recalibrate_bn(params, batches, cfg: CNNConfig):
    """Paper §5: recompute BN running stats on the calibration set.

    Cumulative average over the calibration batches (momentum 1/i), so the
    result is the calibration-set statistics themselves — an EMA from the
    init stats would keep (1-m)^k of the stale zeros/ones and leave eval
    normalization biased for small calibration sets."""
    params = jax.tree.map(lambda a: a, params)  # shallow copy

    def update(bn, mean, var, momentum):
        bn["mean"] = (1 - momentum) * bn["mean"] + momentum * mean
        bn["var"] = (1 - momentum) * bn["var"] + momentum * var

    for i, batch in enumerate(batches):
        momentum = 1.0 / (i + 1)
        _, stats = forward(params, batch["image"], cfg, train=True)
        update(params["stem"]["bn"], *stats["stem"], momentum)
        for si, stage in enumerate(params["stages"]):
            for bi, blk in enumerate(stage):
                (m1, v1), (m2, v2) = stats[f"s{si}b{bi}"]
                update(blk["bn1"], m1, v1, momentum)
                update(blk["bn2"], m2, v2, momentum)
    return params


def synthetic_dataset(key, cfg: CNNConfig, n: int):
    """Deterministic synthetic classification: class = which quadrant-
    pattern of oriented gratings is present. Learnable by a small CNN in a
    few hundred CPU steps, sensitive enough that quantization noise shows."""
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (n,), 0, cfg.num_classes)
    S = cfg.img_size
    yy, xx = jnp.mgrid[0:S, 0:S]
    freqs = 2 * jnp.pi * (1 + jnp.arange(cfg.num_classes) % 4) / 16.0
    angles = jnp.pi * (jnp.arange(cfg.num_classes) // 4) / 4.0
    f, a = freqs[labels], angles[labels]
    phase = jax.random.uniform(k2, (n,)) * 2 * jnp.pi
    wave = jnp.sin(f[:, None, None] *
                   (jnp.cos(a)[:, None, None] * xx[None] +
                    jnp.sin(a)[:, None, None] * yy[None]) + phase[:, None, None])
    img = wave[..., None].repeat(cfg.in_channels, -1)
    img = img + cfg.noise * jax.random.normal(k3, img.shape)
    return {"image": img.astype(jnp.float32), "label": labels}
