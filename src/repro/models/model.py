"""Public model API: init / forward / loss / prefill / decode, uniform over
all 10 architectures, with SPARQ PTQ calibration built in.

A `Model` wraps a ModelConfig; params are plain pytrees so they pjit/shard/
checkpoint uniformly. The decoder stack is grouped into homogeneous runs
(transformer.stack_*); the encoder stack (whisper) is a second run list.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import CalibBank
from repro.models import transformer as tr
from repro.models.common import (ModelConfig, QuantCtx, chunked_lm_loss,
                                 cross_entropy_loss, embed_tokens, norm,
                                 norm_init, sinusoidal_embed)

LB_COEF = 0.01
Z_COEF = 0.001


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = tr.layer_kinds(cfg)
        self.groups_meta = tr._group_runs(self.kinds)

    # ------------------------------------------------------------ init
    def init_params(self, key, dtype=jnp.float32) -> Dict:
        cfg = self.cfg
        k_emb, k_blocks, k_enc, k_head = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": (jax.random.truncated_normal(
                k_emb, -2, 2, (cfg.vocab_size, cfg.d_model)) * 0.02
            ).astype(dtype),
            "blocks": tr.stack_init(k_blocks, cfg, self.kinds, dtype),
            "final_norm": norm_init(cfg.d_model, cfg.norm_type),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.truncated_normal(
                k_head, -2, 2, (cfg.d_model, cfg.vocab_size)) * 0.02
            ).astype(dtype)
        if cfg.is_encdec:
            params["enc_blocks"] = tr.stack_init(
                k_enc, cfg.replace(n_layers=cfg.n_enc_layers),
                ["enc"] * cfg.n_enc_layers, dtype)
            params["enc_norm"] = norm_init(cfg.d_model, cfg.norm_type)
        return params

    # ------------------------------------------------------------ pieces
    def _embed_in(self, params, batch, dtype):
        from repro.distributed.sharding import constrain
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        x = constrain(x * jnp.asarray(cfg.d_model ** 0.5, dtype))
        prefix_len = 0
        if cfg.family == "vlm" and "image_embeds" in batch:
            x = jnp.concatenate([batch["image_embeds"].astype(dtype), x], 1)
            prefix_len = batch["image_embeds"].shape[1]
        return x, prefix_len

    def _encode(self, params, frames, ctx):
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        x = x + sinusoidal_embed(x.shape[1], cfg.d_model).astype(cfg.dtype)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])
        x, _, _ = tr.stack_apply([("enc", cfg.n_enc_layers)],
                                 params["enc_blocks"], x, cfg,
                                 positions=positions, mode="train", ctx=ctx)
        return norm(params["enc_norm"], x, cfg.norm_type, cfg.norm_eps)

    def _head(self, params, x):
        w = params["embed"].T if self.cfg.tie_embeddings \
            else params["lm_head"]
        return jnp.matmul(x, w.astype(x.dtype))

    # ------------------------------------------------------------ train
    def forward(self, params, batch: Dict, ctx: Optional[QuantCtx] = None,
                scales_groups=None) -> jnp.ndarray:
        """Full-sequence hidden states (pre-head)."""
        cfg = self.cfg
        x, prefix_len = self._embed_in(params, batch, cfg.dtype)
        if cfg.is_encdec:
            enc_out = self._encode(params, batch["frames"], ctx)
        else:
            enc_out = None
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])
        x, _, aux = tr.stack_apply(
            self.groups_meta, params["blocks"], x, cfg, positions=positions, mode="train",
            ctx=ctx, scales_groups=scales_groups, prefix_len=prefix_len,
            enc_out=enc_out)
        x = norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        self._last_aux = aux
        return x, prefix_len

    def logits(self, params, batch, ctx=None) -> jnp.ndarray:
        x, prefix_len = self.forward(params, batch, ctx)
        if prefix_len:
            x = x[:, prefix_len:]
        return self._head(params, x)

    def loss(self, params, batch: Dict, ctx: Optional[QuantCtx] = None,
             scales_groups=None) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        x, prefix_len = self.forward(params, batch, ctx, scales_groups)
        if prefix_len:
            x = x[:, prefix_len:]
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        lm = chunked_lm_loss(head, x, batch["labels"],
                             cfg.logit_chunk or x.shape[1])
        aux = getattr(self, "_last_aux", {"lb_loss": 0.0, "z_loss": 0.0})
        total = lm + LB_COEF * aux["lb_loss"] + Z_COEF * aux["z_loss"]
        return total, {"lm_loss": lm, **aux}

    # ------------------------------------------------------------ serve
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   cache_cfg=None, mesh=None):
        """Decode-time cache stack. `cache_cfg` (models.cache.CacheConfig)
        selects the storage layout — fp (in `dtype`) or sparq (§5.1 packed
        int8 codes + meta, quantized on write / meta-decoded on read).
        `mesh` (a ("data","model") jax Mesh) makes decode reads of the
        sparq planes run tensor-parallel over the "model" axis."""
        return tr.stack_cache_init(self.cfg, self.kinds, batch, max_len,
                                   dtype, cache_cfg, mesh=mesh)

    def prefill(self, params, batch: Dict, caches,
                ctx: Optional[QuantCtx] = None, scales_groups=None):
        """Process the prompt; returns (last_token_logits, caches)."""
        cfg = self.cfg
        x, prefix_len = self._embed_in(params, batch, cfg.dtype)
        enc_out = self._encode(params, batch["frames"], ctx) \
            if cfg.is_encdec else None
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])
        x, caches, _ = tr.stack_apply(
            self.groups_meta, params["blocks"], x, cfg, positions=positions, caches=caches,
            mode="prefill", ctx=ctx, scales_groups=scales_groups,
            prefix_len=prefix_len, enc_out=enc_out)
        x = norm(params["final_norm"], x[:, -1:], cfg.norm_type, cfg.norm_eps)
        return self._head(params, x)[:, 0], caches

    def prefill_chunk(self, params, tokens, caches, chunk, last_rows,
                      ctx: Optional[QuantCtx] = None, scales_groups=None):
        """One chunk of the packed ragged-prefill token stream (paged
        caches, standard-KV stacks only). tokens [1, C] in stream order;
        `chunk` is a models.paging.ChunkMeta (per-token slot/position
        metadata, per-slot start positions, post-chunk seq_pos). Every
        layer quantizes the chunk's K/V straight into §5.1 pages and
        attends over chunk + already-written pages — one traced program
        covers every prompt length and join pattern, so admission never
        retraces (the PrefillScheduler jits exactly this function once).

        `last_rows` [S] int32 names the stream row holding each slot's
        final prompt token (-1 if the slot's prefill does not complete in
        this chunk). Returns (tok0 [S] int32 — the greedy token at each
        slot's last prompt row, garbage where last_rows < 0 — , caches)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg.dtype)
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
        positions = chunk.pos[None, :]
        x, caches, _ = tr.stack_apply(
            self.groups_meta, params["blocks"], x, cfg, positions=positions,
            caches=caches, mode="chunk_prefill", ctx=ctx,
            scales_groups=scales_groups, chunk=chunk)
        rows = x[0, jnp.maximum(last_rows, 0)]           # [S, d]
        h = norm(params["final_norm"], rows, cfg.norm_type, cfg.norm_eps)
        return jnp.argmax(self._head(params, h), -1).astype(jnp.int32), \
            caches

    def decode_step(self, params, tokens, caches, pos,
                    ctx: Optional[QuantCtx] = None, scales_groups=None):
        """One token for every sequence. tokens [B,1]; pos: absolute
        position of the new token — a scalar (uniform batch, the scan
        engine) or an int32 [B] vector (ragged continuous batching: every
        sequence decodes at its own position). Returns (logits [B,V],
        caches)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg.dtype)
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
        positions = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1),
                                     (x.shape[0], 1))
        x, caches, _ = tr.stack_apply(
            self.groups_meta, params["blocks"], x, cfg, positions=positions, caches=caches,
            mode="decode", ctx=ctx, scales_groups=scales_groups)
        x = norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        return self._head(params, x)[:, 0], caches

    # ------------------------------------------------------------ PTQ
    def quant_sites(self) -> List[str]:
        """All dense() site names reachable for this family."""
        fam_sites = {
            "dense": ["attn_q", "attn_k", "attn_v", "attn_out",
                      "ffn_gate", "ffn_up", "ffn_down"],
            "moe": ["attn_q", "attn_k", "attn_v", "attn_out"],
            "mla": ["mla_q", "mla_dkv", "mla_uk", "mla_uv", "mla_out",
                    "ffn_gate", "ffn_up", "ffn_down"],
            "rwkv": ["tm_r", "tm_k", "tm_v", "tm_g", "tm_out",
                     "cm_k", "cm_r", "cm_v"],
            "rg": ["rg_gate", "rg_in", "rg_rgate", "rg_igate", "rg_out",
                   "attn_q", "attn_k", "attn_v", "attn_out",
                   "ffn_gate", "ffn_up", "ffn_down"],
        }
        fam = self.cfg.family
        if fam in ("dense", "vlm", "encdec"):
            return fam_sites["dense"]
        if fam == "moe":
            return fam_sites["mla"] if self.cfg.kv_lora_rank \
                else fam_sites["moe"] + ["ffn_gate", "ffn_up", "ffn_down"]
        if fam == "rwkv6":
            return fam_sites["rwkv"]
        if fam == "rglru":
            return fam_sites["rg"]
        raise ValueError(fam)

    def calibrate(self, params, batches: Iterable[Dict],
                  signed: bool = True) -> list:
        """Eager per-layer calibration (paper §5: min-max over ~2K samples).
        Runs blocks layer-by-layer so each layer gets its own observer;
        returns `scales_groups` (list parallel to params['blocks'] of
        {site: (count,) f32}) for stack_apply / the quantized path."""
        cfg = self.cfg
        bank = CalibBank()
        for batch in batches:
            x, prefix_len = self._embed_in(params, batch, cfg.dtype)
            enc_out = self._encode(params, batch["frames"], QuantCtx.off()) \
                if cfg.is_encdec else None
            positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                         x.shape[:2])
            for gi, ((kind, count), stacked) in enumerate(
                        zip(self.groups_meta, params["blocks"])):
                for li in range(count):
                    p_l = jax.tree.map(lambda a: a[li], stacked)
                    ctx = QuantCtx(mode="calibrate", collect=bank,
                                   site_prefix=f"g{gi}.l{li}/")
                    x, _, _ = tr.block_apply(
                        p_l, x, cfg, kind, positions=positions, mode="train",
                        ctx=ctx, prefix_len=prefix_len, enc_out=enc_out)
        # assemble stacked per-group scale arrays
        groups = []
        for gi, (kind, count) in enumerate(self.groups_meta):
            sites = {}
            for name, obs in bank.observers.items():
                if not name.startswith(f"g{gi}."):
                    continue
                li = int(name.split(".l")[1].split("/")[0])
                site = name.split("/")[1]
                span = max(abs(obs.max_val), abs(obs.min_val)) if signed \
                    else obs.max_val
                sites.setdefault(site, [0.0] * count)[li] = float(span)
            groups.append({s: jnp.asarray(v, jnp.float32)
                           for s, v in sites.items()})
        return groups

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree.leaves(params))
