"""Model zoo: 10 assigned architectures + the paper's CNN family."""
from repro.models.common import ModelConfig, QuantCtx
from repro.models.model import Model

__all__ = ["ModelConfig", "QuantCtx", "Model"]
