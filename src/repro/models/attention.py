"""Attention: GQA/MQA/MHA with flash-style chunked softmax, sliding-window
(block-local) attention, KV caches (optionally SPARQ-quantized), decode.

Memory discipline (DESIGN.md §5): train/prefill never materialize the full
[Tq, Tk] score matrix — an outer scan over query chunks and inner scan over
KV chunks carries online-softmax statistics (m, l, acc). Sliding-window
attention uses the exact two-block trick (each query block attends to its
own and the previous key block only), so prefill cost is O(T·W) not O(T²).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.cache import CacheConfig, CacheStore
from repro.models.common import ModelConfig, QuantCtx, dense, rope

# The bare (k, v, pos) KVCache NamedTuple is replaced by the layout-aware
# CacheStore (models/cache.py): same (k, v, pos) shape, but each plane is a
# CachedTensor that may hold fp or SPARQ-packed int8 storage.
KVCache = CacheStore


def _split_heads(x, n_heads):
    B, T, _ = x.shape
    return x.reshape(B, T, n_heads, -1)


def _merge_heads(x):
    B, T, H, hd = x.shape
    return x.reshape(B, T, H * hd)


def qkv_proj(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
             positions: jnp.ndarray, ctx: Optional[QuantCtx] = None):
    from repro.distributed.sharding import constrain_heads
    q = _split_heads(dense(params["wq"], x, "attn_q", ctx), cfg.n_heads)
    k = _split_heads(dense(params["wk"], x, "attn_k", ctx), cfg.n_kv_heads)
    v = _split_heads(dense(params["wv"], x, "attn_v", ctx), cfg.n_kv_heads)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return constrain_heads(q), constrain_heads(k), constrain_heads(v)


def _mask(qpos, kpos, causal: bool, window: int, prefix_len: int):
    """[..., Tq, Tk] boolean allow-mask from position vectors."""
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    allow = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        allow &= kp <= qp
        if prefix_len:
            allow |= kp < prefix_len  # prefix-LM: bidirectional over prefix
    if window:
        allow &= qp - kp < window
    return allow


def flash_attention(q, k, v, *, causal=True, q_chunk=512, kv_chunk=1024,
                    window=0, prefix_len=0, q_offset=0, kv_offset=0):
    """Online-softmax attention. q [B,Tq,H,hd], k/v [B,Tk,KV,hd], GQA via
    head grouping (no materialized repeat). q_offset/kv_offset: absolute
    position of q[0]/k[0] (decode, prefill continuation, window blocks)."""
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    nq, nk = -(-Tq // q_chunk), -(-Tk // kv_chunk)
    # pad to chunk multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Tk), (0, 0), (0, 0)))
    qg = qp.reshape(B, nq, q_chunk, KV, G, hd)
    kg = kp.reshape(B, nk, kv_chunk, KV, hd)
    vg = vp.reshape(B, nk, kv_chunk, KV, hd)
    qpos_all = q_offset + jnp.arange(nq * q_chunk)
    kpos_all = kv_offset + jnp.arange(nk * kv_chunk)
    kvalid = (kpos_all >= 0) & (kpos_all < kv_offset + Tk)

    @jax.checkpoint  # flash backward: recompute scores, never store them
    def q_step(_, qi):
        qc = qg[:, qi]                     # [B, qc, KV, G, hd]
        qpos = jax.lax.dynamic_slice_in_dim(qpos_all, qi * q_chunk, q_chunk)

        def kv_step(carry, kj):
            m, l, acc = carry
            kc, vc = kg[:, kj], vg[:, kj]  # [B, kc, KV, hd]
            kpos = jax.lax.dynamic_slice_in_dim(
                kpos_all, kj * kv_chunk, kv_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            allow = _mask(qpos, kpos, causal, window, prefix_len)
            allow &= jax.lax.dynamic_slice_in_dim(
                kvalid, kj * kv_chunk, kv_chunk)[None, :]
            s = jnp.where(allow[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(allow[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # [B, KV, G, qc, hd] -> [B, qc, KV*G, hd]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Tq]


def local_attention(q, k, v, *, window: int, q_offset=0):
    """Exact sliding-window attention via the two-block trick: query block i
    attends to key blocks {i-1, i} only, each pair through the flash
    (online-softmax, checkpointed) path — O(T*W) compute, one flash tile of
    peak memory, and head sharding preserved (no 6-D score tensor for GSPMD
    to trip on)."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    W = window
    pad = (-T) % W
    nb = (T + pad) // W
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k2 = jnp.concatenate(  # [B, T+W(+pad), KV, hd]: one block of left ctx
        [jnp.zeros((B, W, KV, hd), k.dtype), kp], 1)
    v2 = jnp.concatenate([jnp.zeros((B, W, KV, hd), v.dtype), vp], 1)

    def blk(_, i):
        qb = jax.lax.dynamic_slice_in_dim(qp, i * W, W, axis=1)
        kb = jax.lax.dynamic_slice_in_dim(k2, i * W, 2 * W, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v2, i * W, 2 * W, axis=1)
        out = flash_attention(
            qb, kb, vb, causal=True, window=W,
            q_chunk=min(512, W), kv_chunk=min(1024, 2 * W),
            q_offset=i * W, kv_offset=(i - 1) * W)
        return None, out

    _, outs = jax.lax.scan(blk, None, jnp.arange(nb))  # [nb, B, W, H, hd]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * W, H, hd)
    return out[:, :T].astype(q.dtype)


def decode_attention(q, cache, *, window: int = 0):
    """Single-token decode against a cache. q [B,1,H,hd].

    paged store (models.paging.PagedCacheStore): the block-table gather
    variant of the fused kernel streams the sequence's pages straight from
    the global pool (per-sequence positions and scales).
    sparq layout: the raw packed planes (int8 window codes + meta bytes +
    per-site scale) go straight to the fused flash-decode kernel
    (kernels.ops.sparq_decode_attention) — the §5.1 meta-decode happens
    inside the Tk-tile loop and the fp K/V planes are never materialized.
    fp layout: the dequantize-then-attend fallback below."""
    from repro.models.paging import PagedCacheStore, paged_decode_attention
    if isinstance(cache, PagedCacheStore):
        return paged_decode_attention(q, cache, window=window)
    if cache.k.is_sparq:
        from repro.kernels.ops import sparq_decode_attention
        B, Tk = cache.k.data.shape[:2]
        kpos = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None],
                                (B, Tk))
        out = sparq_decode_attention(
            q, cache.k.data, cache.k.meta, cache.k.scale,
            cache.v.data, cache.v.meta, cache.v.scale,
            kpos, cache.pos - 1, window=window, impl=cache.k.impl,
            bk=cache.k.bk, mesh=cache.k.mesh)
        return out.astype(q.dtype)
    return decode_attention_dequant(q, cache, window=window)


def decode_attention_dequant(q, cache: CacheStore, *, window: int = 0):
    """Full-plane fallback: CachedTensor.read() then attend. For the sparq
    layout this dequantizes the whole [B,Tmax,KV,hd] cache each step — keep
    it off the decode hot path (it is the oracle the fused kernel is tested
    against, and the path for fp planes / cross-attention K/V)."""
    B, _, H, hd = q.shape
    k, v = cache.kv()
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    allow = kpos < cache.pos
    if window:
        allow &= kpos >= cache.pos - window
    s = jnp.where(allow[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cache_init(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16,
               cache_cfg: Optional[CacheConfig] = None,
               mesh=None) -> CacheStore:
    cc = cache_cfg or CacheConfig(layout="fp", dtype=dtype)
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return CacheStore.init(shape, cc, mesh=mesh)


def cache_update(cache: CacheStore, k_new, v_new) -> CacheStore:
    """Insert [B, T_new, KV, hd] at cache.pos (T_new static). Sparq-layout
    planes quantize on write (per-site scale frozen at first write)."""
    return cache.update(k_new, v_new)


def _cache_mesh(cache):
    """The tensor-parallel mesh a cache carries, if any (paged stores
    carry it directly, contiguous stores on their K plane)."""
    if cache is None:
        return None
    mesh = getattr(cache, "mesh", None)
    if mesh is None and hasattr(cache, "k"):
        mesh = getattr(cache.k, "mesh", None)
    return mesh


def attention_block(params: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
                    positions: jnp.ndarray,
                    cache: Optional[CacheStore] = None,
                    mode: str = "train",  # train | prefill | decode |
                                          # chunk_prefill
                    window: int = 0,
                    prefix_len: int = 0,
                    ctx: Optional[QuantCtx] = None,
                    chunk=None):
    """Full attention sub-block: qkv -> attend -> out proj.
    Returns (out, new_cache).

    mode "chunk_prefill" (paged caches only): x is one fixed-shape chunk
    of the packed ragged prompt stream (`chunk`: models.paging.ChunkMeta,
    positions = chunk.pos). The chunk's K/V quantize straight into the
    sequence's pages (PagedCacheStore.write_chunk — no staging cache) and
    attention runs segment-masked over the chunk plus each sequence's
    already-written pages (kernels.ops.sparq_chunked_prefill_attention).
    """
    q, k, v = qkv_proj(params, x, cfg, positions, ctx)
    new_cache = None
    if mode == "chunk_prefill":
        from repro.models.paging import chunked_prefill_attention
        assert cache is not None and chunk is not None
        new_cache = cache.write_chunk(k[0], v[0], chunk)
        out = chunked_prefill_attention(q, k[0], v[0], new_cache, chunk,
                                        window=window)
    elif mode == "decode":
        assert cache is not None
        new_cache = cache_update(cache, k, v)
        out = decode_attention(q, new_cache, window=window)
    else:
        if mode == "prefill":
            assert cache is not None
            new_cache = cache_update(cache, k, v)
        if window:
            out = local_attention(q, k, v, window=window)
        else:
            out = flash_attention(q, k, v, causal=True,
                                  q_chunk=cfg.attn_chunk,
                                  kv_chunk=cfg.attn_chunk,
                                  prefix_len=prefix_len)
    mesh = _cache_mesh(new_cache)
    if mesh is not None:
        # TP exit point: the attention output leaves the sharded read
        # head-sharded over the "model" axis. Gather it back to fully
        # replicated BEFORE the wo matmul — the collective is a pure
        # all-gather (concatenation, no arithmetic), so the contraction
        # over H*hd then runs with replicated operands in the same
        # summation order as TP=1 and tokens stay bit-identical.
        # Constraining after the matmul instead would let GSPMD sum tp
        # partial products, reassociating the reduction.
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
    out = dense(params["wo"], _merge_heads(out), "attn_out", ctx)
    return out, new_cache


def attention_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    from repro.models.common import init_dense
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": init_dense(ks[0], d, cfg.n_heads * hd, dtype=dtype),
        "wk": init_dense(ks[1], d, cfg.n_kv_heads * hd, dtype=dtype),
        "wv": init_dense(ks[2], d, cfg.n_kv_heads * hd, dtype=dtype),
        "wo": init_dense(ks[3], cfg.n_heads * hd, d,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
    }
