"""Mixture-of-Experts with capacity-based sort dispatch (EP-shardable).

Top-k token-choice routing. Dispatch avoids the O(T·E·C) one-hot tensor:
assignments are sorted by expert, a small (E, C) slot table is scattered
with token indices, and tokens are *gathered* into the (E, C, D) buffer —
the standard capacity-based schedule (tokens over capacity drop to the
residual path). Experts run as one batched (E, C, D)x(E, D, F) matmul so
the 'model' mesh axis shards E (expert parallelism); GSPMD inserts the
all-to-all at the token->expert resharding boundary.

Aux losses: Switch-style load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx, init_dense


def _expert_ffn(wg, wu, wd, x, mlp_type):
    """x: [E, C, D]; weights [E, D, F]/[E, F, D]."""
    up = jnp.einsum("ecd,edf->ecf", x, wu.astype(x.dtype))
    if mlp_type in ("swiglu", "geglu"):
        gate = jnp.einsum("ecd,edf->ecf", x, wg.astype(x.dtype))
        act = jax.nn.silu(gate) if mlp_type == "swiglu" else \
            jax.nn.gelu(gate, approximate=True)
        h = act * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(x.dtype))


def moe_apply(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
              ctx: Optional[QuantCtx] = None, exact_capacity: bool = False):
    """x: [B, T, D]. Returns (y, aux) with aux = {lb_loss, z_loss}.
    exact_capacity=True (decode): capacity covers the worst case so no
    token is ever dropped (serving correctness)."""
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    N = B * T
    C = N if exact_capacity else \
        min(N, max(int(N * K / E * cfg.capacity_factor), 1))
    xt = x.reshape(N, D)

    logits = jnp.matmul(xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)             # [N, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # ---- capacity-based slotting ----
    flat_expert = expert_ids.reshape(-1)                        # [N*K]
    flat_token = jnp.repeat(jnp.arange(N), K)
    flat_gate = gate_vals.reshape(-1)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)    # [N*K, E]
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    slot = jnp.sum(pos_in_expert, axis=-1)                      # [N*K]
    keep = slot < C
    slot_c = jnp.where(keep, slot, C - 1)
    # slot table: token index feeding each (expert, slot); -1 = empty
    table = jnp.full((E, C), -1, jnp.int32)
    table = table.at[flat_expert, slot_c].set(
        jnp.where(keep, flat_token, -1), mode="drop")
    gates = jnp.zeros((E, C), jnp.float32)
    gates = gates.at[flat_expert, slot_c].set(
        jnp.where(keep, flat_gate, 0.0), mode="drop")

    # gather tokens -> [E, C, D]; empty slots read token 0, masked by gate 0
    buf = jnp.take(xt, jnp.maximum(table, 0), axis=0)
    buf = buf * (table >= 0)[..., None].astype(buf.dtype)

    out_buf = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                          buf, cfg.mlp_type)                    # [E, C, D]

    # combine: scatter-add expert outputs back to tokens
    y = jnp.zeros((N, D), out_buf.dtype)
    y = y.at[jnp.maximum(table, 0).reshape(-1)].add(
        (out_buf * gates[..., None].astype(out_buf.dtype)).reshape(-1, D),
        mode="drop")

    # shared experts (deepseek): always-on, fused into one [1,D,F*S] expert
    if cfg.n_shared_experts:
        sh = _expert_ffn(params["sh_gate"], params["sh_up"], params["sh_down"],
                         xt[None], cfg.mlp_type)
        y = y + sh[0]

    # ---- aux losses ----
    density = jnp.mean(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32),
                       axis=(0, 1))                 # fraction routed per e
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(density * mean_prob)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)
    return y.reshape(B, T, D).astype(x.dtype), {
        "lb_loss": lb_loss, "z_loss": z_loss}


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 7)
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    gated = cfg.mlp_type in ("swiglu", "geglu")
    out_scale = 1.0 / (2 * cfg.n_layers) ** 0.5

    def experts(k, d_in, d_out, scale=1.0):
        std = scale / jnp.sqrt(d_in)
        return (jax.random.truncated_normal(k, -2, 2, (E, d_in, d_out)) *
                std).astype(dtype)

    p = {"router": init_dense(ks[0], D, E, dtype=jnp.float32),
         "w_up": experts(ks[1], D, F),
         "w_gate": experts(ks[2], D, F) if gated else
         jnp.zeros((E, 1, 1), dtype),
         "w_down": experts(ks[3], F, D, out_scale)}
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p["sh_up"] = init_dense(ks[4], D, Fs, dtype=dtype)[None]
        p["sh_gate"] = init_dense(ks[5], D, Fs, dtype=dtype)[None]
        p["sh_down"] = init_dense(ks[6], Fs, D, scale=out_scale,
                                  dtype=dtype)[None]
    return p
