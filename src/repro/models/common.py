"""Shared model machinery: config, quantization context, primitive layers.

Pure functional JAX (no flax): params are nested dicts of arrays; every
matmul in the network routes through `dense()`, which is where SPARQ plugs
in (off for bf16 training, calibrate to collect per-site activation stats,
quantized for the PTQ serving path).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.calibration import CalibBank
from repro.core.quantizer import QScale, quantize, weight_scale
from repro.core.sparq import SparqConfig
from repro.kernels.ops import quantized_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config per assigned architecture (src/repro/configs/)."""
    name: str
    family: str                  # dense | moe | rwkv6 | rglru | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    mlp_type: str = "swiglu"     # swiglu | gelu | geglu
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    rope_theta: float = 10000.0
    use_rope: bool = True
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # deepseek: first k layers use dense FFN
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- RWKV6 ---
    head_size: int = 64
    decay_lora: int = 64
    # --- RG-LRU hybrid (recurrentgemma) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 2048
    conv_width: int = 4
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    # --- modality frontend stubs (assignment: precomputed embeddings) ---
    frontend: str = "none"       # none | vision | audio
    frontend_len: int = 0
    # --- numerics / execution ---
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    logit_chunk: int = 0         # 0 = unchunked loss
    attn_chunk: int = 1024       # flash-style KV chunk in train/prefill
    mixer_impl: str = "chunked"  # rwkv/rglru sequence mixer: scan | chunked
    mixer_chunk: int = 16        # keeps chunked-WKV decay factors in f32
    train_microbatches: int = 1  # gradient accumulation (activation memory)
    param_dtype: Any = jnp.float32   # bf16 for >100B (f32 opt states)
    tensor_parallel: bool = True     # False: pure ZeRO-DP over all axes
                                     # (right choice for <~5B models)
    remat: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class QuantCtx:
    """How matmuls execute. `scales[site]` is a scalar per quantization site
    (or a (L,) stacked array outside scan bodies; the scan slices it)."""
    mode: str = "off"                     # off | calibrate | quantized
    cfg: Optional[SparqConfig] = None
    scales: Optional[Dict[str, Any]] = None
    collect: Optional[CalibBank] = None
    impl: str = "reference"               # reference | pallas
    skip_sites: tuple[str, ...] = ()      # paper: first layer left intact
    site_prefix: str = ""                 # per-layer prefix (calibration)
    stc: bool = False                     # Sparse-TC path (2:4-pruned w)

    @staticmethod
    def off() -> "QuantCtx":
        return QuantCtx(mode="off")


def dense(w, x: jnp.ndarray, site: str,
          ctx: Optional[QuantCtx] = None) -> jnp.ndarray:
    """x [..., d_in] @ w [d_in, d_out] through the quantization hook.
    `w` is either a float array or a pre-quantized {"q": int8, "s": f32}
    leaf (models.quantize.quantize_params, the serving deployment)."""
    from repro.models.quantize import as_weight, is_qweight
    if ctx is None or ctx.mode == "off" or site in (ctx.skip_sites or ()):
        return jnp.matmul(x, as_weight(w, x.dtype))
    if ctx.mode == "calibrate":
        if ctx.collect is not None:
            ctx.collect.observe(ctx.site_prefix + site, x)
        return jnp.matmul(x, as_weight(w, x.dtype))
    if ctx.mode == "quantized":
        cfg = ctx.cfg or SparqConfig.a8w8()
        scale = None
        if ctx.scales:
            key = ctx.site_prefix + site
            scale = ctx.scales.get(key, ctx.scales.get(site))
        if scale is None:
            scale = jnp.max(jnp.abs(x))  # dynamic per-tensor fallback
        qmax = cfg.max_val
        act_qs = QScale(scale=jnp.asarray(scale, jnp.float32) / qmax,
                        bits=cfg.act_bits, signed=cfg.signed)
        if ctx.stc:
            from repro.core.sparq import sparq_dot_stc
            return sparq_dot_stc(x, as_weight(w, jnp.float32),
                                 act_qs, cfg).astype(x.dtype)
        if is_qweight(w):
            w_codes, chan_scale = w["q"], w["s"]
        else:
            w_qs = weight_scale(w, cfg.weight_bits)
            w_codes = quantize(w, w_qs).astype(jnp.int8)
            chan_scale = w_qs.scale
        out = quantized_matmul(x, w_codes, act_qs, chan_scale, cfg,
                               impl=ctx.impl)
        return out.astype(x.dtype)
    raise ValueError(ctx.mode)


# ----------------------------------------------------------------------
# primitive layers
# ----------------------------------------------------------------------

def norm(params: Dict, x: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)
    mean = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (xf * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_init(d: int, kind: str) -> Dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         dims: Optional[int] = None) -> jnp.ndarray:
    """Rotary embedding over the last `dims` features. x: [B, T, H, hd]."""
    hd = dims or x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, T, half]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:hd]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    if hd < x.shape[-1]:
        rot = jnp.concatenate([rot, x[..., hd:]], -1)
    return rot.astype(x.dtype)


def sinusoidal_embed(T: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def init_dense(key, d_in: int, d_out: int, scale: float = 1.0,
               dtype=jnp.float32) -> jnp.ndarray:
    std = scale / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out)) *
            std).astype(dtype)


def embed_tokens(emb: jnp.ndarray, tokens: jnp.ndarray,
                 dtype) -> jnp.ndarray:
    return jnp.take(emb, tokens, axis=0).astype(dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       ignore: int = -1) -> jnp.ndarray:
    """Mean CE over non-ignored positions. logits [..., V], labels [...]."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_lm_loss(emb_out: jnp.ndarray, x: jnp.ndarray,
                    labels: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """CE loss without materializing [T, vocab] logits: scan over sequence
    chunks, projecting to the vocab one chunk at a time (DESIGN.md §5)."""
    from repro.distributed.sharding import constrain
    x = constrain(x)
    B, T, D = x.shape
    if chunk <= 0 or T % chunk != 0 or T == chunk:
        logits = jnp.matmul(x, emb_out.astype(x.dtype))
        return cross_entropy_loss(logits, labels)
    n = T // chunk
    xc = x.reshape(B, n, chunk, D).swapaxes(0, 1)        # [n, B, c, D]
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        xs, ls = inp
        logits = jnp.matmul(xs, emb_out.astype(xs.dtype)).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        mask = (ls != -1).astype(jnp.float32)
        s, c = carry
        return (s + jnp.sum((lse - gold) * mask), c + jnp.sum(mask)), None

    (s, c), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc))
    return s / jnp.maximum(c, 1.0)
