"""RWKV6 "Finch": attention-free time-mix with data-dependent decay, plus
squared-ReLU channel-mix (whose genuinely sparse unsigned activations are
the best match in the zoo for the paper's vSPARQ assumptions — DESIGN.md §4).

Two sequence-mixer implementations, selected by cfg.mixer_impl:
  scan     — O(T) lax.scan oracle (exact recurrence, used by tests/decode);
  chunked  — FLA-style chunked parallel form: intra-chunk work becomes
             matmuls (MXU-aligned), inter-chunk state flows through a short
             scan. Decays are factorized around the chunk start; per-step
             log-decay is clamped to >= -5 so the largest factor within a
             16..64-step chunk stays inside f32 range.

Simplification vs the full Finch recipe (documented in DESIGN.md): token
shift uses static per-channel interpolation (mu) for r/k/v/g; the decay w
keeps its *data-dependent* LoRA (w0 + tanh(x A) B), which is the paper-pool
note ("data-dependent decay").
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx, dense, init_dense

LOG_DECAY_FLOOR = -5.0


class RWKVCache(NamedTuple):
    state: jnp.ndarray      # [B, H, hs, hs] wkv state
    tm_last: jnp.ndarray    # [B, D] last input of time-mix (token shift)
    cm_last: jnp.ndarray    # [B, D] last input of channel-mix


def _token_shift(x, mu, last=None):
    """lerp(x, prev_token(x), mu). x [B,T,D]; last [B,D] or None."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None], x[:, :-1]], 1)
    return x + (prev - x) * mu.astype(x.dtype)


def _heads(x, hs):
    B, T, D = x.shape
    return x.reshape(B, T, D // hs, hs)


def _log_decay(params, xw, ctx):
    """Data-dependent decay: logw = w0 + tanh(xw A) B; per-step log decay
    = -exp(logw), clamped for the chunked form's f32 safety."""
    lora = jnp.matmul(jnp.tanh(jnp.matmul(xw, params["w_A"].astype(xw.dtype))),
                      params["w_B"].astype(xw.dtype))
    logw = params["w0"].astype(xw.dtype) + lora
    return jnp.clip(-jnp.exp(logw.astype(jnp.float32)), LOG_DECAY_FLOOR, -1e-4)


def _wkv_scan(r, k, v, logw, u, state0):
    """Exact recurrence. r/k/v [B,T,H,hs], logw [B,T,H,hs] (log decay per
    key channel), u [H,hs]. Returns (y [B,T,H,hs], state [B,H,hs,hs])."""
    w = jnp.exp(logw)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,hs]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S) + \
            jnp.einsum("bhi,bhi,bhj->bhj", r_t, u[None] * k_t, v_t)
        S = w_t[..., None] * S + k_t[..., None] * v_t[..., None, :]
        return S, y

    seq = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
           w.swapaxes(0, 1).astype(r.dtype))
    state, ys = jax.lax.scan(step, state0.astype(r.dtype), seq)
    return ys.swapaxes(0, 1), state


def _wkv_chunked(r, k, v, logw, u, state0, chunk):
    """Chunked parallel form (see module docstring)."""
    B, T, H, hs = r.shape
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        # zero k/v inject nothing; zero log-decay passes state through, so
        # trailing pad steps leave real outputs and the final state exact.
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v, logw = (jnp.pad(a, z) for a in (r, k, v, logw))
        T += pad
    n = T // C
    f32 = jnp.float32
    rc = r.reshape(B, n, C, H, hs).astype(f32)
    kc = k.reshape(B, n, C, H, hs).astype(f32)
    vc = v.reshape(B, n, C, H, hs).astype(f32)
    lw = logw.reshape(B, n, C, H, hs).astype(f32)
    cum = jnp.cumsum(lw, axis=2)                 # inclusive cumsum in-chunk
    cum_prev = cum - lw                          # cumsum up to t-1
    r_t = rc * jnp.exp(cum_prev)                 # r~_t = r_t * exp(cum[t-1])
    k_t = kc * jnp.exp(-cum)                     # k~_s = k_s * exp(-cum[s])
    k_end = kc * jnp.exp(cum[:, :, -1:] - cum)   # decay from s to chunk end
    a_end = jnp.exp(cum[:, :, -1])               # [B,n,H,hs] total decay

    # intra-chunk: scores[t,s] = (r~_t . k~_s) for s<t; + u-bonus diagonal
    scores = jnp.einsum("bnthi,bnshi->bnhts", r_t, k_t)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    y_intra = jnp.einsum("bnhts,bnshj->bnthj", scores, vc)
    bonus = jnp.einsum("bnthi,hi,bnthi->bnth", rc, u.astype(f32), kc)
    y_intra += bonus[..., None] * vc

    # per-chunk state outer products to inject at chunk boundaries
    inject = jnp.einsum("bnshi,bnshj->bnhij", k_end, vc)  # [B,n,H,hs,hs]

    def boundary(S, inp):
        a_e, inj = inp                            # [B,H,hs], [B,H,hs,hs]
        S_next = a_e[..., None] * S + inj
        return S_next, S                          # emit state *entering* chunk

    (state, S_in) = jax.lax.scan(
        boundary, state0.astype(f32),
        (a_end.swapaxes(0, 1), inject.swapaxes(0, 1)))
    S_in = S_in.swapaxes(0, 1)                    # [B,n,H,hs,hs]
    y_state = jnp.einsum("bnthi,bnhij->bnthj", r_t, S_in)
    y = (y_intra + y_state).reshape(B, T, H, hs)
    if pad:
        y = y[:, :T - pad]
    return y.astype(r.dtype), state.astype(r.dtype)


def time_mix(params: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
             cache: Optional[RWKVCache] = None, mode: str = "train",
             ctx: Optional[QuantCtx] = None):
    B, T, D = x.shape
    hs = cfg.head_size
    H = D // hs
    last = cache.tm_last if cache is not None else None
    xr = _token_shift(x, params["mu_r"], last)
    xk = _token_shift(x, params["mu_k"], last)
    xv = _token_shift(x, params["mu_v"], last)
    xw = _token_shift(x, params["mu_w"], last)
    xg = _token_shift(x, params["mu_g"], last)
    from repro.distributed.sharding import constrain_heads
    r = constrain_heads(_heads(dense(params["w_r"], xr, "tm_r", ctx), hs))
    k = constrain_heads(_heads(dense(params["w_k"], xk, "tm_k", ctx), hs))
    v = constrain_heads(_heads(dense(params["w_v"], xv, "tm_v", ctx), hs))
    g = jax.nn.silu(dense(params["w_g"], xg, "tm_g", ctx))
    logw = constrain_heads(_heads(_log_decay(params, xw, ctx), hs))
    u = params["u"].reshape(H, hs)
    state0 = cache.state if cache is not None else \
        jnp.zeros((B, H, hs, hs), x.dtype)
    if mode == "decode" or cfg.mixer_impl == "scan":
        y, state = _wkv_scan(r, k, v, logw, u, state0)
    else:
        y, state = _wkv_chunked(r, k, v, logw, u, state0, cfg.mixer_chunk)
    # per-head group norm
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, -1, keepdims=True)
    var = jnp.var(yf, -1, keepdims=True)
    yf = (yf - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (yf.reshape(B, T, D) * params["ln_x_scale"] +
         params["ln_x_bias"]).astype(x.dtype)
    out = dense(params["w_o"], y * g, "tm_out", ctx)
    if cache is not None or mode != "train":
        # keep the carried state's dtypes (a lax.scan decode loop needs a
        # fixed-point carry; compute may run in a different dtype)
        new_cache = RWKVCache(
            state=state.astype(cache.state.dtype) if cache is not None
            else state,
            tm_last=x[:, -1].astype(cache.tm_last.dtype)
            if cache is not None else x[:, -1],
            cm_last=cache.cm_last if cache is not None
            else jnp.zeros((B, D), x.dtype))
    else:
        new_cache = None
    return out, new_cache


def channel_mix(params: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
                last: Optional[jnp.ndarray] = None,
                ctx: Optional[QuantCtx] = None):
    xk = _token_shift(x, params["mu_ck"], last)
    xr = _token_shift(x, params["mu_cr"], last)
    k = jnp.square(jax.nn.relu(dense(params["w_ck"], xk, "cm_k", ctx)))
    r = jax.nn.sigmoid(dense(params["w_cr"], xr, "cm_r", ctx))
    # k is post-relu^2: genuinely sparse unsigned input to cm_v (paper mode)
    return r * dense(params["w_cv"], k, "cm_v", ctx)


def rwkv_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 10)
    D, F = cfg.d_model, cfg.d_ff
    la = cfg.decay_lora
    mus = {f"mu_{n}": jnp.full((D,), 0.5, jnp.float32)
           for n in ("r", "k", "v", "w", "g")}
    mus.update({"mu_ck": jnp.full((D,), 0.5, jnp.float32),
                "mu_cr": jnp.full((D,), 0.5, jnp.float32)})
    return {
        **mus,
        "w_r": init_dense(ks[0], D, D, dtype=dtype),
        "w_k": init_dense(ks[1], D, D, dtype=dtype),
        "w_v": init_dense(ks[2], D, D, dtype=dtype),
        "w_g": init_dense(ks[3], D, D, dtype=dtype),
        "w_o": init_dense(ks[4], D, D,
                          scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
        "w_A": init_dense(ks[5], D, la, dtype=dtype),
        "w_B": (jax.random.truncated_normal(ks[6], -2, 2, (la, D)) *
                0.01).astype(dtype),
        "w0": jnp.full((D,), -1.0, jnp.float32),  # exp(-exp(-1)) ~ 0.69 decay
        "u": jnp.zeros((D,), jnp.float32),
        "ln_x_scale": jnp.ones((D,), jnp.float32),
        "ln_x_bias": jnp.zeros((D,), jnp.float32),
        "w_ck": init_dense(ks[7], D, F, dtype=dtype),
        "w_cr": init_dense(ks[8], D, D, dtype=dtype),
        "w_cv": init_dense(ks[9], F, D,
                           scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
    }
