"""RG-LRU recurrent block (RecurrentGemma / Griffin): causal depthwise conv
+ gated linear recurrence, parallelized with jax.lax.associative_scan
(the recurrence is elementwise-linear, so the Blelloch scan is exact),
plus the 1:2 local-attention:recurrent hybrid pattern assembled in
transformer.py.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
a_t = exp(-c * softplus(L) * sigmoid(W_a x_t)),  c = 8.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx, dense, init_dense

_C = 8.0


class RGLRUCache(NamedTuple):
    h: jnp.ndarray          # [B, W] recurrence state
    conv: jnp.ndarray       # [B, conv_width-1, W] trailing conv inputs


def _causal_conv(x, kernel, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv via shifted adds. x [B,T,C], kernel [W,C].
    cache: [B, W-1, C] trailing context from previous call (decode)."""
    W = kernel.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)      # [B, T+W-1, C]
    T = x.shape[1]
    out = jnp.zeros_like(x)
    for w in range(W):
        out = out + xp[:, w:w + T] * kernel[w].astype(x.dtype)
    new_cache = xp[:, -(W - 1):] if W > 1 else pad
    return out, new_cache


def _lru_scan(a, bx, h0, impl: str):
    """h_t = a_t h_{t-1} + bx_t, elementwise over [B,T,C]."""
    if impl == "scan":
        def step(h, inp):
            a_t, b_t = inp
            h = a_t * h + b_t
            return h, h
        h_last, hs = jax.lax.scan(
            step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
        return hs.swapaxes(0, 1), h_last
    # associative scan: compose (a2*a1, a2*b1 + b2); fold h0 into first b
    b0 = bx.at[:, 0].add(a[:, 0] * h0) if h0 is not None else bx

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    aa, bb = jax.lax.associative_scan(combine, (a, b0), axis=1)
    return bb, bb[:, -1]


def rglru_block(params: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
                cache: Optional[RGLRUCache] = None,
                mode: str = "train",
                ctx: Optional[QuantCtx] = None):
    """Full recurrent sub-block: in-proj (x & gate branches) -> conv ->
    RG-LRU -> gated out-proj. Returns (out, new_cache)."""
    from repro.distributed.sharding import constrain_last
    B, T, D = x.shape
    gate = jax.nn.gelu(dense(params["w_y"], x, "rg_gate", ctx),
                       approximate=True)
    xb = constrain_last(dense(params["w_x"], x, "rg_in", ctx))
    xb, conv_cache = _causal_conv(
        xb, params["conv_k"], cache.conv if cache is not None else None)
    r = jax.nn.sigmoid(constrain_last(
        dense(params["w_a"], xb, "rg_rgate", ctx)).astype(jnp.float32))
    i = jax.nn.sigmoid(constrain_last(
        dense(params["w_i"], xb, "rg_igate", ctx)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = i * xb.astype(jnp.float32)
    bx = constrain_last(
        jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x)
    h0 = cache.h.astype(jnp.float32) if cache is not None else \
        jnp.zeros((B, xb.shape[-1]), jnp.float32)
    impl = "scan" if (mode == "decode" or cfg.mixer_impl == "scan") \
        else "assoc"
    hs, h_last = _lru_scan(a, bx, h0, impl)
    hs = hs.astype(x.dtype)
    out = dense(params["w_out"], hs * gate, "rg_out", ctx)
    if cache is not None:
        # match the carried cache dtypes (fixed-point scan carry)
        new_cache = RGLRUCache(h=h_last.astype(cache.h.dtype),
                               conv=conv_cache.astype(cache.conv.dtype))
    elif mode != "train":
        new_cache = RGLRUCache(h=h_last.astype(x.dtype), conv=conv_cache)
    else:
        new_cache = None
    return out, new_cache


def rglru_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    D, W = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "w_y": init_dense(ks[0], D, W, dtype=dtype),
        "w_x": init_dense(ks[1], D, W, dtype=dtype),
        "w_a": init_dense(ks[2], W, W, scale=0.1, dtype=dtype),
        "w_i": init_dense(ks[3], W, W, scale=0.1, dtype=dtype),
        "w_out": init_dense(ks[4], W, D,
                            scale=1.0 / (2 * cfg.n_layers) ** 0.5,
                            dtype=dtype),
        "conv_k": (jax.random.truncated_normal(
            ks[5], -2, 2, (cfg.conv_width, W)) * 0.1).astype(dtype),
        # Lambda init so a ~ U(0.9, 0.999)^c-ish (Griffin appendix)
        "lam": jnp.full((W,), 0.65, jnp.float32),
    }


def rglru_cache_init(cfg: ModelConfig, batch: int,
                     dtype=jnp.bfloat16) -> RGLRUCache:
    W = cfg.lru_width or cfg.d_model
    return RGLRUCache(
        h=jnp.zeros((batch, W), dtype),
        conv=jnp.zeros((batch, cfg.conv_width - 1, W), dtype))
