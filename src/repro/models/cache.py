"""SPARQ-quantized KV-cache subsystem: one cache API for every layout.

`CacheConfig` picks the storage layout for all decode-time state:

  fp     — today's behavior: float planes in `dtype` (fp32 / bf16);
  sparq  — the paper's §5.1 packed format: each cached tensor is stored as
           int8 *window codes* (the n-bit data nibble in sign-magnitude,
           or the full 8-bit magnitude for vSPARQ mux'd lanes) plus one
           packed meta byte per lane pair [mux(1)|shift_hi(3)|shift_lo(3)],
           produced by `kernels.sparq_quantize` and decoded on read by
           `kernels.sparq_dequantize` (reference or Pallas impl), then
           rescaled by a per-site scale.

Scales are *per site*: every cache plane (each layer's K, V, MLA latent,
ring buffer, ...) carries its own f32 scale, calibrated from the first
write (the prefill pass — decode writes reuse the frozen scale so the
decode loop stays a fixed-point program under `lax.scan`).

`CachedTensor` is the single storage plane; `CacheStore` replaces the old
bare `KVCache` NamedTuple (k, v, pos). Both are jit/scan-transparent
pytrees: layout/codec/impl are static metadata, arrays are leaves, so the
existing stacked-layer `lax.scan` machinery in `transformer.stack_apply`
carries them unchanged.

Decode read path: the sparq layout is consumed *without* dequantizing the
full plane. `attention.decode_attention`, `transformer.ring_decode_attention`
and the MLA decode hand the raw (data, meta, scale) planes to
`kernels.ops.sparq_decode_attention` (or a tiled equivalent), which performs
the §5.1 meta-decode tile-by-tile inside the fused attention loop — the
bytes the decode step streams from HBM are the packed ones.
`CachedTensor.read()` still materializes the dequantized plane, but only as
the prefill/debug fallback (cross-attention K/V, tests, inspection).

Footprint accounting splits the §5.1 format into two planes:
  data plane — n data bits per value + 1 MuxCtrl bit per vSPARQ pair
               (`bytes_per_value`, the headline cache-residency figure:
               0.5625 B/value for 4-bit 5opt);
  ctrl plane — the 3-bit ShiftCtrl per value (`ctrl_bytes_per_value`,
               0.375 B/value), reported separately because on hardware it
               streams with the (much smaller) metadata side-band.
Both figures delegate to `kernels.ops` (`data_bytes_per_value` /
`ctrl_bytes_per_value`), whose sum is the roofline's combined
`kernels.ops.bytes_per_value` — one source of truth, enforced by test.

This module is the *contiguous* cache (one [B, Tmax, ...] plane per
site); `models/paging.py` stores the same packed format in a shared pool
of fixed-size pages for continuous batching. Byte-level format reference:
docs/packed_format.md (doctested against kernels.ops).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import QScale
from repro.core.sparq import SparqConfig


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Decode-time cache storage policy (static; hashable jit argument).

    Attributes:
      layout:  "fp" (float planes in `dtype`) or "sparq" (§5.1 packed int8).
      dtype:   storage dtype for the fp layout (ignored for sparq).
      sparq:   codec for the sparq layout; None -> plain int8 (no trimming).
      impl:    kernel impl for the codec + fused decode attention
               ("reference" | "pallas" | "auto" = pallas on TPU).
      attn_bk: Tk-tile size for the fused decode kernel (None -> default
               128, clamped to the cache length). The tile split fixes the
               f32 online-softmax summation order — set it to the paged
               engine's page_size to compare contiguous vs paged decodes
               bit for bit.
    """
    layout: str = "fp"                     # fp | sparq
    dtype: Any = jnp.bfloat16              # storage dtype for fp layout
    sparq: Optional[SparqConfig] = None    # codec for sparq layout
    impl: str = "auto"                     # reference | pallas | auto
    attn_bk: Optional[int] = None          # fused decode Tk-tile size

    def __post_init__(self):
        if self.layout not in ("fp", "sparq"):
            raise ValueError(f"unknown cache layout {self.layout!r}")
        if self.layout == "sparq" and self.sparq is None:
            # plain int8 storage (SPARQ trimming disabled) by default
            object.__setattr__(
                self, "sparq", SparqConfig(enabled=False, signed=True))

    @staticmethod
    def fp32() -> "CacheConfig":
        return CacheConfig(layout="fp", dtype=jnp.float32)

    @staticmethod
    def bf16() -> "CacheConfig":
        return CacheConfig(layout="fp", dtype=jnp.bfloat16)

    @staticmethod
    def sparq_cache(cfg: Optional[SparqConfig] = None,
                    impl: str = "auto") -> "CacheConfig":
        cfg = cfg or SparqConfig.opt5(signed=True)
        if not cfg.signed:
            cfg = dataclasses.replace(cfg, signed=True)  # K/V are signed
        return CacheConfig(layout="sparq", sparq=cfg, impl=impl)


def bytes_per_value(cc: CacheConfig) -> float:
    """Modeled HBM residency of the cache *data plane*, bytes per value.
    Delegates to kernels.ops so cache reports and the roofline agree."""
    if cc.layout == "fp":
        return float(jnp.dtype(cc.dtype).itemsize)
    from repro.kernels.ops import data_bytes_per_value
    return data_bytes_per_value(cc.sparq)


def ctrl_bytes_per_value(cc: CacheConfig) -> float:
    """Modeled ShiftCtrl side-band residency, bytes per value."""
    if cc.layout == "fp":
        return 0.0
    from repro.kernels.ops import ctrl_bytes_per_value as _ops_ctrl
    return _ops_ctrl(cc.sparq)


# ----------------------------------------------------------------------
# CachedTensor: one storage plane
# ----------------------------------------------------------------------

@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("data", "meta", "scale"),
                   meta_fields=("layout", "codec", "impl", "bk", "mesh"))
@dataclasses.dataclass
class CachedTensor:
    """One cache plane with time axis 1: [B, Tmax, ...rest].

    fp layout:    data float [B, Tmax, ...]; meta None; scale unused (1.0).
    sparq layout: data int8 window codes; meta int8 packed ShiftCtrl/MuxCtrl
                  byte per lane; scale f32 scalar (0.0 = uncalibrated
                  sentinel, set from the first write's dynamic range).
    `bk` (static, from CacheConfig.attn_bk) is the fused decode kernel's
    Tk-tile size; None keeps the kernel default.
    """
    data: jnp.ndarray
    meta: Optional[jnp.ndarray]
    scale: jnp.ndarray
    layout: str = "fp"
    codec: Optional[SparqConfig] = None
    impl: str = "auto"
    bk: Optional[int] = None
    #: optional ("data","model") jax Mesh — decode reads of this plane run
    #: tensor-parallel over the "model" axis (see kernels.ops.tp_size).
    mesh: Optional[jax.sharding.Mesh] = None

    # -------------------------------------------------------------- init
    @staticmethod
    def init(shape, cc: CacheConfig,
             mesh: Optional[jax.sharding.Mesh] = None) -> "CachedTensor":
        if cc.layout == "fp":
            return CachedTensor(data=jnp.zeros(shape, cc.dtype), meta=None,
                                scale=jnp.ones((), jnp.float32))
        assert shape[-1] % 2 == 0, \
            f"sparq cache pairs adjacent lanes; last dim must be even: {shape}"
        return CachedTensor(data=jnp.zeros(shape, jnp.int8),
                            meta=jnp.zeros(shape, jnp.int8),
                            scale=jnp.zeros((), jnp.float32),
                            layout="sparq", codec=cc.sparq, impl=cc.impl,
                            bk=cc.attn_bk, mesh=mesh)

    @staticmethod
    def fp(data: jnp.ndarray) -> "CachedTensor":
        """Wrap an existing float array as an fp plane (cross-attn K/V)."""
        return CachedTensor(data=data, meta=None,
                            scale=jnp.ones((), jnp.float32))

    # ------------------------------------------------------------- write
    def _resolve_scale(self, x: jnp.ndarray) -> jnp.ndarray:
        """Per-site scale: frozen once calibrated (scale > 0), else set
        from this write's dynamic range (the prefill pass)."""
        dyn = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-8) \
            / self.codec.max_val
        return jnp.where(self.scale > 0, self.scale, dyn)

    def _encode(self, x: jnp.ndarray, scale: jnp.ndarray):
        # sparq_quantize emits reconstructed codes (window << shift); the
        # pack shifts them back down to window form. The extra elementwise
        # pass is deliberate: it keeps the quant kernel's public contract
        # (codes ready for an int matmul) unchanged, and is noise next to
        # the attention matmuls on the simulated (non-TPU) path.
        from repro.kernels.ops import sparq_pack, sparq_quantize
        qs = QScale(scale=scale, bits=self.codec.act_bits,
                    signed=self.codec.signed)
        codes, meta = sparq_quantize(x.astype(jnp.float32), qs, self.codec,
                                     impl=self.impl)
        return sparq_pack(codes, meta), meta

    def append(self, x_new: jnp.ndarray, pos: jnp.ndarray) -> "CachedTensor":
        """Insert a float [B, T_new, ...] slab at time offset `pos`.

        T_new is static; `pos` is a traced int32 scalar. The sparq layout
        quantizes on write (per-site scale resolved as above); note the
        traced write clamps `pos` at the capacity rather than erroring —
        callers bound-check host-side (see DecodeEngine.generate)."""
        if self.layout == "fp":
            data = jax.lax.dynamic_update_slice_in_dim(
                self.data, x_new.astype(self.data.dtype), pos, axis=1)
            return dataclasses.replace(self, data=data)
        scale = self._resolve_scale(x_new)
        store, meta = self._encode(x_new, scale)
        data = jax.lax.dynamic_update_slice_in_dim(
            self.data, store, pos, axis=1)
        meta = jax.lax.dynamic_update_slice_in_dim(
            self.meta, meta, pos, axis=1)
        return dataclasses.replace(self, data=data, meta=meta, scale=scale)

    def write_slots(self, x_new: jnp.ndarray,
                    slots: jnp.ndarray) -> "CachedTensor":
        """Scatter float [B, T_new, ...] into ring slots (int32 [T_new])
        along axis 1 — the sliding-window ring cache's rolling write."""
        if self.layout == "fp":
            data = self.data.at[:, slots].set(x_new.astype(self.data.dtype))
            return dataclasses.replace(self, data=data)
        scale = self._resolve_scale(x_new)
        store, meta = self._encode(x_new, scale)
        data = self.data.at[:, slots].set(store)
        meta = self.meta.at[:, slots].set(meta)
        return dataclasses.replace(self, data=data, meta=meta, scale=scale)

    @property
    def is_sparq(self) -> bool:
        return self.layout == "sparq"

    # -------------------------------------------------------------- read
    def read(self, dtype=None) -> jnp.ndarray:
        """Dequantized full plane — the prefill/debug fallback ONLY.

        The decode hot path must NOT call this for the sparq layout: the
        fused kernels (kernels.ops.sparq_decode_attention, the tiled MLA
        decode) consume the raw (data, meta, scale) planes directly, so the
        packed bytes are what actually stream from HBM. A full-plane read
        on every decode step would re-expand the cache to fp32 and forfeit
        the §5.1 memory-bound win (enforced by a spy test in test_cache)."""
        if self.layout == "fp":
            return self.data if dtype is None else self.data.astype(dtype)
        from repro.kernels.ops import sparq_dequantize
        codes = sparq_dequantize(self.data, self.meta, impl=self.impl)
        out = codes.astype(jnp.float32) * self.scale
        return out if dtype is None else out.astype(dtype)

    @property
    def n_values(self) -> int:
        return int(self.data.size)


# ----------------------------------------------------------------------
# CacheStore: the (k, v, pos) KV cache — replaces the bare KVCache tuple
# ----------------------------------------------------------------------

class CacheStore(NamedTuple):
    """Full-attention KV cache: two CachedTensor planes + write position."""
    k: CachedTensor
    v: CachedTensor
    pos: jnp.ndarray        # scalar int32: tokens already in cache

    @staticmethod
    def init(shape, cc: CacheConfig, mesh=None) -> "CacheStore":
        return CacheStore(k=CachedTensor.init(shape, cc, mesh=mesh),
                          v=CachedTensor.init(shape, cc, mesh=mesh),
                          pos=jnp.zeros((), jnp.int32))

    @staticmethod
    def from_kv(k: jnp.ndarray, v: jnp.ndarray, pos) -> "CacheStore":
        """Wrap plain float K/V arrays (encoder cross-attention)."""
        return CacheStore(k=CachedTensor.fp(k), v=CachedTensor.fp(v),
                          pos=jnp.asarray(pos, jnp.int32))

    def update(self, k_new: jnp.ndarray, v_new: jnp.ndarray) -> "CacheStore":
        """Append float [B, T_new, KV, hd] K/V at `pos`; advances pos."""
        T_new = k_new.shape[1]
        return CacheStore(k=self.k.append(k_new, self.pos),
                          v=self.v.append(v_new, self.pos),
                          pos=self.pos + T_new)

    def kv(self, dtype=None):
        """Full dequantized (k, v) planes — prefill/debug fallback only;
        the decode hot path reads the packed planes (see CachedTensor.read)."""
        return self.k.read(dtype), self.v.read(dtype)


# ----------------------------------------------------------------------
# footprint accounting
# ----------------------------------------------------------------------

def modeled_cache_bytes(caches) -> dict:
    """Walk a cache pytree; model packed HBM residency per §5.1.

    CachedTensor planes are charged `bytes_per_value` (+ ShiftCtrl plane);
    any other array leaf (recurrent state, slot indices, positions) is
    charged its actual dtype size.
    """
    tally = {"data_bytes": 0.0, "ctrl_bytes": 0.0, "values": 0,
             "other_bytes": 0.0}

    def visit(node):
        if isinstance(node, CachedTensor):
            cc = CacheConfig(layout=node.layout,
                             dtype=node.data.dtype,
                             sparq=node.codec, impl=node.impl) \
                if node.layout == "sparq" else \
                CacheConfig(layout="fp", dtype=node.data.dtype)
            tally["data_bytes"] += node.n_values * bytes_per_value(cc)
            tally["ctrl_bytes"] += node.n_values * ctrl_bytes_per_value(cc)
            tally["values"] += node.n_values
        else:
            tally["other_bytes"] += node.size * node.dtype.itemsize
        return node

    jax.tree.map(visit, caches,
                 is_leaf=lambda n: isinstance(n, CachedTensor))
    tally["total_bytes"] = (tally["data_bytes"] + tally["ctrl_bytes"] +
                            tally["other_bytes"])
    return tally
