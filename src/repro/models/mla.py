"""Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV cache.

The KV cache stores only the rank-r latent c_kv (plus one shared RoPE key
head) — for deepseek-v2-lite: 512 + 64 = 576 floats/token vs 4096 for GQA-16,
a 7.1x cache compression. Decode uses the *absorbed* form: W_uk folds into
the query and W_uv into the output projection, so attention runs directly in
the latent space (no per-token decompression).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention
from repro.models.cache import CacheConfig, CachedTensor
from repro.models.common import ModelConfig, QuantCtx, dense, init_dense, rope
from repro.models.quantize import as_weight


class MLACache(NamedTuple):
    c_kv: CachedTensor     # [B, Tmax, r] latent plane (fp or sparq layout)
    k_pe: CachedTensor     # [B, Tmax, rope_dim] shared RoPE key plane
    pos: jnp.ndarray


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    d, H = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    return {
        "wq": init_dense(ks[0], d, H * (dn + dr), dtype=dtype),
        "w_dkv": init_dense(ks[1], d, r + dr, dtype=dtype),
        "w_uk": init_dense(ks[2], r, H * dn, dtype=dtype),
        "w_uv": init_dense(ks[3], r, H * dv, dtype=dtype),
        "wo": init_dense(ks[4], H * dv, d,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
        "c_norm": jnp.zeros((r,), jnp.float32),
    }


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * (1.0 + scale)).astype(x.dtype)


def mla_block(params: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: jnp.ndarray,
              cache: Optional[MLACache] = None,
              mode: str = "train",
              ctx: Optional[QuantCtx] = None):
    """Returns (out, new_cache)."""
    B, T, _ = x.shape
    H = cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q = dense(params["wq"], x, "mla_q", ctx).reshape(B, T, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = rope(q_pe, positions, cfg.rope_theta)

    ckv_pe = dense(params["w_dkv"], x, "mla_dkv", ctx)
    c_kv = _rms(ckv_pe[..., :r], params["c_norm"], cfg.norm_eps)
    k_pe = rope(ckv_pe[..., None, r:], positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if mode in ("prefill", "decode"):
        assert cache is not None
        new_cache = MLACache(cache.c_kv.append(c_kv, cache.pos),
                             cache.k_pe.append(k_pe, cache.pos),
                             cache.pos + T)

    if mode == "decode":
        # absorbed form: attend in latent space (cache planes dequantized
        # on read — the sparq layout's meta-decode + per-site scale)
        c_full = new_cache.c_kv.read(x.dtype)
        pe_full = new_cache.k_pe.read(x.dtype)
        wuk = as_weight(params["w_uk"], x.dtype).reshape(r, H, dn)
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, wuk)
        s = (jnp.einsum("bthr,bsr->bhts", q_lat, c_full) +
             jnp.einsum("bthe,bse->bhts", q_pe, pe_full))
        s = s.astype(jnp.float32) * (dn + dr) ** -0.5
        kpos = jnp.arange(c_full.shape[1])
        s = jnp.where((kpos < new_cache.pos)[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", p.astype(x.dtype),
                           c_full.astype(x.dtype))
        wuv = as_weight(params["w_uv"], x.dtype).reshape(r, H, dv)
        out = jnp.einsum("bthr,rhv->bthv", o_lat, wuv)
    else:
        # naive form: decompress K/V, shared rope key head across heads
        k_nope = dense(params["w_uk"], c_kv, "mla_uk", ctx).reshape(
            B, T, H, dn)
        v = dense(params["w_uv"], c_kv, "mla_uv", ctx).reshape(B, T, H, dv)
        k_pe_b = jnp.broadcast_to(k_pe[:, :, None], (B, T, H, dr))
        qf = jnp.concatenate([q_nope, q_pe], -1)
        kf = jnp.concatenate([k_nope, k_pe_b], -1)
        # pad v to qk head dim for the shared flash kernel, then slice
        if dv < dn + dr:
            v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        else:
            v_p = v
        out = flash_attention(qf, kf, v_p, causal=True,
                              q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        out = out[..., :dv]
    out = out.reshape(B, T, H * dv)
    return dense(params["wo"], out, "mla_out", ctx), new_cache


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16,
                   cache_cfg: Optional[CacheConfig] = None) -> MLACache:
    cc = cache_cfg or CacheConfig(layout="fp", dtype=dtype)
    return MLACache(
        c_kv=CachedTensor.init((batch, max_len, cfg.kv_lora_rank), cc),
        k_pe=CachedTensor.init((batch, max_len, cfg.qk_rope_dim), cc),
        pos=jnp.zeros((), jnp.int32))
