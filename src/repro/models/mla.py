"""Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV cache.

The KV cache stores only the rank-r latent c_kv (plus one shared RoPE key
head) — for deepseek-v2-lite: 512 + 64 = 576 floats/token vs 4096 for GQA-16,
a 7.1x cache compression. Decode uses the *absorbed* form: W_uk folds into
the query and W_uv into the output projection, so attention runs directly in
the latent space (no per-token decompression).

Cache layouts: the latent planes are CachedTensors, so they store fp or the
§5.1 packed sparq format (quantize-on-write, tiled fused meta-decode on
read via `_sparq_mla_decode`). The MLA cache stays on the *contiguous*
layout and the scan engine — its scores couple two quantized planes, which
the shared paged GQA kernel does not model; paging the latent cache is a
possible follow-up (the block-table machinery in models/paging.py is
layout-agnostic).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention
from repro.models.cache import CacheConfig, CachedTensor
from repro.models.common import ModelConfig, QuantCtx, dense, init_dense, rope
from repro.models.quantize import as_weight


class MLACache(NamedTuple):
    c_kv: CachedTensor     # [B, Tmax, r] latent plane (fp or sparq layout)
    k_pe: CachedTensor     # [B, Tmax, rope_dim] shared RoPE key plane
    pos: jnp.ndarray


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    d, H = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    return {
        "wq": init_dense(ks[0], d, H * (dn + dr), dtype=dtype),
        "w_dkv": init_dense(ks[1], d, r + dr, dtype=dtype),
        "w_uk": init_dense(ks[2], r, H * dn, dtype=dtype),
        "w_uv": init_dense(ks[3], r, H * dv, dtype=dtype),
        "wo": init_dense(ks[4], H * dv, d,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5, dtype=dtype),
        "c_norm": jnp.zeros((r,), jnp.float32),
    }


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * (1.0 + scale)).astype(x.dtype)


def _sparq_mla_decode(q_lat: jnp.ndarray, q_pe: jnp.ndarray,
                      cache: MLACache, *, sm_scale: float, out_dtype,
                      bk: int = 128) -> jnp.ndarray:
    """Fused absorbed-MLA decode over the packed latent planes.

    Scores couple two quantized planes (s = q_lat·c_kv + q_pe·k_pe, each
    with its own per-site scale), so this uses a tiled lax.scan rather than
    the shared GQA kernel: each Tk tile is meta-decoded (via
    ops.sparq_dequantize — reference or Pallas per the plane's impl) and
    folded into an online-softmax accumulation in latent space. The full fp
    latent plane is never materialized. Returns o_lat [B, 1, H, r]."""
    from repro.kernels.ops import sparq_dequantize
    B, _, H, r = q_lat.shape
    Tk = cache.c_kv.data.shape[1]
    bk = min(bk, Tk)

    def pad_t(x):       # pad the time axis to a tile multiple (packed int8)
        return jnp.pad(x, ((0, 0), (0, (-Tk) % bk), (0, 0)))

    c_data = pad_t(cache.c_kv.data)
    c_meta = pad_t(cache.c_kv.meta)
    p_data = pad_t(cache.k_pe.data)
    p_meta = pad_t(cache.k_pe.meta)
    # padded slots have kpos >= Tk >= cache.pos, so kpos < pos masks them
    kpos_all = jnp.arange(c_data.shape[1], dtype=jnp.int32)
    ql = q_lat[:, 0].astype(jnp.float32)                   # [B, H, r]
    qp = q_pe[:, 0].astype(jnp.float32)                    # [B, H, dr]
    impl = cache.c_kv.impl
    c_scale = cache.c_kv.scale
    pe_scale = cache.k_pe.scale

    def tile(carry, t):
        m, l, acc = carry
        cs = jax.lax.dynamic_slice_in_dim(c_data, t * bk, bk, 1)
        cm = jax.lax.dynamic_slice_in_dim(c_meta, t * bk, bk, 1)
        ps = jax.lax.dynamic_slice_in_dim(p_data, t * bk, bk, 1)
        pm = jax.lax.dynamic_slice_in_dim(p_meta, t * bk, bk, 1)
        kp = jax.lax.dynamic_slice_in_dim(kpos_all, t * bk, bk)
        c_f = sparq_dequantize(cs, cm, impl=impl).astype(jnp.float32) \
            * c_scale                                      # [B, bk, r]
        pe_f = sparq_dequantize(ps, pm, impl=impl).astype(jnp.float32) \
            * pe_scale                                     # [B, bk, dr]
        s = (jnp.einsum("bhr,bsr->bhs", ql, c_f,
                        preferred_element_type=jnp.float32) +
             jnp.einsum("bhe,bse->bhs", qp, pe_f,
                        preferred_element_type=jnp.float32)) * sm_scale
        ok = (kp < cache.pos)[None, None, :]               # [1, 1, bk]
        s = jnp.where(ok, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(ok, p, 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bhs,bsr->bhr", p, c_f,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr + pv), None

    m0 = jnp.full((B, H, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, 1), jnp.float32)
    a0 = jnp.zeros((B, H, r), jnp.float32)
    nT = c_data.shape[1] // bk
    (m, l, acc), _ = jax.lax.scan(tile, (m0, l0, a0), jnp.arange(nT))
    o_lat = acc / jnp.maximum(l, 1e-30)
    return o_lat[:, None].astype(out_dtype)                # [B, 1, H, r]


def mla_block(params: Dict, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: jnp.ndarray,
              cache: Optional[MLACache] = None,
              mode: str = "train",
              ctx: Optional[QuantCtx] = None):
    """Returns (out, new_cache)."""
    B, T, _ = x.shape
    H = cfg.n_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q = dense(params["wq"], x, "mla_q", ctx).reshape(B, T, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = rope(q_pe, positions, cfg.rope_theta)

    ckv_pe = dense(params["w_dkv"], x, "mla_dkv", ctx)
    c_kv = _rms(ckv_pe[..., :r], params["c_norm"], cfg.norm_eps)
    k_pe = rope(ckv_pe[..., None, r:], positions, cfg.rope_theta)[:, :, 0]

    new_cache = None
    if mode in ("prefill", "decode"):
        assert cache is not None
        new_cache = MLACache(cache.c_kv.append(c_kv, cache.pos),
                             cache.k_pe.append(k_pe, cache.pos),
                             cache.pos + T)

    if mode == "decode":
        # absorbed form: attend in latent space. sparq layout: tiled fused
        # decode over the raw packed planes (per-tile §5.1 meta-decode, no
        # full-plane read); fp layout: plane read + plain softmax.
        wuk = as_weight(params["w_uk"], x.dtype).reshape(r, H, dn)
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, wuk)
        if new_cache.c_kv.is_sparq:
            o_lat = _sparq_mla_decode(q_lat, q_pe, new_cache,
                                      sm_scale=(dn + dr) ** -0.5,
                                      out_dtype=x.dtype)
        else:
            c_full = new_cache.c_kv.read(x.dtype)
            pe_full = new_cache.k_pe.read(x.dtype)
            s = (jnp.einsum("bthr,bsr->bhts", q_lat, c_full) +
                 jnp.einsum("bthe,bse->bhts", q_pe, pe_full))
            s = s.astype(jnp.float32) * (dn + dr) ** -0.5
            kpos = jnp.arange(c_full.shape[1])
            s = jnp.where((kpos < new_cache.pos)[None, None, None],
                          s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1)
            o_lat = jnp.einsum("bhts,bsr->bthr", p.astype(x.dtype),
                               c_full.astype(x.dtype))
        wuv = as_weight(params["w_uv"], x.dtype).reshape(r, H, dv)
        out = jnp.einsum("bthr,rhv->bthv", o_lat, wuv)
    else:
        # naive form: decompress K/V, shared rope key head across heads
        k_nope = dense(params["w_uk"], c_kv, "mla_uk", ctx).reshape(
            B, T, H, dn)
        v = dense(params["w_uv"], c_kv, "mla_uv", ctx).reshape(B, T, H, dv)
        k_pe_b = jnp.broadcast_to(k_pe[:, :, None], (B, T, H, dr))
        qf = jnp.concatenate([q_nope, q_pe], -1)
        kf = jnp.concatenate([k_nope, k_pe_b], -1)
        # pad v to qk head dim for the shared flash kernel, then slice
        if dv < dn + dr:
            v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        else:
            v_p = v
        out = flash_attention(qf, kf, v_p, causal=True,
                              q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
        out = out[..., :dv]
    out = out.reshape(B, T, H * dv)
    return dense(params["wo"], out, "mla_out", ctx), new_cache


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16,
                   cache_cfg: Optional[CacheConfig] = None) -> MLACache:
    cc = cache_cfg or CacheConfig(layout="fp", dtype=dtype)
    return MLACache(
        c_kv=CachedTensor.init((batch, max_len, cfg.kv_lora_rank), cc),
        k_pe=CachedTensor.init((batch, max_len, cfg.qk_rope_dim), cc),
        pos=jnp.zeros((), jnp.int32))
