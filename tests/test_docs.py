"""Docs stay true: the byte-level format reference is executable
(doctests cross-check every bytes/value figure against kernels.ops), and
relative markdown links across README/docs resolve to real files."""
import doctest
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

# [text](target) — skip absolute URLs and in-page anchors
_LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def test_packed_format_doctests():
    """The §5.1 format doc's code blocks run against the live kernels
    (same check CI runs via `python -m doctest`)."""
    result = doctest.testfile(str(ROOT / "docs" / "packed_format.md"),
                              module_relative=False, verbose=False)
    assert result.attempted >= 10, "format doc lost its executable table"
    assert result.failed == 0


@pytest.mark.parametrize("md", DOCS, ids=[p.name for p in DOCS])
def test_markdown_links_resolve(md):
    missing = []
    for m in _LINK.finditer(md.read_text()):
        target = m.group(1)
        if "://" in target or target.startswith("mailto:"):
            continue
        if not (md.parent / target).exists():
            missing.append(target)
    assert not missing, f"{md.name}: broken relative links {missing}"
