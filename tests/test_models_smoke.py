"""Per-architecture smoke tests (assignment requirement): reduced configs,
one forward/train step + prefill/decode on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_reduced_config
from repro.models.model import Model

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg, key=KEY):
    k1, k2 = jax.random.split(key)
    batch = {}
    S_tok = S
    if cfg.family == "vlm":
        P = cfg.frontend_len
        batch["image_embeds"] = jax.random.normal(
            k2, (B, P, cfg.d_model), jnp.float32) * 0.02
        S_tok = S - P
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            k2, (B, S, cfg.d_model), jnp.float32) * 0.02
    batch["tokens"] = jax.random.randint(k1, (B, S_tok), 0, cfg.vocab_size)
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        # capacity_factor large -> dropless MoE so teacher-forced decode is
        # exactly comparable with the full forward pass
        cfg = get_reduced_config(arch).replace(dtype=jnp.float32, remat=False,
                                               capacity_factor=1000.0)
        model = Model(cfg)
        params = model.init_params(jax.random.fold_in(KEY, hash(arch) % 997))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(built, arch):
    cfg, model, params = built[arch]
    batch = _batch(cfg)
    logits = model.logits(params, batch)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(built, arch):
    cfg, model, params = built[arch]
    batch = _batch(cfg)

    def loss(p):
        l, _ = model.loss(p, batch)
        return l

    l, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    # rough sanity: CE near log(V) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(l) < \
        2.5 * np.log(cfg.vocab_size) + 2
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(built, arch):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg, model, params = built[arch]
    batch = _batch(cfg)
    full = model.logits(params, batch)

    caches = model.init_cache(B, S + 8, dtype=jnp.float32)
    last, caches = model.prefill(params, batch, caches)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2)
    # decode the next token and compare against an extended forward pass
    nxt = jnp.argmax(last, -1)[:, None]
    dec_logits, caches = model.decode_step(
        params, nxt, caches, pos=batch["tokens"].shape[1]
        + (cfg.frontend_len if cfg.family == "vlm" else 0))
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], 1)
    if cfg.is_encdec:
        pass  # frames unchanged
    full2 = model.logits(params, batch2)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full2[:, -1]),
                               rtol=3e-2, atol=3e-2)


def test_rwkv_scan_vs_chunked():
    cfg = get_reduced_config("rwkv6-7b").replace(dtype=jnp.float32,
                                                 remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg)
    l_chunked = model.logits(params, batch)
    cfg_s = cfg.replace(mixer_impl="scan")
    l_scan = Model(cfg_s).logits(params, batch)
    np.testing.assert_allclose(np.asarray(l_chunked), np.asarray(l_scan),
                               rtol=5e-3, atol=5e-3)


def test_rglru_assoc_vs_scan():
    cfg = get_reduced_config("recurrentgemma-9b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg)
    l_assoc = model.logits(params, batch)
    l_scan = Model(cfg.replace(mixer_impl="scan")).logits(params, batch)
    np.testing.assert_allclose(np.asarray(l_assoc), np.asarray(l_scan),
                               rtol=5e-3, atol=5e-3)


def test_quantized_forward_close_to_fp(built):
    """A8W8 quantized inference stays close to FP (paper Table 1 premise)."""
    cfg, model, params = built["tinyllama-1.1b"]
    batch = _batch(cfg)
    full = model.logits(params, batch)
    from repro.core.sparq import SparqConfig
    from repro.models.common import QuantCtx
    scales = model.calibrate(params, [batch])
    ctx = QuantCtx(mode="quantized", cfg=SparqConfig(enabled=False,
                                                     signed=True))
    q = Model(cfg).logits_with_scales(params, batch, ctx, scales) \
        if hasattr(Model, "logits_with_scales") else None
    if q is None:
        x, pl = model.forward(params, batch, ctx, scales)
        q = model._head(params, x if not pl else x[:, pl:])
    err = np.abs(np.asarray(q) - np.asarray(full)).mean()
    scale = np.abs(np.asarray(full)).mean() + 1e-6
    assert err / scale < 0.15
