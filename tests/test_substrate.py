"""Substrate tests: checkpoint atomicity/elasticity, fault-tolerance logic,
data determinism, gradient compression, optimizer, sharding rule fitting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import Batcher, DataConfig
from repro.distributed.collectives import GradCompressor, sparq_compress
from repro.distributed.fault import (ElasticCoordinator, HeartbeatMonitor,
                                     StragglerDetector, plan_remesh)
from repro.distributed.sharding import fit_spec, param_pspecs
from repro.optim.adamw import AdamW, cosine_schedule


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(12.0).reshape(3, 4) + k,
                "b": {"c": jnp.ones((5,), jnp.int32) * (k + 1)},
                "d": [jnp.zeros((2, 2)) + k]}

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 7, self._tree(3))
        out = ckpt.restore(d, 7, self._tree(0))
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), out, self._tree(3))

    def test_latest_and_prune(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, self._tree(s), keep=2)
        assert ckpt.latest_step(d) == 5
        assert ckpt.all_steps(d) == [4, 5]

    def test_atomic_no_tmp_left(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._tree())
        assert not any(n.endswith(".tmp") for n in os.listdir(d))

    def test_missing_leaf_keeps_template(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, {"a": jnp.ones((2,))})
        out = ckpt.restore(d, 1, {"a": jnp.zeros((2,)),
                                  "new": jnp.full((3,), 9.0)})
        np.testing.assert_array_equal(np.asarray(out["a"]), [1, 1])
        np.testing.assert_array_equal(np.asarray(out["new"]), [9, 9, 9])


class TestFault:
    def test_heartbeat_death(self):
        mon = HeartbeatMonitor(timeout_s=10)
        mon.beat(0, 5, now=100.0)
        mon.beat(1, 5, now=100.0)
        mon.beat(1, 6, now=200.0)
        assert mon.dead_workers(now=205.0) == [0]
        assert mon.alive(now=205.0) == [1]

    def test_straggler_zscore(self):
        det = StragglerDetector(z_threshold=2.0)
        for w in range(8):
            for _ in range(10):
                det.record(w, 1.0 if w != 3 else 5.0)
        assert det.stragglers() == [3]

    def test_remesh_plan(self):
        plan = plan_remesh(512, model_parallel=16)
        assert plan.mesh_shape == (2, 16, 16)
        plan = plan_remesh(511, model_parallel=16)  # lost one chip
        assert plan.mesh_shape == (16, 16)
        plan = plan_remesh(100, model_parallel=16)
        assert plan.mesh_shape == (4, 16)
        with pytest.raises(ValueError):
            plan_remesh(8, model_parallel=16)

    def test_coordinator_end_to_end(self):
        c = ElasticCoordinator(n_workers=4, model_parallel=2)
        for w in range(4):
            c.step_report(w, 1, 0.5, now=100.0)
        assert c.maybe_remesh(now=101.0) is None
        for w in (0, 1, 2):
            c.step_report(w, 2, 0.5, now=280.0)
        plan = c.maybe_remesh(restore_step=2, now=290.0)
        assert plan is not None and plan.dropped_workers == (3,)
        assert plan.mesh_shape == (1, 2) and plan.restore_step == 2


class TestData:
    def test_determinism_across_restart(self):
        cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=4)
        b1, b2 = Batcher(cfg), Batcher(cfg)
        for step in (0, 5, 17):
            x, y = b1.global_batch(step), b2.global_batch(step)
            np.testing.assert_array_equal(np.asarray(x["tokens"]),
                                          np.asarray(y["tokens"]))

    def test_steps_differ_and_structured(self):
        cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4)
        b = Batcher(cfg)
        t0 = np.asarray(b.global_batch(0)["tokens"])
        t1 = np.asarray(b.global_batch(1)["tokens"])
        assert (t0 != t1).any()
        # structured stream: far fewer unique tokens than uniform noise
        assert len(np.unique(t0)) < 0.8 * min(512, t0.size)

    def test_host_sharding_disjoint(self):
        cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=8)
        a = Batcher(cfg, host_id=0, n_hosts=2).local_batch(3)
        b = Batcher(cfg, host_id=1, n_hosts=2).local_batch(3)
        assert a["tokens"].shape == (4, 16)
        assert (np.asarray(a["tokens"]) != np.asarray(b["tokens"])).any()


class TestGradCompression:
    def test_error_feedback_accumulates(self):
        gc = GradCompressor(min_size=1)
        g = {"w": jnp.linspace(-1, 1, 8192).reshape(64, 128)}
        state = gc.init(g)
        cg, state = gc.compress(g, state)
        err = np.asarray(state["w"])
        assert np.abs(err).max() > 0  # quantization error captured
        # compressed + error == original (exact bookkeeping)
        np.testing.assert_allclose(
            np.asarray(cg["w"], np.float64) + err,
            np.asarray(g["w"], np.float64), rtol=1e-6, atol=1e-6)

    def test_compression_is_close(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (256, 256)) * 1e-3
        c = sparq_compress(g, bits=4)
        rel = float(jnp.linalg.norm(c - g) / jnp.linalg.norm(g))
        assert rel < 0.05  # 4-bit windowed: ~2% typical

    def test_tiny_tensors_exact(self):
        gc = GradCompressor(min_size=4096)
        g = {"scale": jnp.asarray([1.0, -2.0, 3.0])}
        cg, _ = gc.compress(g, gc.init(g))
        np.testing.assert_array_equal(np.asarray(cg["scale"]),
                                      np.asarray(g["scale"]))


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)  # d/dx x^2
            params, state, _ = opt.update(grads, state, params)
        assert float(jnp.abs(params["x"]).max()) < 1e-2

    def test_clip_norm(self):
        opt = AdamW(lr=1e-3, clip_norm=1.0)
        params = {"x": jnp.zeros((4,))}
        state = opt.init(params)
        _, _, m = opt.update({"x": jnp.full((4,), 100.0)}, state, params)
        assert float(m["grad_norm"]) > 100

    def test_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=110)
        assert abs(float(lr(jnp.asarray(5))) - 0.5) < 1e-6
        assert float(lr(jnp.asarray(10))) == 1.0
        assert float(lr(jnp.asarray(110))) <= 0.11


class TestShardingRules:
    def test_fit_spec_drops_indivisible(self):
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        # all sizes divide 1 -> everything kept
        assert fit_spec((8, 8), P("data", "model"), mesh) == \
            P("data", "model")

    def test_param_pspecs_shapes(self):
        from repro.configs.base import get_reduced_config
        from repro.models.model import Model
        cfg = get_reduced_config("tinyllama-1.1b")
        model = Model(cfg)
        abs_p = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0)))
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        specs = param_pspecs(abs_p, mesh)
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert all(isinstance(s, P) for s in flat)
        # every spec's rank must not exceed its param's rank
        leaves = jax.tree.leaves(abs_p)
        specs_l = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for a, s in zip(leaves, specs_l):
            assert len(s) <= len(a.shape)
