"""Preemption scheduler for oversubscribed paged serving.

The engine's scheduling state machine (admission, join/evict, alloc/free,
preempt/resume) has outgrown example-driven testing, so this module drives
it with deterministic *randomized traces*: a seeded generator emits
arrival/length/eviction traces which are replayed through the paged engine
at several pool sizes — including heavily oversubscribed ones — under both
preemption policies, asserting per-step invariants through the engine's
`trace_hook` (no page double-use, free-list conservation, block-table /
seq-position consistency, host/device agreement) plus end-state greedy
token equality against the uncontended contiguous engine.

Also here: the acceptance matrix (int8 grid under both policies; the trace
runs cover 4-bit 5opt), strict resume-before-admit priority, the
decode-time PoolExhausted regression (a failed step allocation must not
strand pages off the free list), and a property test of arbitrary
alloc/free/swap interleavings on the allocator (hypothesis when available,
a seeded deterministic sweep otherwise, same convention as test_bsparq).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparq import SparqConfig
from repro.models.cache import CacheConfig
from repro.models.paging import PageAllocator, PoolExhausted

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)
PS = 4                                          # page size for every trace


# ----------------------------------------------------------------------
# allocator property test: arbitrary alloc/free/swap interleavings
# ----------------------------------------------------------------------

def _run_allocator_script(n_pages: int, ops):
    """Interpret (op, arg) pairs against a PageAllocator, mirroring the
    engine's usage: sequences hold pages, swap-out frees them (the swap
    store keeps only bytes, never page ids), swap-in allocates afresh.
    Conservation and uniqueness are asserted after every operation."""
    al = PageAllocator(n_pages)
    held = {}                                   # seq tag -> owned pages
    swapped = {}                                # seq tag -> page count
    next_tag = 0
    for op_i, arg in ops:
        op = ("alloc", "free", "swap_out", "swap_in")[op_i % 4]
        if op == "alloc":
            n = 1 + arg % 3
            if n <= al.free_count:
                pages = al.alloc(n)
                assert len(set(pages)) == n
                for other in held.values():
                    assert set(pages).isdisjoint(other), "double handout"
                held[next_tag] = pages
                next_tag += 1
            else:
                before = al.free_pages
                with pytest.raises(PoolExhausted):
                    al.alloc(n)
                assert al.free_pages == before, "failed alloc took pages"
        elif op == "free" and held:
            tag = sorted(held)[arg % len(held)]
            al.free(held.pop(tag))
        elif op == "swap_out" and held:
            tag = sorted(held)[arg % len(held)]
            pages = held.pop(tag)
            al.free(pages)                      # pages return; bytes host
            swapped[tag] = len(pages)
        elif op == "swap_in" and swapped:
            tag = sorted(swapped)[arg % len(swapped)]
            if swapped[tag] <= al.free_count:
                held[tag] = al.alloc(swapped.pop(tag))
        # free-list conservation after every operation
        owned = [p for pages in held.values() for p in pages]
        assert len(owned) == len(set(owned))
        assert al.free_count + len(owned) == n_pages
        al.assert_consistent()
    return al, held


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 12),
           st.lists(st.tuples(st.integers(0, 3), st.integers(0, 10 ** 6)),
                    max_size=60))
    @settings(max_examples=200, deadline=None)
    def test_allocator_interleavings_property(n_pages, ops):
        _run_allocator_script(n_pages, ops)


def test_allocator_interleavings_sweep():
    """Deterministic fallback: seeded random scripts exercise the same
    interleaving property when hypothesis is unavailable."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        n_pages = int(rng.integers(1, 12))
        ops = [(int(rng.integers(0, 4)), int(rng.integers(0, 10 ** 6)))
               for _ in range(60)]
        _run_allocator_script(n_pages, ops)


# ----------------------------------------------------------------------
# randomized-trace harness
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    return model, params


def _cc(codec=None):
    codec = codec or SparqConfig.opt5(signed=True)
    # attn_bk = page size: the contiguous oracle's fused decode tiles
    # coincide with pages, so oracle and paged tokens are bit-identical
    return dataclasses.replace(
        CacheConfig.sparq_cache(codec, impl="reference"), attn_bk=PS)


def _guard_transfers(eng):
    """Run the engine's jitted step/chunk entry points under
    `jax.transfer_guard("disallow")`: every argument must already live
    on device, so an implicit host->device transfer sneaking into the
    per-step dispatch path fails loudly here (the static counterpart is
    HL202 in `python -m repro.analysis`)."""
    import functools

    def wrap(fn):
        @functools.wraps(fn)
        def guarded(*a, **k):
            with jax.transfer_guard("disallow"):
                return fn(*a, **k)
        if hasattr(fn, "_cache_size"):      # compile_count reads this
            guarded._cache_size = fn._cache_size
        return guarded

    eng._step = wrap(eng._step)
    if eng._sched is not None:
        eng._sched._chunk = wrap(eng._sched._chunk)
    return eng


def _make_trace(seed: int, n_req: int, vocab: int):
    """Seeded arrival/length trace: ragged prompts, ragged token budgets
    (eviction times), staggered arrivals."""
    rng = np.random.default_rng(seed)
    from repro.launch.serve import Request
    reqs = []
    for _ in range(n_req):
        # short prompts + long budgets: sequences admit cheap and then
        # grow, which is what drives decode-time pool exhaustion
        L = int(rng.integers(3, 8))
        g = int(rng.integers(6, 15))
        a = int(rng.integers(0, 12))
        reqs.append(Request(rng.integers(0, vocab, (L,)), g, arrive_at=a))
    return reqs


@pytest.fixture(scope="module")
def trace(tiny_lm):
    model, _ = tiny_lm
    return _make_trace(seed=0, n_req=6, vocab=model.cfg.vocab_size)


@pytest.fixture(scope="module")
def oracle(tiny_lm, trace):
    """Uncontended per-request greedy tokens from the contiguous engine."""
    from repro.launch.serve import DecodeEngine
    model, params = tiny_lm
    eng = DecodeEngine(model, _cc())
    out = {}
    for rid, req in enumerate(trace):
        toks, _ = eng.generate(
            params, {"tokens": jnp.asarray(req.tokens)[None]}, req.gen,
            warmup=False)
        out[rid] = np.asarray(toks)[0]
    return out


class InvariantChecker:
    """Per-step scheduler invariants, asserted from outside the engine
    through `run(trace_hook=...)` — an independent re-derivation of the
    accounting the engine also asserts internally."""

    def __init__(self, ps: int, deep_every: int = 5):
        self.ps = ps
        self.deep_every = deep_every
        self.steps = 0
        self.max_owned = 0

    def __call__(self, snap):
        slots = snap["slots"]
        owned = [p for info in slots.values() for p in info["pages"]]
        mult = {}
        for p in owned:
            mult[p] = mult.get(p, 0) + 1
        free = set(snap["free_pages"])
        # refcount conservation: every page's refcount equals the number
        # of block-table references across slots (without a prefix cache
        # all counts are 1, reducing to the old no-double-use invariant)
        assert mult == snap["page_refcounts"], \
            "page refcounts disagree with block-table references"
        # "preemption never frees a page another sequence references":
        # a page on the free list is referenced by no live slot, and
        # free ∪ referenced covers the pool exactly
        assert free.isdisjoint(mult), "freed page still referenced"
        assert len(free) + len(mult) == snap["n_pages"], \
            "free-list conservation violated"
        # shared pages are write-never: any slot whose next write lands
        # mid-page must own that page exclusively
        for s, info in slots.items():
            if info["pos"] % self.ps:
                blk = info["pos"] // self.ps
                row = snap["host_bt"][s]
                if blk < row.shape[0] and row[blk] >= 0:
                    assert snap["page_refcounts"][row[blk]] == 1, \
                        f"slot {s} would write shared page {row[blk]}"
        self.max_owned = max(self.max_owned, len(owned))
        for s, info in slots.items():
            # block table is exactly the owned pages, in block order,
            # as a contiguous prefix of the row
            row = snap["host_bt"][s]
            nb = len(info["pages"])
            assert list(row[row >= 0]) == info["pages"]
            assert (row[:nb] >= 0).all() and (row[nb:] == -1).all()
            # the sequence position lies inside its allocated blocks
            assert 0 <= info["pos"] <= nb * self.ps
            assert info["pos"] > (nb - 1) * self.ps - self.ps, \
                "sequence owns more than one block past its position"
        # a request lives in exactly one place at a time
        places = ([info["rid"] for info in slots.values()]
                  + snap["resume_rids"] + snap["queued"])
        assert len(places) == len(set(places)), "request in two places"
        # host/device agreement (fetches device state; sampled)
        if self.steps % self.deep_every == 0:
            bt_dev = np.asarray(snap["caches"][0].block_table[0])
            np.testing.assert_array_equal(bt_dev, snap["host_bt"])
            pos_dev = np.asarray(snap["caches"][0].seq_pos[0])
            for s in range(pos_dev.shape[0]):
                if s in snap.get("prefilling", ()):
                    # mid-chunked-prefill: host tracks written prompt
                    # tokens, the device holds the -1 inactive sentinel
                    # so interleaved decode steps can't touch the slot
                    assert pos_dev[s] == -1, \
                        f"mid-prefill slot {s} active on device"
                    continue
                want = slots[s]["pos"] if s in slots else -1
                assert pos_dev[s] == want, f"slot {s} position drift"
        self.steps += 1


# pool sizes: generous (no preemption expected), tight, and heavily
# oversubscribed (barely above the largest single request); the chunked
# rows replay the same trace through the chunked ragged-prefill path
# (prompts fit one segment, so tokens must still match the oracle
# exactly, and the same per-step invariants must hold around mid-
# prefill slots and in-band replay)
@pytest.mark.parametrize("n_pages,policy_mode,prefill,expect_preempt", [
    (24, "requeue", "sequential", False),
    (8, "requeue", "sequential", True),
    (8, "swap", "sequential", True),
    (6, "requeue", "sequential", True),
    (6, "swap", "sequential", True),
    (8, "requeue", "chunked", True),
    (6, "swap", "chunked", True),
], ids=["pool24-requeue", "pool8-requeue", "pool8-swap",
        "pool6-requeue", "pool6-swap",
        "pool8-requeue-chunked", "pool6-swap-chunked"])
def test_trace_invariants_and_token_equality(tiny_lm, trace, oracle,
                                             n_pages, policy_mode,
                                             prefill, expect_preempt):
    """Replay the seeded trace at one pool size/policy: every step holds
    the page-accounting invariants and the end state reproduces the
    uncontended contiguous tokens exactly."""
    from repro.launch.serve import ContinuousBatchingEngine, SchedulerPolicy
    model, params = tiny_lm
    per_req = [math.ceil((len(r.tokens) + r.gen - 1) / PS) for r in trace]
    assert max(per_req) < n_pages <= sum(per_req) or n_pages == 24
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=n_pages, max_active=3,
        max_seq_len=24,
        policy=SchedulerPolicy(preempt=policy_mode, victim="last_joined"),
        prefill=prefill, chunk_size=16, chunk_align=4)
    _guard_transfers(eng)
    check = InvariantChecker(ps=PS)
    results, stats = eng.run(params, trace, trace_hook=check)
    assert check.steps == stats["decode_steps"] > 0
    if prefill == "chunked":
        assert stats["prefill_compile_count"] == 1
        assert stats["prefill_chunks"] > 0
    if expect_preempt:
        assert stats["preemptions"] > 0, \
            "trace did not stress the pool — tighten it"
        assert check.max_owned <= n_pages
        if policy_mode == "swap":
            assert stats["swap_bytes_out"] == stats["swap_bytes_in"] > 0
            if prefill == "sequential":
                assert stats["preempt_swap"] == stats["preemptions"]
            else:
                # chunked: a victim caught mid-prefill or mid-replay has
                # only a partial cache in its pages, so it requeues even
                # under the swap policy; complete victims still swap
                assert stats["preempt_swap"] > 0
        else:
            assert stats["replay_steps"] > 0
            assert stats["swap_bytes_out"] == 0
    else:
        assert stats["preemptions"] == 0
    for rid in oracle:
        np.testing.assert_array_equal(results[rid], oracle[rid])


def test_trace_policies_agree_on_victim_rule(tiny_lm, trace, oracle):
    """fewest_pages victim selection also preserves exactness (different
    preemption order, same tokens)."""
    from repro.launch.serve import ContinuousBatchingEngine, SchedulerPolicy
    model, params = tiny_lm
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=8, max_active=3, max_seq_len=24,
        policy=SchedulerPolicy(preempt="swap", victim="fewest_pages"))
    results, stats = eng.run(params, trace, trace_hook=InvariantChecker(PS))
    assert stats["preemptions"] > 0
    for rid in oracle:
        np.testing.assert_array_equal(results[rid], oracle[rid])


# ----------------------------------------------------------------------
# acceptance: int8 grid under both policies (5opt runs in the trace grid)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["requeue", "swap"])
def test_oversubscribed_int8_matches_uncontended(tiny_lm, mode):
    from repro.launch.serve import (ContinuousBatchingEngine, DecodeEngine,
                                    Request, SchedulerPolicy)
    model, params = tiny_lm
    cc = _cc(SparqConfig(enabled=False, signed=True))
    rng = np.random.default_rng(11)
    reqs = [Request(rng.integers(0, model.cfg.vocab_size, (L,)), g)
            for L, g in zip([5, 4, 6], [10, 9, 8])]
    eng = ContinuousBatchingEngine(
        model, cc, page_size=PS, n_pages=5, max_active=3, max_seq_len=16,
        policy=SchedulerPolicy(preempt=mode))
    results, stats = eng.run(params, reqs, trace_hook=InvariantChecker(PS))
    assert stats["preemptions"] > 0
    contiguous = DecodeEngine(model, cc)
    for rid, req in enumerate(reqs):
        toks, _ = contiguous.generate(
            params, {"tokens": jnp.asarray(req.tokens)[None]}, req.gen,
            warmup=False)
        np.testing.assert_array_equal(results[rid], np.asarray(toks)[0])


def test_finished_slot_is_evicted_not_preempted(tiny_lm):
    """A gen==1 request finishes at admission and its pages are
    reclaimed by ordinary eviction before the peer's growth needs them:
    serving it through a contended pool costs zero preemptions (the
    scheduler may never pay a swap round trip / replay for a sequence
    that will emit nothing)."""
    from repro.launch.serve import (ContinuousBatchingEngine, DecodeEngine,
                                    Request, SchedulerPolicy)
    model, params = tiny_lm
    rng = np.random.default_rng(4)
    grower = Request(rng.integers(0, model.cfg.vocab_size, (5,)), 12)
    oneshot = Request(rng.integers(0, model.cfg.vocab_size, (8,)), 1,
                      arrive_at=1)
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=4, max_active=2, max_seq_len=16,
        policy=SchedulerPolicy(preempt="swap"))
    results, stats = eng.run(params, [grower, oneshot],
                             trace_hook=InvariantChecker(PS))
    assert stats["preemptions"] == 0, \
        "scheduler preempted instead of reclaiming a finished slot"
    contiguous = DecodeEngine(model, _cc())
    for rid, req in enumerate([grower, oneshot]):
        toks, _ = contiguous.generate(
            params, {"tokens": jnp.asarray(req.tokens)[None]}, req.gen,
            warmup=False)
        np.testing.assert_array_equal(results[rid], np.asarray(toks)[0])


# ----------------------------------------------------------------------
# resume-before-admit priority
# ----------------------------------------------------------------------

def test_resume_has_priority_over_admission(tiny_lm):
    """While a preempted sequence waits for pages, a cheaper queued
    request must NOT jump past it: B (swapped, needs 3 pages) blocks C
    (needs 1 page, pool has 1 free) until B resumes."""
    from repro.launch.serve import (ContinuousBatchingEngine, Request,
                                    SchedulerPolicy)
    model, params = tiny_lm
    rng = np.random.default_rng(5)
    mk = lambda L, g: Request(
        rng.integers(0, model.cfg.vocab_size, (L,)), g)
    reqs = [mk(4, 8), mk(4, 6), mk(4, 2)]       # A, B, C
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=4, max_active=2, max_seq_len=12,
        policy=SchedulerPolicy(preempt="swap", victim="last_joined"))
    active_by_step = []
    free_by_step = []

    def hook(snap):
        active_by_step.append(
            {info["rid"] for info in snap["slots"].values()})
        free_by_step.append(len(snap["free_pages"]))

    results, stats = eng.run(params, reqs, trace_hook=hook)
    assert stats["preempt_swap"] >= 1
    b_steps = [i for i, act in enumerate(active_by_step) if 1 in act]
    c_steps = [i for i, act in enumerate(active_by_step) if 2 in act]
    gaps = [i for i in range(b_steps[0], b_steps[-1] + 1)
            if i not in b_steps]
    assert gaps, "B was never preempted mid-run"
    # during B's preemption gap there were free pages C could have used;
    # strict resume-before-admit kept C queued anyway
    assert any(free_by_step[i] >= 1 for i in gaps)
    assert all(i not in c_steps for i in gaps), \
        "admission jumped past the resume queue"
    from repro.launch.serve import DecodeEngine
    contiguous = DecodeEngine(model, _cc())
    for rid, req in enumerate(reqs):
        toks, _ = contiguous.generate(
            params, {"tokens": jnp.asarray(req.tokens)[None]}, req.gen,
            warmup=False)
        np.testing.assert_array_equal(results[rid], np.asarray(toks)[0])


# ----------------------------------------------------------------------
# shared-prefix traces: refcount invariants + on/off token equality
# ----------------------------------------------------------------------

def _make_shared_trace(seed: int, vocab: int):
    """Shared-system-prompt trace: every request opens with the same
    8-token preamble (2 pages at PS=4), four carry distinct ragged tails
    and two are exact duplicates of the first (full-prompt matches — with
    seg < PS their tail boundary lands mid-page, driving copy-on-write).
    Arrivals are staggered so later requests admit while donors are still
    resident."""
    rng = np.random.default_rng(seed)
    from repro.launch.serve import Request
    preamble = rng.integers(0, vocab, (8,))
    reqs = []
    for i in range(4):
        # request 0's length is a whole number of pages (8 + 4 = 12) so
        # its FULL prompt gets indexed and the duplicates match it
        # end-to-end (the partial-match path is covered by requests 1-3,
        # whose registered prefix is capped at the whole-quantum floor)
        tail = rng.integers(0, vocab,
                            (4 if i == 0 else int(rng.integers(1, 5)),))
        g = int(rng.integers(6, 11))
        reqs.append(Request(np.concatenate([preamble, tail]), g,
                            arrive_at=3 * i))
    for i, a in enumerate((2, 7)):              # duplicates of request 0
        reqs.append(Request(reqs[0].tokens.copy(),
                            int(rng.integers(6, 11)), arrive_at=a))
    return reqs


def _run_shared_trace(tiny_lm, codec, n_pages, policy_mode, prefix,
                      hook=None):
    from repro.launch.serve import ContinuousBatchingEngine, SchedulerPolicy
    model, params = tiny_lm
    reqs = _make_shared_trace(seed=7, vocab=model.cfg.vocab_size)
    eng = ContinuousBatchingEngine(
        model, _cc(codec), page_size=PS, n_pages=n_pages, max_active=3,
        max_seq_len=24,
        policy=SchedulerPolicy(preempt=policy_mode, victim="last_joined"),
        prefill="chunked", chunk_size=16, chunk_align=4, chunk_seg=2,
        prefix_cache=prefix)
    return eng.run(params, reqs, trace_hook=hook)


_INT8 = SparqConfig(enabled=False, signed=True)


@pytest.fixture(scope="module")
def shared_trace_reference(tiny_lm):
    """Prefix-cache-OFF tokens per codec, generous pool. By PR 5's
    scheduling invariance these are THE tokens for (prompt, seg) — every
    pool size and policy must reproduce them exactly, shared pages or
    not."""
    return {name: _run_shared_trace(tiny_lm, codec, 24, "requeue",
                                    prefix=False)[0]
            for name, codec in (("5opt", None), ("int8", _INT8))}


@pytest.mark.parametrize("n_pages,policy_mode,codec_name", [
    (24, "requeue", "5opt"),
    (8, "requeue", "5opt"),
    (8, "swap", "int8"),
    (7, "swap", "5opt"),
    (7, "requeue", "int8"),
], ids=["pool24-requeue-5opt", "pool8-requeue-5opt", "pool8-swap-int8",
        "pool7-swap-5opt", "pool7-requeue-int8"])
def test_shared_prefix_trace_exact_and_conserving(
        tiny_lm, shared_trace_reference, n_pages, policy_mode, codec_name):
    """Shared-prefix serving under preemption: per-step refcount
    conservation (block-table references == page refcounts, preemption
    never frees a page another sequence references, shared pages are
    write-never) and greedy tokens bit-identical to the prefix-cache-OFF
    reference."""
    codec = None if codec_name == "5opt" else _INT8
    check = InvariantChecker(ps=PS)
    results, stats = _run_shared_trace(tiny_lm, codec, n_pages,
                                       policy_mode, prefix=True,
                                       hook=check)
    assert check.steps == stats["decode_steps"] > 0
    assert stats["prefix_hits"] >= 1, "trace produced no prefix hits"
    assert stats["prefix_shared_pages"] >= 1
    if n_pages >= 24:
        # generous pool: donors stay resident, so every later request
        # hits, and the duplicates' full-prompt matches resume mid-page
        assert stats["prefix_misses"] <= 1
        assert stats["cow_copies"] >= 1
        assert stats["preemptions"] == 0
    else:
        assert stats["preemptions"] > 0, \
            "trace did not stress the pool — tighten it"
    if policy_mode == "swap" and stats["preempt_swap"] > 0:
        assert stats["swap_bytes_out"] == stats["swap_bytes_in"] > 0
    ref = shared_trace_reference[codec_name]
    for rid in ref:
        np.testing.assert_array_equal(results[rid], ref[rid])


def test_swap_refuses_shared_pages(tiny_lm):
    """A victim holding shared pages may not park them in the SwapStore
    (the other holders keep them live in the pool); under the swap policy
    such victims requeue instead, counted by swap_refusals, and the other
    sequences' shared pages survive the preemption (checked per-step by
    the refcount invariants)."""
    check = InvariantChecker(ps=PS)
    results, stats = _run_shared_trace(tiny_lm, None, 7, "swap",
                                       prefix=True, hook=check)
    assert stats["preemptions"] > 0
    assert stats["swap_refusals"] >= 1, \
        "no victim held shared pages — the refusal path went untested"
    # every refused swap took the requeue path instead
    assert stats["preempt_requeue"] >= stats["swap_refusals"]
    ref, _ = _run_shared_trace(tiny_lm, None, 24, "requeue", prefix=False)
    for rid in ref:
        np.testing.assert_array_equal(results[rid], ref[rid])


# ----------------------------------------------------------------------
# regression: failed decode-time allocation must not strand pages
# ----------------------------------------------------------------------

def test_failed_step_allocation_releases_pages(tiny_lm):
    """Without a policy, concurrent decode growth can exhaust the pool;
    the raised PoolExhausted must leave the allocator conserving every
    page (a partially-allocated step may not leak pages off the free
    list): free ⊎ slot-owned == the whole pool."""
    from repro.launch.serve import ContinuousBatchingEngine, Request
    model, params = tiny_lm
    rng = np.random.default_rng(2)
    reqs = [Request(rng.integers(0, model.cfg.vocab_size, (8,)), 18)
            for _ in range(2)]
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=8, n_pages=4, max_active=2, max_seq_len=32)
    with pytest.raises(PoolExhausted, match="exhausted"):
        eng.run(params, reqs)
    allocator = eng._debug_state["allocator"]
    slots = eng._debug_state["slots"]
    owned = [p for st_ in slots if st_ is not None for p in st_.pages]
    assert len(owned) == len(set(owned))
    assert sorted(owned + list(allocator.free_pages)) == list(range(4)), \
        "pages leaked by the failed step allocation"
    allocator.assert_consistent()


# ----------------------------------------------------------------------
# regression: idle fast-forward vs interleaved mid-run arrivals
# ----------------------------------------------------------------------

def test_idle_fastforward_admits_interleaved_arrivals(tiny_lm):
    """Mid-run submissions interleave with the initial arrival schedule:
    when every slot drains, the clock must fast-forward to the EARLIEST
    pending arrival (the heap head), not the head of the initial queue
    — the old list-based fast-forward jumped straight to the
    initially-scheduled arrival, admitting it ahead of a mid-run
    submission with an earlier arrival time and silently stretching the
    earlier request's queueing delay past the later one's."""
    from repro.launch.serve import (ContinuousBatchingEngine, Request,
                                    SchedulerPolicy)
    model, params = tiny_lm
    rng = np.random.default_rng(13)
    vocab = model.cfg.vocab_size
    prompts = [rng.integers(0, vocab, (4,)) for _ in range(3)]
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=12, max_active=1,
        max_seq_len=24,
        policy=SchedulerPolicy(preempt="requeue", victim="last_joined"))
    oracle = {}
    for i, p in enumerate(prompts):
        out, _ = eng.run(params, [Request(p, 5)])
        oracle[i] = out[0]

    # rid 0 decodes steps 0-4; rid 1 is scheduled for step 50 up front;
    # rid 2 is submitted DURING the run for step 10. The idle window
    # after rid 0 spans both pending arrivals — admission order must be
    # 0, 2, 1 and the step clock must stop at 10 on the way to 50.
    reqs = [Request(prompts[0], 5, arrive_at=0),
            Request(prompts[1], 5, arrive_at=50)]
    first_seen = {}
    state = {"submitted": False, "step": 0}

    def hook(snap):
        if not state["submitted"]:
            state["submitted"] = True
            rid = eng.submit(Request(prompts[2], 5), at=10)
            assert rid == 2, "mid-run rids continue the initial numbering"
        for info in snap["slots"].values():
            first_seen.setdefault(info["rid"], state["step"])
        state["step"] += 1

    results, stats = eng.run(params, reqs, trace_hook=hook)
    assert set(first_seen) == {0, 1, 2}
    assert first_seen[0] < first_seen[2] < first_seen[1], \
        f"admission order violated arrival order: {first_seen}"
    for rid in range(3):
        np.testing.assert_array_equal(results[rid], oracle[rid])
