"""End-to-end reproduction checks of the paper's claims on the trained
mini-CNN (DESIGN.md §7 tier 3). Statistical note: the synthetic eval gives
~±0.5% noise per config, so assertions use tolerant margins; the *strict*
orderings are proven noise-free at the SQNR level (test_quantizer.py) and
bit level (test_bsparq.py). First pytest run trains the CNNs (~2 min),
later runs hit the benchmark cache."""
import numpy as np
import pytest

from benchmarks import common, tables
from repro.core.sparq import SparqConfig

MARGIN = 0.012  # paired-eval noise allowance


@pytest.fixture(scope="module")
def model():
    return common.train_cnn()


@pytest.fixture(scope="module")
def scales(model):
    return common.calibrate_cnn(model)


@pytest.fixture(scope="module")
def fp32(model, scales):
    return common.cnn_accuracy(model)


def _acc(model, scales, cfg, stc=False):
    return common.cnn_accuracy(model, common.quant_ctx(scales, cfg, stc=stc))


def _logit_err(model, scales, cfg, n=512):
    """Mean relative logit perturbation of a quant config vs FP32 — the
    model-level degradation measure that stays informative when the
    (BN-recalibrated) substrate saturates the synthetic task's accuracy."""
    import jax.numpy as jnp
    from repro.models import cnn as cnn_mod
    mcfg, params = model["cfg"], model["params"]
    ctx = common.quant_ctx(scales, cfg)
    errs = []
    for b in common.eval_batches(mcfg, n=n, batch=256):
        lf, _ = cnn_mod.forward(params, b["image"], mcfg, train=False)
        lq, _ = cnn_mod.forward(params, b["image"], mcfg, ctx=ctx,
                                train=False)
        errs.append(float(jnp.abs(lq - lf).mean() /
                          (jnp.abs(lf).mean() + 1e-9)))
    return float(np.mean(errs))


class TestTable1:
    def test_model_trained(self, fp32):
        assert fp32 > 0.85  # far above 1/8 chance

    def test_a8w8_negligible(self, model, scales, fp32):
        """Paper: INT8 mapping yields negligible degradation."""
        assert _acc(model, scales, SparqConfig(enabled=False)) > fp32 - 0.01

    def test_a8w4_noticeable(self, model, scales, fp32):
        """Paper: below 8 bits (naive) degradation becomes noticeable.
        With BN recalibration the mini task saturates (every config sits at
        ~100% accuracy), so the claim is asserted on logits: naive A8W4
        perturbs them several times more than A8W8, and SPARQ-4bit stays
        well below naive A8W4 (the Table 1 vs Table 2 contrast)."""
        e_w8 = _logit_err(model, scales, SparqConfig(enabled=False))
        e_w4 = _logit_err(model, scales,
                          SparqConfig(enabled=False, weight_bits=4))
        assert e_w4 > 4 * e_w8          # measured ~12x
        e_sparq = _logit_err(model, scales, SparqConfig.opt5())
        assert e_sparq < e_w4           # SPARQ 4-bit beats naive W4
        # accuracy itself must not collapse under naive W4 on this task
        a8w4 = _acc(model, scales, SparqConfig(enabled=False, weight_bits=4))
        assert a8w4 > 0.85


class TestTable2:
    def test_sparq_4bit_minor_degradation(self, model, scales, fp32):
        """Headline claim: SPARQ 4-bit ~= 8-bit accuracy."""
        for cfg in (SparqConfig.opt5(), SparqConfig.opt3()):
            assert _acc(model, scales, cfg) > fp32 - 0.025

    def test_trim_deltas_bounded(self, model, scales, fp32):
        """Model-level note (EXPERIMENTS.md §Reproduction): on this noisy
        synthetic task, trim's downward bias acts as activation shrinkage
        and can IMPROVE accuracy (deltas here are small positive) — the
        paper's strict 5opt>=3opt>=2opt error ordering is therefore
        asserted at the SQNR/bit level (test_quantizer/test_bsparq), and
        at model level we assert boundedness."""
        for opts in (5, 3, 2):
            a = _acc(model, scales, SparqConfig(bits=4, opts=opts,
                                                rounding=False))
            assert abs(a - fp32) < 0.08


class TestTable4:
    def test_low_bits_degrade_more(self, model, scales, fp32):
        """2-bit hurts more than 4-bit (Table 2 vs Table 4 pattern)."""
        a4 = _acc(model, scales, SparqConfig.opt5())
        a2 = _acc(model, scales, SparqConfig.opt7())
        assert a4 >= a2 - MARGIN
        assert a2 > 0.5  # still far above chance — vSPARQ rescues 2-bit

    def test_vsparq_helps_at_2bit(self, model, scales):
        """Paper §5.1: vSPARQ impact grows as bits shrink."""
        w = _acc(model, scales, SparqConfig.opt7(vsparq=True))
        wo = _acc(model, scales, SparqConfig.opt7(vsparq=False))
        assert w >= wo - MARGIN


class TestTable6:
    @pytest.fixture(scope="class")
    def pruned(self):
        return common.train_cnn(tag="cnn_2_4", prune_2_4=True)

    def test_pruned_model_works(self, pruned):
        from repro.core.pruning import sparsity
        acc = common.cnn_accuracy(pruned)
        assert acc > 0.8
        w = pruned["params"]["stages"][0][0]["w1"]
        assert abs(sparsity(w.reshape(-1, w.shape[-1])) - 0.5) < 1e-6

    def test_stc_sparq_minor_degradation(self, pruned):
        scales = common.calibrate_cnn(pruned)
        fp32 = common.cnn_accuracy(pruned, n=256)
        acc = common.cnn_accuracy(
            pruned, common.quant_ctx(scales, SparqConfig.opt5(), stc=True),
            n=256)
        assert acc > fp32 - 0.03


class TestBitStats:
    def test_activation_sparsity_supports_vsparq(self, model):
        """Paper premise: post-ReLU activations have high zero rates."""
        rows = {r[0]: r[2] for r in tables.bit_stats(model)}
        assert rows["zero_fraction"] > 0.3
        # bell-shape: higher bits toggle less often
        assert rows["bit7_toggle_nonzero"] < rows["bit5_toggle_nonzero"]
