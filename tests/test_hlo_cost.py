"""Validate the while-aware HLO cost analyzer on hand-computable graphs."""
import jax
import jax.numpy as jnp
import pytest

from benchmarks.hlo_cost import HloCost, analyze


def test_plain_matmul():
    f = jax.jit(lambda a, b: a @ b)
    c = f.lower(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((128, 32), jnp.float32)).compile()
    got = analyze(c)["flops"]
    assert got == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_by_trip_count():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out.sum()
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    got = analyze(c)["flops"]
    assert got == pytest.approx(10 * 2 * 128 ** 3, rel=0.05)
    # and the built-in undercounts (sanity that the fix matters)
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per program
        ca = ca[0]
    builtin = ca.get("flops", 0)
    assert builtin < got / 5


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out.sum()
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    got = analyze(c)["flops"]
    assert got == pytest.approx(12 * 2 * 64 ** 3, rel=0.05)


def test_einsum_contraction_dims():
    f = jax.jit(lambda a, b: jnp.einsum("bik,bkj->bij", a, b))
    c = f.lower(jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)).compile()
    got = analyze(c)["flops"]
    assert got == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)
