"""Seeded host-discipline violations — one per HL check ID.

Linted AST-only by tests/test_analysis.py (never imported/executed);
each construct below fires its check exactly once and nothing else.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.paging import PageAllocator, PoolExhausted

__analysis__ = {
    "traced": ("FakeEngine._step_fn",),
    "host_loop": ("FakeEngine.run",),
    "device_returning": (),
    "device_params": (),
    "host_objects": (),
}


class FakeEngine:
    def __init__(self):
        self.allocator = PageAllocator(4)
        self._step = jax.jit(self._step_fn)

    def _step_fn(self, tok):
        self.allocator.release([0])             # HL203: traced mutation
        if tok.shape[0] == 0:
            raise PoolExhausted("dry inside the trace")     # HL204
        return tok + 1

    def run(self, tok):
        out = []
        while len(out) < 4:
            tok = self._step(tok)
            z = jnp.sum(tok)                    # HL201: loop device math
            out.append(int(np.asarray(tok[0])))  # HL202: implicit sync
        return out, z


def _double(x):
    return x * 2


fast_double = jax.jit(_double)                  # HL205: undeclared target
