"""Seeded jaxpr-auditor violations — one program per JX check ID.

Each function below, registered as a ProgramSpec by tests/test_analysis.py,
trips exactly one check and nothing else; the test asserts the exact
finding multiset so a dead check (or a check firing twice) is loud.
These are traced abstractly only — never executed.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sink(x):  # pragma: no cover - host side of the seeded callback
    del x


def hostcall(x):
    """JX101: a host callback inside a hot program."""
    jax.debug.callback(_sink, x)
    return x + 1


def packed_cast(codes):
    """JX102: packed int8 codes decoded to float outside any kernel.

    `codes` is an int8 plane; the astype is the stray full-plane
    materialization the packed format forbids on the hot path."""
    return codes.astype(jnp.float32) * 0.5


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def tile_misdivide(x):
    """JX103: the input block (32, 16) does not divide x's (48, 16)."""
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct((64, 16), x.dtype),
        grid=(2,),
        in_specs=[pl.BlockSpec((32, 16), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((32, 16), lambda i: (i, 0)),
        interpret=True)(x)


def _decode_kernel(p_ref, o_ref):
    # float conversion *inside* the kernel: legal (not JX102)
    o_ref[...] = p_ref[...].astype(jnp.float32)


def page_tile_mismatch(planes):
    """JX104: int8 plane tiled at 8 rows/page in a program whose spec
    declares page_size=16 — the paged read no longer aligns to pages."""
    return pl.pallas_call(
        _decode_kernel,
        out_shape=jax.ShapeDtypeStruct(planes.shape, jnp.float32),
        grid=(4, 2),
        in_specs=[pl.BlockSpec((1, 8, 2, 8), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 8, 2, 8), lambda i, j: (i, j, 0, 0)),
        interpret=True)(planes)


def vmem_hog(x):
    """JX105 (under a small test budget): whole-array blocks."""
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec(x.shape, lambda i: (0, 0)),
        interpret=True)(x)


def shape_polymorphic(x):
    """JX106 when registered with a two-length shape set: one jit
    signature per length, i.e. the per-shape retrace JX106 forbids."""
    return x * 2


def shard_map_hostcall(x):
    """JX101 again, but buried inside a shard_map body: the auditor must
    walk through the shard_map eqn's inner jaxpr (the tensor-parallel
    decode/prefill programs all trace through one), not just pjit cores.
    A 1-device mesh keeps the fixture traceable on any host."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("model",))

    def body(v):
        jax.debug.callback(_sink, v)
        return v + 1

    return shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)
