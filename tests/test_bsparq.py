"""Bit-exact tests of bSPARQ against the paper's worked examples (§3.1).

Property-based tests need `hypothesis`; when it is absent they are skipped
(the worked examples and the exhaustive uint8 smoke sweeps below still run,
so the module always tests something)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    HAVE_HYPOTHESIS = False

from repro.core.bsparq import bsparq_encode, bsparq_recon, bsparq_recon_signed, shifts_for
from repro.core.bitops import msb_pos, select_shift


def enc(x, n, opts, rounding=False):
    q, s = bsparq_encode(jnp.asarray([x]), n, shifts_for(n, opts), rounding)
    return int(q[0]), int(s[0])


def recon(x, n, opts, rounding=False):
    return int(bsparq_recon(jnp.asarray([x]), n, shifts_for(n, opts), rounding)[0])


class TestPaperExamples:
    """Every worked example in §3.1 of the paper."""

    def test_27_5opt_window(self):
        # 00011011b = 27: 5opt places the window at bits [4:1] -> 1101b,
        # shift 1, approximated value 26.
        q, s = enc(27, 4, 5)
        assert (q, s) == (0b1101, 1)
        assert recon(27, 4, 5) == 26

    def test_27_3opt_window(self):
        # 3opt chooses bits [5:2] -> 000110b window value 6, shift 2 -> 24.
        q, s = enc(27, 4, 3)
        assert (q, s) == (0b0110, 2)
        assert recon(27, 4, 3) == 24

    def test_27_2opt_window(self):
        # 2opt chooses bits [7:4] -> 0001b, shift 4 -> 16.
        q, s = enc(27, 4, 2)
        assert (q, s) == (0b0001, 4)
        assert recon(27, 4, 2) == 16

    def test_33_5opt_region(self):
        # §3.1: 33 = 00100001b maps to the region scaled by 2^2 in 5opt.
        q, s = enc(33, 4, 5)
        assert s == 2
        assert q == 0b1000
        assert recon(33, 4, 5) == 32

    def test_shift_sets(self):
        assert shifts_for(4, 5) == (0, 1, 2, 3, 4)
        assert shifts_for(4, 3) == (0, 2, 4)
        assert shifts_for(4, 2) == (0, 4)
        assert shifts_for(3, 6) == (0, 1, 2, 3, 4, 5)
        assert shifts_for(2, 7) == (0, 1, 2, 3, 4, 5, 6)


class TestRounding:
    def test_rounding_27_5opt(self):
        # residual LSB below window [4:1] is bit0=1 -> rounds 13 to 14 -> 28.
        assert recon(27, 4, 5, rounding=True) == 28

    def test_rounding_carry_reencode(self):
        # 31 = 00011111b, 5opt window [4:1]=15, round bit 1 -> carry to 16,
        # re-encoded exactly as 32 (single bit at position 5).
        assert recon(31, 4, 5, rounding=True) == 32

    def test_rounding_saturation(self):
        # 255 -> round(255/16)=16 overflows the top window; saturates at 240.
        assert recon(255, 4, 5, rounding=True) == 240

    def test_zero(self):
        for opts, n in [(5, 4), (3, 4), (2, 4), (6, 3), (7, 2)]:
            assert recon(0, n, opts) == 0
            assert recon(0, n, opts, rounding=True) == 0


class TestExhaustiveSmoke:
    """Deterministic sweeps over the full uint8 domain — the non-hypothesis
    versions of the properties below (all 256 inputs, no sampling)."""
    ALL = np.arange(256)

    def test_small_values_exact_under_trim(self):
        x = jnp.asarray(self.ALL)
        for n, opts in [(4, 5), (4, 3), (4, 2), (3, 6), (2, 7)]:
            r = np.asarray(bsparq_recon(x, n, shifts_for(n, opts), False))
            small = self.ALL < (1 << n)
            np.testing.assert_array_equal(r[small], self.ALL[small])

    def test_trim_underestimates_and_opts_monotone(self):
        errs = {}
        for opts in (5, 3, 2):
            r = np.asarray(bsparq_recon(jnp.asarray(self.ALL), 4,
                                        shifts_for(4, opts), False))
            assert (r <= self.ALL).all() and (r >= 0).all()
            errs[opts] = np.abs(self.ALL - r)
        assert (errs[5] <= errs[3]).all()
        assert (errs[3] <= errs[2]).all()

    def test_rounding_mse_not_worse_exhaustive(self):
        x = self.ALL.astype(np.int64)
        for n, opts in [(4, 5), (4, 3), (4, 2)]:
            sh = shifts_for(n, opts)
            rt = np.asarray(bsparq_recon(jnp.asarray(x), n, sh, False),
                            dtype=np.int64)
            rr = np.asarray(bsparq_recon(jnp.asarray(x), n, sh, True),
                            dtype=np.int64)
            assert ((x - rr) ** 2).sum() <= ((x - rt) ** 2).sum()

    def test_signed_is_odd_function_exhaustive(self):
        x = jnp.asarray(np.arange(-127, 128))
        for n, opts in [(4, 5), (4, 3)]:
            sh = shifts_for(n, opts)
            r_pos = np.asarray(bsparq_recon_signed(x, n, sh, True))
            r_neg = np.asarray(bsparq_recon_signed(-x, n, sh, True))
            np.testing.assert_array_equal(r_pos, -r_neg)


if HAVE_HYPOTHESIS:
    @st.composite
    def uint8s(draw):
        return draw(st.integers(min_value=0, max_value=255))

    class TestProperties:
        @given(st.lists(uint8s(), min_size=1, max_size=64))
        @settings(max_examples=200, deadline=None)
        def test_window_covers_msb_exact_small_values(self, xs):
            """Values below 2**n are always exact under trim (window [n-1:0])."""
            x = jnp.asarray(xs)
            for n, opts in [(4, 5), (4, 3), (4, 2), (3, 6), (2, 7)]:
                r = np.asarray(bsparq_recon(x, n, shifts_for(n, opts), False))
                small = np.asarray(x) < (1 << n)
                np.testing.assert_array_equal(r[small], np.asarray(x)[small])

        @given(st.lists(uint8s(), min_size=1, max_size=64))
        @settings(max_examples=200, deadline=None)
        def test_more_opts_never_worse(self, xs):
            """Trim error is monotone in placement options: 5opt <= 3opt <= 2opt."""
            x = np.asarray(xs)
            errs = {}
            for opts in (5, 3, 2):
                r = np.asarray(bsparq_recon(jnp.asarray(x), 4, shifts_for(4, opts), False))
                errs[opts] = np.abs(x - r)
            assert (errs[5] <= errs[3]).all()
            assert (errs[3] <= errs[2]).all()

        @given(st.lists(uint8s(), min_size=1, max_size=64))
        @settings(max_examples=200, deadline=None)
        def test_trim_underestimates(self, xs):
            """Trim (no rounding) never overshoots: recon <= x, error < 2**shift_max."""
            x = np.asarray(xs)
            for n, opts in [(4, 5), (4, 3), (4, 2), (3, 6), (2, 7)]:
                r = np.asarray(bsparq_recon(jnp.asarray(x), n, shifts_for(n, opts), False))
                assert (r <= x).all()
                assert (r >= 0).all()

        @given(st.lists(uint8s(), min_size=4, max_size=256))
        @settings(max_examples=100, deadline=None)
        def test_rounding_mse_not_worse(self, xs):
            """+R never increases total squared error (per-value it rounds to
            nearest within the same window, carries re-encode exactly)."""
            x = np.asarray(xs, dtype=np.int64)
            for n, opts in [(4, 5), (4, 3), (4, 2)]:
                sh = shifts_for(n, opts)
                rt = np.asarray(bsparq_recon(jnp.asarray(x), n, sh, False), dtype=np.int64)
                rr = np.asarray(bsparq_recon(jnp.asarray(x), n, sh, True), dtype=np.int64)
                assert ((x - rr) ** 2).sum() <= ((x - rt) ** 2).sum()

        @given(st.lists(st.integers(min_value=-127, max_value=127), min_size=1,
                        max_size=64))
        @settings(max_examples=100, deadline=None)
        def test_signed_is_odd_function(self, xs):
            x = jnp.asarray(xs)
            for n, opts in [(4, 5), (4, 3)]:
                sh = shifts_for(n, opts)
                r_pos = np.asarray(bsparq_recon_signed(x, n, sh, True))
                r_neg = np.asarray(bsparq_recon_signed(-x, n, sh, True))
                np.testing.assert_array_equal(r_pos, -r_neg)


class TestBitops:
    def test_msb(self):
        xs = jnp.asarray([0, 1, 2, 3, 4, 7, 8, 27, 128, 255])
        np.testing.assert_array_equal(
            np.asarray(msb_pos(xs)), [0, 0, 1, 1, 2, 2, 3, 4, 7, 7])

    def test_select_shift_5opt(self):
        m = jnp.asarray([0, 3, 4, 5, 6, 7])
        np.testing.assert_array_equal(
            np.asarray(select_shift(m, 4, (0, 1, 2, 3, 4))), [0, 0, 1, 2, 3, 4])

    def test_select_shift_3opt(self):
        m = jnp.asarray([0, 3, 4, 5, 6, 7])
        np.testing.assert_array_equal(
            np.asarray(select_shift(m, 4, (0, 2, 4))), [0, 0, 2, 2, 4, 4])
