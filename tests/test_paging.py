"""Paged SPARQ KV-cache + continuous batching.

Covers: bit-identity of the block-table gather kernel against the
contiguous fused kernel (ref and pallas-interpret, full/partial block
tables, windowed = ring-style masking), PagedCacheStore write semantics
(page/offset addressing, per-slot scale freeze, trash-page isolation),
allocator edge cases (exhaustion raises host-side before tracing, the
used-set refcount guard, watermarks, page reuse after eviction is
bit-exact), swap round-trip byte identity (preemption's swap-out/swap-in
across the vsparq x signed x window grid), and the end-to-end acceptance:
the continuous-batching engine reproduces the contiguous scan engine's
greedy tokens for ragged requests on both the int8 grid and the 5opt
codec. Scheduler-level preemption traces live in tests/test_scheduler.py.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import QScale
from repro.core.sparq import SparqConfig
from repro.kernels import ops
from repro.models.cache import CacheConfig, CacheStore
from repro.models.paging import (PageAllocator, PagedCacheStore,
                                 PoolExhausted, SwapStore, adopt_prefill,
                                 evict_slot, gather_slot_pages,
                                 modeled_pool_bytes, paged_decode_attention,
                                 restore_slot_pages)

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# kernel level: block-table gather vs contiguous fused decode
# ----------------------------------------------------------------------

def _packed_planes(rng, B, Tk, KV, hd, cfg, scale=0.02):
    x = jnp.asarray(rng.normal(size=(B, Tk, KV, hd)), jnp.float32)
    qs = QScale(scale=jnp.float32(scale), bits=8, signed=True)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    return ops.sparq_pack(codes, meta), meta


def _scatter_pool(rng, kd, km, vd, vm, ps):
    """Move contiguous [B, Tk, ...] planes into a pool with a scrambled
    per-sequence block table. Returns (pools..., block_table)."""
    B, Tk, KV, hd = kd.shape
    NB = Tk // ps
    P = B * NB + 2
    pages = rng.permutation(P)[: B * NB].reshape(B, NB)
    pool = lambda: np.zeros((P, ps, KV, hd), np.int8)
    pk, pkm, pv, pvm = pool(), pool(), pool(), pool()
    for b in range(B):
        for t in range(NB):
            sl = slice(t * ps, (t + 1) * ps)
            pk[pages[b, t]] = np.asarray(kd[b, sl])
            pkm[pages[b, t]] = np.asarray(km[b, sl])
            pv[pages[b, t]] = np.asarray(vd[b, sl])
            pvm[pages[b, t]] = np.asarray(vm[b, sl])
    return (jnp.asarray(pk), jnp.asarray(pkm), jnp.asarray(pv),
            jnp.asarray(pvm), jnp.asarray(pages, jnp.int32))


class TestPagedKernel:
    B, KV, G, hd, ps, NB = 3, 2, 4, 16, 8, 4

    @pytest.fixture(scope="class")
    def planes(self):
        rng = np.random.default_rng(0)
        cfg = SparqConfig.opt5(signed=True)
        Tk = self.NB * self.ps
        kd, km = _packed_planes(rng, self.B, Tk, self.KV, self.hd, cfg)
        vd, vm = _packed_planes(rng, self.B, Tk, self.KV, self.hd, cfg)
        q = jnp.asarray(rng.normal(size=(self.B, 1, self.KV * self.G,
                                         self.hd)), jnp.float32)
        pool = _scatter_pool(rng, kd, km, vd, vm, self.ps)
        return q, (kd, km, vd, vm), pool

    @pytest.mark.parametrize("cur,window", [(19, 0), (31, 0), (19, 12),
                                            (30, 12)])
    @pytest.mark.parametrize("impl", ["reference", "pallas"])
    def test_bit_identical_to_contiguous(self, planes, cur, window, impl):
        """One page == one Tk tile: with page_size == bk the gather path
        reproduces the contiguous fused kernel bit for bit (the windowed
        case is the ring cache's masking arithmetic — ring + paged
        composition at the kernel level)."""
        q, (kd, km, vd, vm), (pk, pkm, pv, pvm, bt) = planes
        Tk = kd.shape[1]
        s = jnp.float32(0.02)
        kpos = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None],
                                (self.B, Tk))
        want = ops.sparq_decode_attention(
            q, kd, km, s, vd, vm, s, kpos, jnp.int32(cur),
            window=window, impl="reference", bk=self.ps)
        sv = jnp.full((self.B,), s)
        got = ops.sparq_paged_decode_attention(
            q, pk, pkm, sv, pv, pvm, sv, bt,
            jnp.full((self.B,), cur, jnp.int32), window=window, impl=impl)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_partial_block_table(self, planes):
        """Blocks past a sequence's length stay unallocated (-1): identical
        to the contiguous path as long as cur never reaches them."""
        q, (kd, km, vd, vm), (pk, pkm, pv, pvm, bt) = planes
        Tk = kd.shape[1]
        s = jnp.float32(0.02)
        cur = 2 * self.ps + 3                   # block 3 never touched
        bt2 = np.asarray(bt).copy()
        bt2[:, 3] = -1
        kpos = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None],
                                (self.B, Tk))
        want = ops.sparq_decode_attention(
            q, kd, km, s, vd, vm, s, kpos, jnp.int32(cur),
            impl="reference", bk=self.ps)
        sv = jnp.full((self.B,), s)
        got = ops.sparq_paged_decode_attention(
            q, pk, pkm, sv, pv, pvm, sv, jnp.asarray(bt2),
            jnp.full((self.B,), cur, jnp.int32), impl="reference")
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_ragged_cur_and_inactive_slots(self, planes):
        """Per-sequence cur: each row masks at its own length; an inactive
        slot (cur < 0) is fully masked and returns zeros."""
        q, (kd, km, vd, vm), (pk, pkm, pv, pvm, bt) = planes
        Tk = kd.shape[1]
        s = jnp.float32(0.02)
        curs = [19, -2, 7]
        sv = jnp.full((self.B,), s)
        got = ops.sparq_paged_decode_attention(
            q, pk, pkm, sv, pv, pvm, sv, bt,
            jnp.asarray(curs, jnp.int32), impl="reference")
        assert np.all(np.asarray(got)[1] == 0.0)
        kpos = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None],
                                (self.B, Tk))
        for b in (0, 2):                        # rows agree with per-row cur
            want = ops.sparq_decode_attention(
                q, kd, km, s, vd, vm, s, kpos, jnp.int32(curs[b]),
                impl="reference", bk=self.ps)
            np.testing.assert_array_equal(np.asarray(want)[b],
                                          np.asarray(got)[b])


# ----------------------------------------------------------------------
# store level: write addressing, scales, adoption
# ----------------------------------------------------------------------

CC5 = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                              impl="reference")


class TestPagedCacheStore:
    def test_update_addresses_page_and_offset(self):
        st = PagedCacheStore.init(n_seqs=2, n_pages=4, page_size=4,
                                  n_blocks=3, kv_heads=2, head_dim=8, cc=CC5)
        st = dataclasses.replace(
            st,
            block_table=jnp.asarray([[2, 0, -1], [1, -1, -1]], jnp.int32),
            seq_pos=jnp.asarray([5, 2], jnp.int32),
            k_scale=jnp.asarray([0.1, 0.1]), v_scale=jnp.asarray([0.1, 0.1]))
        k = jnp.ones((2, 1, 2, 8)) * 0.3
        st2 = st.update(k, k)
        # seq 0: pos 5 -> block 1 (page 0), row 1; seq 1: pos 2 -> page 1
        assert np.any(np.asarray(st2.k_data[0, 1]) != 0)
        assert np.any(np.asarray(st2.k_data[1, 2]) != 0)
        np.testing.assert_array_equal(np.asarray(st2.seq_pos), [6, 3])
        # everything else untouched
        assert not np.any(np.asarray(st2.k_data[3]))

    def test_inactive_slot_writes_trash_page(self):
        st = PagedCacheStore.init(n_seqs=2, n_pages=3, page_size=4,
                                  n_blocks=2, kv_heads=2, head_dim=8, cc=CC5)
        st = dataclasses.replace(
            st, block_table=jnp.asarray([[0, -1], [-1, -1]], jnp.int32),
            seq_pos=jnp.asarray([1, -1], jnp.int32),
            k_scale=jnp.asarray([0.1, 0.0]), v_scale=jnp.asarray([0.1, 0.0]))
        x = jnp.ones((2, 1, 2, 8))
        st2 = st.update(x, x)
        trash = st.n_pages                      # last page index
        assert np.any(np.asarray(st2.k_data[trash]))    # inactive -> trash
        assert np.any(np.asarray(st2.k_data[0, 1]))     # active -> its page
        np.testing.assert_array_equal(np.asarray(st2.seq_pos), [2, -1])
        assert float(st2.k_scale[1]) == 0.0     # inactive scale untouched

    def test_per_slot_scale_freeze(self):
        st = PagedCacheStore.init(n_seqs=2, n_pages=3, page_size=4,
                                  n_blocks=2, kv_heads=2, head_dim=8, cc=CC5)
        st = dataclasses.replace(
            st, block_table=jnp.asarray([[0, -1], [1, -1]], jnp.int32),
            seq_pos=jnp.asarray([0, 0], jnp.int32),
            k_scale=jnp.asarray([0.5, 0.0]))    # slot 0 calibrated
        x = jax.random.normal(KEY, (2, 1, 2, 8))
        st2 = st.update(x, x)
        assert float(st2.k_scale[0]) == 0.5     # frozen
        assert float(st2.k_scale[1]) > 0        # calibrated from this write
        st3 = st2.update(10.0 * x, 10.0 * x)
        assert float(st3.k_scale[1]) == pytest.approx(float(st2.k_scale[1]))

    def test_adopt_prefill_copies_bytes_verbatim(self):
        """Adoption moves the contiguous cache's packed planes into pages
        without requantization: gathered pool bytes == contiguous bytes."""
        ps, nbp, L = 4, 3, 2                    # L = stacked layer count
        cs = CacheStore.init((1, nbp * ps, 2, 8), CC5)
        k = jax.random.normal(KEY, (1, 10, 2, 8))
        cs = cs.update(k, k * 0.5)
        cs_stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), cs)
        one = PagedCacheStore.init(n_seqs=2, n_pages=6, page_size=ps,
                                   n_blocks=4, kv_heads=2, head_dim=8,
                                   cc=CC5)
        st = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), one)
        pages = jnp.asarray([4, 1, 3], jnp.int32)
        st2 = adopt_prefill(st, cs_stacked, jnp.int32(1), pages)
        got = np.asarray(st2.k_data[:, pages]).reshape(L, 1, nbp * ps, 2, 8)
        np.testing.assert_array_equal(got, np.asarray(cs_stacked.k.data))
        np.testing.assert_array_equal(np.asarray(st2.block_table[:, 1, :3]),
                                      np.asarray(pages)[None].repeat(L, 0))
        np.testing.assert_array_equal(np.asarray(st2.seq_pos[:, 1]),
                                      [10] * L)
        np.testing.assert_array_equal(np.asarray(st2.k_scale[:, 1]),
                                      np.asarray(cs_stacked.k.scale))
        # evict clears the slot
        st3 = evict_slot(st2, jnp.int32(1))
        assert np.all(np.asarray(st3.block_table[:, 1]) == -1)
        assert np.all(np.asarray(st3.seq_pos[:, 1]) == -1)
        assert np.all(np.asarray(st3.k_scale[:, 1]) == 0.0)

    def test_modeled_pool_bytes(self):
        st = PagedCacheStore.init(n_seqs=2, n_pages=3, page_size=4,
                                  n_blocks=2, kv_heads=2, head_dim=8, cc=CC5)
        tally = modeled_pool_bytes(st)
        n = 2 * (3 + 1) * 4 * 2 * 8             # k+v pools incl. trash page
        assert tally["values"] == n
        assert tally["data_bytes"] == pytest.approx(n * 0.5625)
        assert tally["ctrl_bytes"] == pytest.approx(n * 0.375)

    def test_fp_layout_rejected(self):
        with pytest.raises(ValueError, match="sparq"):
            PagedCacheStore.init(1, 2, 4, 2, 2, 8, CacheConfig.fp32())


# ----------------------------------------------------------------------
# swap round trip: preemption's swap-out -> swap-in is byte-verbatim
# ----------------------------------------------------------------------

class TestSwapRoundTrip:
    """Packed data/meta/scale planes survive a host swap round trip
    byte-identically, and fused paged decode over resumed pages matches
    the never-preempted oracle — across the vsparq x signed grid and for
    full-attention and windowed (ring-style) masking."""
    L, ps, KV, hd = 2, 4, 2, 8                  # stacked layers, geometry

    def _stacked(self, tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.L,) + a.shape).copy(), tree)

    def _filled_store(self, cfg, n_tok=10, seed=0):
        """Stacked paged store with slot 1 holding an adopted prefill."""
        cc = CacheConfig(layout="sparq", sparq=cfg, impl="reference")
        nbp = 3
        cs = CacheStore.init((1, nbp * self.ps, self.KV, self.hd), cc)
        k = jax.random.normal(jax.random.PRNGKey(seed),
                              (1, n_tok, self.KV, self.hd))
        cs = cs.update(k, k * 0.5)
        st = self._stacked(PagedCacheStore.init(
            n_seqs=2, n_pages=8, page_size=self.ps, n_blocks=4,
            kv_heads=self.KV, head_dim=self.hd, cc=cc))
        pages = jnp.asarray([5, 0, 3], jnp.int32)
        return (adopt_prefill(st, self._stacked(cs), jnp.int32(1), pages),
                pages, cc, n_tok)

    @pytest.mark.parametrize("vsparq", [True, False])
    @pytest.mark.parametrize("signed", [True, False])
    @pytest.mark.parametrize("window", [0, 8])
    def test_bits_and_attention_survive_roundtrip(self, vsparq, signed,
                                                  window):
        cfg = SparqConfig.opt5(signed=signed, vsparq=vsparq)
        st, pages, cc, n_tok = self._filled_store(cfg)
        swap = SwapStore()
        planes = gather_slot_pages(st, jnp.int32(1), pages)
        nbytes = swap.put(7, [planes], pos=n_tok)
        assert nbytes == swap.bytes_out == swap.resident_bytes > 0
        # resume into a *different* slot and different pages of a fresh,
        # partly-dirty pool (restore overwrites every claimed byte)
        fresh = self._stacked(PagedCacheStore.init(
            n_seqs=2, n_pages=8, page_size=self.ps, n_blocks=4,
            kv_heads=self.KV, head_dim=self.hd, cc=cc))
        fresh = dataclasses.replace(
            fresh, k_data=fresh.k_data.at[:].set(111))
        new_pages = jnp.asarray([2, 6, 1], jnp.int32)
        (host_groups,), pos = swap.pop(7)
        assert swap.bytes_in == nbytes and swap.resident_bytes == 0
        restored = restore_slot_pages(
            fresh, {k: jnp.asarray(v) for k, v in host_groups.items()},
            jnp.int32(0), new_pages, jnp.int32(pos))
        # byte identity of every packed plane and the per-layer scales
        back = gather_slot_pages(restored, jnp.int32(0), new_pages)
        for name in ("k_data", "k_meta", "v_data", "v_meta",
                     "k_scale", "v_scale"):
            np.testing.assert_array_equal(np.asarray(back[name]),
                                          np.asarray(planes[name]))
        np.testing.assert_array_equal(np.asarray(restored.seq_pos[:, 0]),
                                      [pos] * self.L)
        # fused paged decode over the resumed slot == never-swapped oracle
        rng = np.random.default_rng(3)
        q = jnp.broadcast_to(                   # same query for both slots
            jnp.asarray(rng.normal(size=(1, 1, self.KV * 2, self.hd)),
                        jnp.float32), (2, 1, self.KV * 2, self.hd))
        for layer in range(self.L):
            take = lambda t, l=layer: jax.tree.map(lambda a: a[l], t)
            want = paged_decode_attention(q, take(st), window=window)
            got = paged_decode_attention(q, take(restored), window=window)
            np.testing.assert_array_equal(np.asarray(want)[1],
                                          np.asarray(got)[0])

    def test_swapstore_rejects_double_put(self):
        cfg = SparqConfig.opt5(signed=True)
        st, pages, _, n_tok = self._filled_store(cfg)
        swap = SwapStore()
        swap.put(1, [gather_slot_pages(st, jnp.int32(1), pages)], n_tok)
        assert 1 in swap and len(swap) == 1
        with pytest.raises(AssertionError, match="already swapped"):
            swap.put(1, [gather_slot_pages(st, jnp.int32(1), pages)], n_tok)
        assert swap.n_pages(1) == 3 and swap.pos(1) == n_tok


# ----------------------------------------------------------------------
# allocator
# ----------------------------------------------------------------------

class TestAllocator:
    def test_alloc_free_reuse(self):
        al = PageAllocator(4)
        a = al.alloc(3)
        assert al.free_count == 1 and al.used_count == 3
        al.free(a[:2])
        b = al.alloc(3)
        assert set(b).isdisjoint({a[2]})
        assert al.free_count == 0

    def test_exhaustion_raises(self):
        al = PageAllocator(2)
        al.alloc(1)
        with pytest.raises(PoolExhausted, match="exhausted"):
            al.alloc(2)
        assert al.free_count == 1               # failed alloc takes nothing

    def test_double_free_asserts(self):
        al = PageAllocator(2)
        pages = al.alloc(1)
        al.free(pages)
        with pytest.raises(AssertionError):
            al.free(pages)

    def test_foreign_free_asserts(self):
        """The used-set refcount guard: freeing a page that was never
        handed out trips immediately (not only a duplicate free)."""
        al = PageAllocator(4)
        al.alloc(2)
        with pytest.raises(AssertionError, match="not allocated"):
            al.free([3])
        al.assert_consistent()

    def test_peak_watermark(self):
        al = PageAllocator(4)
        a = al.alloc(3)
        al.free(a)
        al.alloc(1)
        assert al.peak_used == 3                # high watermark persists
        assert al.free_count == 3 and al.used_count == 1
        assert set(al.free_pages).isdisjoint(al.refcounts)


# ----------------------------------------------------------------------
# engine level: continuous batching end to end
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    return model, params


def _engine(model, cc, **kw):
    from repro.launch.serve import ContinuousBatchingEngine
    kw.setdefault("page_size", 8)
    kw.setdefault("n_pages", 16)
    kw.setdefault("max_active", 2)
    kw.setdefault("max_seq_len", 64)
    return ContinuousBatchingEngine(model, cc, **kw)


def _reqs(model, lens, gens, seed=3):
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(rng.integers(0, model.cfg.vocab_size, (L,)), g)
            for L, g in zip(lens, gens)]


@pytest.mark.parametrize("codec", [SparqConfig(enabled=False, signed=True),
                                   SparqConfig.opt5(signed=True)],
                         ids=["int8", "5opt"])
def test_paged_engine_matches_contiguous_greedy(tiny_lm, codec):
    """Acceptance: ragged continuous batching (queueing, staggered
    completions, multi-page sequences, page reuse) emits exactly the
    greedy tokens of the contiguous scan engine serving each request
    alone — int8 grid and the full 4-bit 5opt codec. attn_bk aligns the
    contiguous kernel's Tk tiles with the page size, so even the f32
    summation order matches (bit-identical logits, not just argmax)."""
    from repro.launch.serve import DecodeEngine
    model, params = tiny_lm
    ps = 8
    cc = dataclasses.replace(
        CacheConfig.sparq_cache(codec, impl="reference"), attn_bk=ps)
    eng = _engine(model, cc, page_size=ps, n_pages=14)
    reqs = _reqs(model, lens=[12, 9, 20, 9], gens=[10, 5, 7, 12])
    results, stats = eng.run(params, reqs)
    assert stats["decode_steps"] > 0
    contiguous = DecodeEngine(model, cc)
    for rid, req in enumerate(reqs):
        toks, _ = contiguous.generate(
            params, {"tokens": jnp.asarray(req.tokens)[None]}, req.gen,
            warmup=False)
        np.testing.assert_array_equal(results[rid], np.asarray(toks)[0])


def test_page_reuse_after_eviction_is_exact(tiny_lm):
    """One slot, a pool just big enough for one sequence: the second
    (identical) request recycles the first one's pages and must produce
    identical tokens — adoption rewrites every byte of a claimed page."""
    model, params = tiny_lm
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                 impl="reference")
    eng = _engine(model, cc, page_size=8, n_pages=4, max_active=1,
                  max_seq_len=32)
    req = _reqs(model, lens=[14], gens=[12])[0]
    results, stats = eng.run(params, [req, req, req])
    assert stats["peak_pages_used"] <= 4
    np.testing.assert_array_equal(results[0], results[1])
    np.testing.assert_array_equal(results[0], results[2])


def test_pool_exhaustion_raises_before_tracing(tiny_lm):
    """Admission or decode growth beyond the pool raises host-side
    (PoolExhausted/ValueError), mirroring the contiguous engine's
    host-side capacity check — never a silent traced clamp."""
    model, params = tiny_lm
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                 impl="reference")
    # request that can never fit the pool: rejected up front
    eng = _engine(model, cc, page_size=8, n_pages=2, max_active=1,
                  max_seq_len=64)
    big = _reqs(model, lens=[40], gens=[2])
    with pytest.raises(ValueError, match="pages"):
        eng.run(params, big)
    # each request alone fits (4 pages of 4 total) but two growing
    # concurrently drain the free list: without a SchedulerPolicy,
    # decode-time allocation raises host-side, before the step is traced
    # (tests/test_scheduler.py covers the preemption path)
    eng2 = _engine(model, cc, page_size=8, n_pages=4, max_active=2,
                   max_seq_len=32)
    from repro.models.paging import PoolExhausted as PE
    with pytest.raises(PE, match="exhausted"):
        eng2.run(params, _reqs(model, lens=[8, 8], gens=[18, 18]))


def test_paged_engine_rejects_unsupported(tiny_lm):
    """fp layouts and non-standard-KV families keep the scan engine."""
    from repro.configs.base import get_reduced_config
    from repro.launch.serve import ContinuousBatchingEngine
    from repro.models.model import Model
    model, _ = tiny_lm
    with pytest.raises(ValueError, match="sparq"):
        _engine(model, CacheConfig.fp32())
    mla = Model(get_reduced_config("deepseek-v2-lite-16b"))
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
    with pytest.raises(ValueError, match="standard-KV"):
        _engine(mla, cc)


def test_ring_and_paged_masking_agree():
    """Ring + paged composition: the sliding-window ring cache (arbitrary
    slot order, kpos = slot_pos) and the paged pool (logical order through
    a block table) express the same attention set; outputs agree to fp
    tolerance (summation order differs with slot order)."""
    rng = np.random.default_rng(5)
    B, KV, G, hd, W, ps = 2, 2, 2, 8, 8, 4
    cfg = SparqConfig.opt5(signed=True)
    Tk = 16                                     # logical positions 0..15
    kd, km = _packed_planes(rng, B, Tk, KV, hd, cfg)
    vd, vm = _packed_planes(rng, B, Tk, KV, hd, cfg)
    q = jnp.asarray(rng.normal(size=(B, 1, KV * G, hd)), jnp.float32)
    s = jnp.float32(0.02)
    cur = 14
    # ring: keep the last W tokens in rotated slots, kpos = absolute pos
    slots = [(p % W) for p in range(cur + 1)]   # position p -> slot p%W
    ring_kd = np.zeros((B, W, KV, hd), np.int8)
    ring_km, ring_vd, ring_vm = (np.zeros_like(ring_kd) for _ in range(3))
    ring_pos = np.full((B, W), -1, np.int32)
    for p in range(cur + 1):
        ring_kd[:, slots[p]] = np.asarray(kd[:, p])
        ring_km[:, slots[p]] = np.asarray(km[:, p])
        ring_vd[:, slots[p]] = np.asarray(vd[:, p])
        ring_vm[:, slots[p]] = np.asarray(vm[:, p])
        ring_pos[:, slots[p]] = p
    want = ops.sparq_decode_attention(
        q, jnp.asarray(ring_kd), jnp.asarray(ring_km), s,
        jnp.asarray(ring_vd), jnp.asarray(ring_vm), s,
        jnp.asarray(ring_pos), jnp.int32(cur), window=W, impl="reference")
    pk, pkm, pv, pvm, bt = _scatter_pool(rng, kd, km, vd, vm, ps)
    sv = jnp.full((B,), s)
    got = ops.sparq_paged_decode_attention(
        q, pk, pkm, sv, pv, pvm, sv, bt,
        jnp.full((B,), cur, jnp.int32), window=W, impl="reference")
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-6)


def test_stats_shape(tiny_lm):
    model, params = tiny_lm
    cc = CacheConfig.sparq_cache(SparqConfig(enabled=False, signed=True),
                                 impl="reference")
    eng = _engine(model, cc)
    results, stats = eng.run(params, _reqs(model, lens=[9], gens=[4]))
    assert results[0].shape == (4,)
    for key in ("decode_tok_s", "pool_slots", "peak_pages_used",
                "peak_pool_utilization", "cache_total_bytes"):
        assert key in stats
    assert stats["pool_slots"] == 16 * 8
