"""Shared-prefix page reuse: the radix prefix index and the refcounted
allocator, unit-tested in isolation, plus the engine-level configuration
contract (prefix cache requires the chunked prefill path).

The index invariants under test mirror how the engine uses it:
longest-match correctness, whole-quantum granularity (only fully-written
pages are shareable, so partial trailing segments never index), first
donor wins on concurrent registration, invalidation on release-to-zero
(a dead page kills its node and the node's whole subtree — deeper
prefixes contain the dead pages), and hash-collision safety (the rolling
segment hash only buckets; exact token comparison decides). A
hypothesis-optional property test checks the radix structure against a
naive dictionary model over random insert/match/invalidate
interleavings, same convention as the allocator interleaving test in
test_scheduler.

Engine-level shared-prefix behavior (refcount conservation under
preemption, on/off token equality, copy-on-write, swap refusal) lives in
test_scheduler's randomized-trace harness; the end-to-end throughput
claim in benchmarks/run.py shared_prefix.
"""
import numpy as np
import pytest

from repro.models import paging
from repro.models.paging import PageAllocator, PoolExhausted, PrefixIndex

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------------
# refcounted allocator: share / release semantics
# ----------------------------------------------------------------------

class TestRefcounts:
    def test_share_release_lifecycle(self):
        al = PageAllocator(6)
        pages = al.alloc(3)
        al.share(pages[:2])                     # second holder
        assert [al.refcount(p) for p in pages] == [2, 2, 1]
        assert al.shared_count == 2 and al.total_refs == 5
        # first holder leaves: shared pages survive, exclusive one frees
        freed = al.release(pages)
        assert freed == [pages[2]]
        assert al.free_count == 4
        # second holder leaves: now they free
        assert sorted(al.release(pages[:2])) == sorted(pages[:2])
        assert al.free_count == 6 and al.used_count == 0
        al.assert_consistent()

    def test_share_unallocated_asserts(self):
        al = PageAllocator(4)
        with pytest.raises(AssertionError, match="not allocated"):
            al.share([2])

    def test_free_of_shared_page_asserts(self):
        """`free` keeps the strict exclusive-ownership contract: shared
        pages must go through `release`."""
        al = PageAllocator(4)
        pages = al.alloc(2)
        al.share(pages)
        with pytest.raises(AssertionError, match="use release"):
            al.free(pages)

    def test_release_to_zero_reports_freed_pages(self):
        al = PageAllocator(4)
        (a,) = al.alloc(1)
        (b,) = al.alloc(1)
        al.share([a])
        assert al.release([a, b]) == [b]        # a still held
        assert al.release([a]) == [a]

    def test_exhaustion_message_reports_sharing(self):
        """The PoolExhausted message distinguishes resident from shared
        pages so oversubscription failures under sharing are
        diagnosable: requested vs free vs shared-resident counts."""
        al = PageAllocator(4)
        pages = al.alloc(3)
        al.share(pages[:2])
        with pytest.raises(PoolExhausted,
                           match=r"need 2 page\(s\), 1 of 4 free "
                                 r"\(3 resident, of which 2 shared "
                                 r"across 5 references\)"):
            al.alloc(2)
        # atomic: the failing alloc took nothing
        assert al.free_count == 1

    def test_shared_alloc_conservation_sweep(self):
        """Seeded interleavings of alloc/share/release conserve the pool:
        free + distinct-held == n_pages and refcount == holder count."""
        rng = np.random.default_rng(3)
        al = PageAllocator(8)
        holders: list = []                      # list of page lists
        for _ in range(200):
            op = rng.integers(0, 3)
            if op == 0 and al.free_count:
                n = int(rng.integers(1, al.free_count + 1))
                holders.append(al.alloc(n))
            elif op == 1 and holders:
                src = holders[rng.integers(len(holders))]
                al.share(src)
                holders.append(list(src))
            elif op == 2 and holders:
                al.release(holders.pop(rng.integers(len(holders))))
            counts: dict = {}
            for hl in holders:
                for p in hl:
                    counts[p] = counts.get(p, 0) + 1
            assert counts == al.refcounts
            assert al.free_count + len(counts) == 8
            al.assert_consistent()


# ----------------------------------------------------------------------
# radix prefix index
# ----------------------------------------------------------------------

def _toks(*vals):
    return np.asarray(vals, np.int64)


class TestPrefixIndex:
    def test_longest_match(self):
        ix = PrefixIndex(quantum=4, page_size=4)
        ix.insert(_toks(*range(12)), [10, 11, 12], scales="A")
        # full, partial, and divergent queries
        n, pages, sc = ix.match(_toks(*range(12)))
        assert (n, pages, sc) == (12, [10, 11, 12], "A")
        n, pages, _ = ix.match(_toks(*range(8), 99, 98, 97, 96))
        assert (n, pages) == (8, [10, 11])
        assert ix.match(_toks(99, 98, 97, 96))[0] == 0
        # queries shorter than one quantum can never match
        assert ix.match(_toks(0, 1, 2))[0] == 0

    def test_whole_quantum_granularity(self):
        """Only whole quanta index: a 10-token prompt at quantum 4
        registers 8 tokens / 2 pages — the ragged trailing segment (and
        its partially-filled page) is never shareable."""
        ix = PrefixIndex(quantum=4, page_size=4)
        assert ix.insert(_toks(*range(10)), [5, 6, 7], scales=None) == 8
        assert ix.n_nodes == 2
        n, pages, _ = ix.match(_toks(*range(10)))
        assert (n, pages) == (8, [5, 6])
        assert 7 not in ix.indexed_pages

    def test_multi_page_nodes(self):
        """quantum > page_size: each node carries quantum/page_size
        pages and matches stay node-atomic."""
        ix = PrefixIndex(quantum=8, page_size=4)
        ix.insert(_toks(*range(16)), [1, 2, 3, 4], scales=None)
        n, pages, _ = ix.match(_toks(*range(12)))   # 12 < 2 quanta
        assert (n, pages) == (8, [1, 2])

    def test_first_donor_wins(self):
        """Concurrent cold admissions of the same prompt register
        different physical pages; the second insert adopts the existing
        entry instead of replacing it (both byte-identical by scheduling
        invariance, and the first may already be shared)."""
        ix = PrefixIndex(quantum=4, page_size=4)
        ix.insert(_toks(1, 2, 3, 4), [7], scales="first")
        ix.insert(_toks(1, 2, 3, 4), [9], scales="second")
        assert ix.n_nodes == 1
        n, pages, sc = ix.match(_toks(1, 2, 3, 4))
        assert (n, pages, sc) == (4, [7], "first")

    def test_branching(self):
        ix = PrefixIndex(quantum=4, page_size=4)
        ix.insert(_toks(0, 1, 2, 3, 10, 11, 12, 13), [1, 2], scales=None)
        ix.insert(_toks(0, 1, 2, 3, 20, 21, 22, 23), [1, 3], scales=None)
        assert ix.n_nodes == 3                  # shared root segment
        assert ix.match(_toks(0, 1, 2, 3, 20, 21, 22, 23))[1] == [1, 3]

    def test_invalidate_releases_subtree(self):
        """Release-to-zero of a page kills its node AND every deeper
        node: a surviving deeper entry would hand out the dead page as
        part of its prefix run."""
        ix = PrefixIndex(quantum=4, page_size=4)
        ix.insert(_toks(*range(12)), [1, 2, 3], scales=None)
        ix.insert(_toks(0, 1, 2, 3, 50, 51, 52, 53), [1, 9], scales=None)
        assert ix.n_nodes == 4
        assert ix.invalidate([2]) == 2          # node for page 2 + child
        n, pages, _ = ix.match(_toks(*range(12)))
        assert (n, pages) == (4, [1])
        # the sibling branch under page 1 survives
        assert ix.match(_toks(0, 1, 2, 3, 50, 51, 52, 53))[1] == [1, 9]
        # killing the root segment empties the tree
        ix.invalidate([1])
        assert ix.n_nodes == 0 and ix.indexed_pages == ()

    def test_invalidate_unknown_page_is_noop(self):
        ix = PrefixIndex(quantum=4, page_size=4)
        ix.insert(_toks(1, 2, 3, 4), [0], scales=None)
        assert ix.invalidate([3]) == 0
        assert ix.n_nodes == 1

    def test_hash_collisions_never_false_match(self, monkeypatch):
        """Bucket the hash to a constant: every segment collides, and
        lookups must still resolve by exact token comparison."""
        monkeypatch.setattr(paging, "_segment_hash", lambda toks: 17)
        ix = PrefixIndex(quantum=4, page_size=4)
        ix.insert(_toks(1, 2, 3, 4), [0], scales="A")
        ix.insert(_toks(4, 3, 2, 1), [1], scales="B")
        ix.insert(_toks(1, 2, 3, 4, 9, 9, 9, 9), [0, 2], scales="C")
        assert ix.match(_toks(4, 3, 2, 1))[1] == [1]
        assert ix.match(_toks(1, 2, 3, 4, 9, 9, 9, 9))[1] == [0, 2]
        assert ix.match(_toks(5, 5, 5, 5))[0] == 0


# ----------------------------------------------------------------------
# property test: radix index vs a naive dictionary model
# ----------------------------------------------------------------------

class _NaiveIndex:
    """Reference model: one dict entry per (prefix-tuple) node."""

    def __init__(self, quantum, page_size):
        self.q, self.ppn = quantum, quantum // page_size
        self.nodes = {}                 # prefix tuple -> own page run

    def insert(self, tokens, pages):
        depth = len(tokens) // self.q
        for d in range(depth):
            key = tuple(tokens[:(d + 1) * self.q])
            self.nodes.setdefault(
                key, tuple(pages[d * self.ppn:(d + 1) * self.ppn]))

    def match(self, tokens):
        pages, n = [], 0
        for d in range(len(tokens) // self.q):
            key = tuple(tokens[:(d + 1) * self.q])
            if key not in self.nodes:
                break
            pages.extend(self.nodes[key])
            n += self.q
        return n, pages

    def invalidate(self, dead):
        dead = set(dead)
        direct = {k for k, v in self.nodes.items() if dead & set(v)}
        self.nodes = {k: v for k, v in self.nodes.items()
                      if not any(k[:len(r)] == r for r in direct)}


def _run_index_script(quantum, page_size, ops):
    """Interpret (op, seed) pairs against PrefixIndex and the naive
    model, asserting identical match results after every operation.
    Token sequences draw from a tiny alphabet with short lengths so
    prefixes collide often; pages are distinct per insert."""
    ix = PrefixIndex(quantum=quantum, page_size=page_size)
    naive = _NaiveIndex(quantum, page_size)
    ppn = quantum // page_size
    next_page = 0
    for op_i, seed in ops:
        rng = np.random.default_rng(seed)
        toks = rng.integers(0, 3, (int(rng.integers(1, 4 * quantum)),))
        op = ("insert", "match", "invalidate")[op_i % 3]
        if op == "insert":
            depth = len(toks) // quantum
            pages = list(range(next_page, next_page + depth * ppn))
            next_page += len(pages)
            ix.insert(toks, pages, scales=None)
            naive.insert(toks, pages)
        elif op == "match":
            pass                        # compared below every op anyway
        elif op == "invalidate":
            dead = [int(rng.integers(0, max(next_page, 1)))]
            ix.invalidate(dead)
            naive.invalidate(dead)
        got_n, got_pages, _ = ix.match(toks)
        want_n, want_pages = naive.match(toks)
        assert (got_n, got_pages) == (want_n, want_pages)
        assert len(ix.indexed_pages) == len(
            {p for v in naive.nodes.values() for p in v})


if HAVE_HYPOTHESIS:
    @given(st.sampled_from([(4, 4), (8, 4)]),
           st.lists(st.tuples(st.integers(0, 2), st.integers(0, 10 ** 6)),
                    max_size=40))
    @settings(max_examples=150, deadline=None)
    def test_index_matches_naive_model_property(geom, ops):
        _run_index_script(*geom, ops)


def test_index_matches_naive_model_sweep():
    """Deterministic fallback mirroring the hypothesis property."""
    for seed in range(25):
        rng = np.random.default_rng(seed)
        quantum, page_size = (4, 4) if seed % 2 else (8, 4)
        ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 10 ** 6)))
               for _ in range(40)]
        _run_index_script(quantum, page_size, ops)


# ----------------------------------------------------------------------
# engine configuration contract
# ----------------------------------------------------------------------

def test_prefix_cache_requires_chunked_prefill():
    """Sequential admission freezes scales from the whole prompt's
    dynamic range, so equal prefixes of different prompts would NOT
    produce equal bytes — the engine must refuse the combination."""
    import jax.numpy as jnp
    from repro.configs.base import get_reduced_config
    from repro.models.cache import CacheConfig
    from repro.models.model import Model
    from repro.core.sparq import SparqConfig
    from repro.launch.serve import ContinuousBatchingEngine
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                 impl="reference")
    with pytest.raises(ValueError, match="prefill chunked"):
        ContinuousBatchingEngine(
            Model(cfg), cc, page_size=4, n_pages=8, max_active=2,
            max_seq_len=16, prefill="sequential", prefix_cache=True)


def test_quantum_covers_pages_and_segments():
    """The engine's match granularity is lcm(page_size, chunk_seg): a
    PrefixIndex built on anything that does not cover whole pages is
    rejected at construction."""
    with pytest.raises(AssertionError, match="whole pages"):
        PrefixIndex(quantum=6, page_size=4)
    import jax.numpy as jnp
    from repro.configs.base import get_reduced_config
    from repro.models.cache import CacheConfig
    from repro.models.model import Model
    from repro.core.sparq import SparqConfig
    from repro.launch.serve import ContinuousBatchingEngine
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                 impl="reference")
    eng = ContinuousBatchingEngine(
        Model(cfg), cc, page_size=4, n_pages=8, max_active=2,
        max_seq_len=16, prefill="chunked", chunk_size=16, chunk_align=4,
        chunk_seg=2, prefix_cache=True)
    assert eng._quantum == 4                    # lcm(4, 2)
