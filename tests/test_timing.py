"""Clock discipline in timed paths (lint-style source check).

Interval measurements must use `time.perf_counter()` — `time.time()` is
wall-clock and steps backwards under NTP slew, which turns benchmark
deltas, TTFT/ITL samples, and the engine's wall arrival clock into
noise (the scheduler-clock bugfix this pins). Heartbeat timestamps in
distributed/fault.py use `time.monotonic()` for the same reason (they
cross method calls, not intervals inside one frame).

This is a source-text check, not an import-time one, so it also catches
call sites that only run on hardware CI never exercises.
"""
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every module that measures intervals or stamps arrivals/heartbeats
TIMED_PATHS = [
    "src/repro/launch/serve.py",
    "src/repro/launch/frontend.py",
    "src/repro/launch/prefill.py",
    "src/repro/launch/dryrun.py",
    "src/repro/launch/train.py",
    "src/repro/distributed/fault.py",
    "src/repro/obs/__init__.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/tracing.py",
    "src/repro/obs/export.py",
    "benchmarks/run.py",
    "benchmarks/common.py",
]


@pytest.mark.parametrize("rel", TIMED_PATHS)
def test_no_wall_clock_in_timed_paths(rel):
    src = open(os.path.join(ROOT, rel)).read()
    hits = [i + 1 for i, line in enumerate(src.splitlines())
            if re.search(r"\btime\.time\(", line)]
    assert not hits, (f"{rel} uses time.time() on line(s) {hits}; "
                      f"use time.perf_counter() (intervals) or "
                      f"time.monotonic() (cross-call stamps)")


def test_timed_paths_exist():
    """The list above goes stale silently if files move; fail loudly."""
    for rel in TIMED_PATHS:
        assert os.path.exists(os.path.join(ROOT, rel)), rel
