"""The static-analysis gate itself: every check fires on its seeded
fixture (exactly once), the clean tree reports zero unsuppressed
findings, and the baseline machinery is strict about malformed input.

The fixtures under tests/fixtures/analysis/ are the analyzer's unit
corpus: jaxpr_violations.py is traced abstractly (never executed),
host_violations.py is linted AST-only (never imported).
"""
import importlib.util
import os

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import host_lint
from repro.analysis.findings import (ALL_CHECKS, HL_LOOP_NUMERIC,
                                     HL_LOOP_SYNC, HL_TRACED_MUT,
                                     HL_TRACED_RAISE, HL_UNANNOTATED,
                                     JX_COMPILE_CACHE, JX_HOSTCALL,
                                     JX_PACKED_CAST, JX_PAGE_TILE,
                                     JX_TILE_DIVIDE, JX_VMEM, Finding,
                                     load_baseline, split_suppressed)
from repro.analysis.jaxpr_audit import (DEFAULT_VMEM_BUDGET, ProgramSpec,
                                        audit_program, call_signature)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@pytest.fixture(scope="module")
def jaxpr_fixture():
    spec = importlib.util.spec_from_file_location(
        "jaxpr_violations", os.path.join(FIXTURES, "jaxpr_violations.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------- jaxpr
# one seeded program per check — each must fire its check exactly once,
# and nothing else (a second finding means a check is over-firing; an
# empty list means it is dead)

JX_CASES = [
    ("hostcall", [((4,), jnp.float32)], {}, DEFAULT_VMEM_BUDGET,
     JX_HOSTCALL),
    # same check, different container: proves the auditor descends into
    # shard_map bodies (the TP serving programs), not only pjit cores
    ("shard_map_hostcall", [((4,), jnp.float32)], {}, DEFAULT_VMEM_BUDGET,
     JX_HOSTCALL),
    ("packed_cast", [((8, 16), jnp.int8)], {}, DEFAULT_VMEM_BUDGET,
     JX_PACKED_CAST),
    ("tile_misdivide", [((48, 16), jnp.float32)], {}, DEFAULT_VMEM_BUDGET,
     JX_TILE_DIVIDE),
    ("page_tile_mismatch", [((4, 16, 2, 8), jnp.int8)],
     {"page_size": 16}, DEFAULT_VMEM_BUDGET, JX_PAGE_TILE),
    # whole-array f32 blocks: 2 * 256*256*4 = 512 KiB > the 256 KiB
    # test budget (and well under the default budget, so only JX105
    # distinguishes this case)
    ("vmem_hog", [((256, 256), jnp.float32)], {}, 256 * 1024, JX_VMEM),
]


@pytest.mark.parametrize("fn,argspec,kw,budget,check",
                         JX_CASES, ids=[c[4] for c in JX_CASES])
def test_jaxpr_check_fires_exactly_once(jaxpr_fixture, fn, argspec, kw,
                                        budget, check):
    args = tuple(_sds(s, d) for s, d in argspec)
    spec = ProgramSpec(fn, getattr(jaxpr_fixture, fn), [args], **kw)
    findings, n_sig = audit_program(spec, vmem_budget=budget)
    assert [f.check for f in findings] == [check], \
        [f.format() for f in findings]
    assert n_sig == 1
    assert findings[0].program == fn


def test_compile_cache_check_fires_exactly_once(jaxpr_fixture):
    spec = ProgramSpec(
        "shape_polymorphic", jaxpr_fixture.shape_polymorphic,
        [(_sds((4,), jnp.float32),), (_sds((8,), jnp.float32),)])
    findings, n_sig = audit_program(spec)
    assert [f.check for f in findings] == [JX_COMPILE_CACHE]
    assert n_sig == 2


def test_call_signature_is_jit_cache_identity():
    a = (jnp.float32, (4, 2))
    sig = lambda *args, **kw: call_signature(args, kw or None)
    x, y = _sds((4, 2), jnp.float32), _sds((4, 2), jnp.float32)
    assert sig(x, 3) == sig(y, 3)                    # same shapes/statics
    assert sig(x, 3) != sig(_sds((8, 2), jnp.float32), 3)   # shape
    assert sig(x, 3) != sig(_sds((4, 2), jnp.int32), 3)     # dtype
    assert sig(x, 3) != sig(x, 4)                    # static arg value
    assert sig(x, steps=3) != sig(x, 3)              # tree structure
    del a


# ----------------------------------------------------------------- host

def test_each_host_check_fires_exactly_once():
    rel = "tests/fixtures/analysis/host_violations.py"
    findings = host_lint.lint_file(
        os.path.join(FIXTURES, "host_violations.py"), rel)
    assert sorted(f.check for f in findings) == [
        HL_LOOP_NUMERIC, HL_LOOP_SYNC, HL_TRACED_MUT, HL_TRACED_RAISE,
        HL_UNANNOTATED], [f.format() for f in findings]
    assert all(f.file == rel and f.line > 0 for f in findings)


def test_module_without_annotation_is_flagged_wholesale(tmp_path):
    p = tmp_path / "unannotated.py"
    p.write_text("import jax\n\nfast = jax.jit(lambda x: x)\n")
    findings = host_lint.lint_file(str(p))
    assert [f.check for f in findings] == [HL_UNANNOTATED]


def test_every_check_id_is_covered_by_a_fixture():
    """The seeded corpus spans the full check catalog — adding a check
    without a fixture fails here, not silently in CI."""
    seeded = {c[4] for c in JX_CASES} | {
        JX_COMPILE_CACHE, HL_LOOP_NUMERIC, HL_LOOP_SYNC, HL_TRACED_MUT,
        HL_TRACED_RAISE, HL_UNANNOTATED}
    assert seeded == set(ALL_CHECKS)


# ------------------------------------------------------------- baseline

def test_baseline_round_trip(tmp_path):
    p = tmp_path / "baseline.toml"
    p.write_text(
        '# reviewed\n'
        '[[suppress]]\n'
        'check = "JX106"\n'
        'contains = "decode_replay"\n'
        'reason = "replay retraces per recorded-token count by design"\n')
    sups = load_baseline(str(p))
    assert len(sups) == 1
    hit = Finding("JX106", "a.py", 1, "decode_replay", "2 signatures")
    miss = Finding("JX106", "a.py", 1, "prefill_chunk", "2 signatures")
    live, muted = split_suppressed([hit, miss], sups)
    assert muted == [hit] and live == [miss]


@pytest.mark.parametrize("body,err", [
    ('[[suppress]]\ncheck = "JX101"\n', "reason"),      # no justification
    ('[[suppress]]\nreason = "x"\n', "check"),          # no check
    ('[[suppress]]\ncheck = JX101\nreason = "x"\n', "double-quoted"),
    ('[[suppress]]\ncheck = "JX101"\nreason = "x"\nfoo = "y"\n',
     "unknown"),
    ('what is this\n', "unparseable"),
], ids=["no-reason", "no-check", "unquoted", "unknown-key", "garbage"])
def test_malformed_baseline_is_a_hard_error(tmp_path, body, err):
    p = tmp_path / "baseline.toml"
    p.write_text(body)
    with pytest.raises(ValueError, match=err):
        load_baseline(str(p))


# ------------------------------------------------------------ clean tree

def test_clean_tree_reports_zero_unsuppressed_findings():
    """The CI gate, as an importable assertion: both engines over the
    real tree and shipped baseline — nothing fires."""
    from repro.analysis import run_all
    live, muted, counters = run_all()
    assert live == [], [f.format() for f in live]
    assert muted == []                   # shipped baseline is empty
    assert counters["programs_traced"] >= 10
    per = counters["jaxprs_per_program"]
    assert per["prefill_chunk"] == 1 and per["decode_step.paged"] == 1
