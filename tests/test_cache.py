"""SPARQ-quantized KV-cache subsystem + scan-based decode engine.

Covers: CachedTensor fp/sparq layout semantics, CacheStore append/read,
ring-slot writes, modeled footprint accounting (§5.1 packed format), and
the end-to-end acceptance: the scan-based DecodeEngine produces identical
greedy tokens for the fp and sparq(int8, trimming disabled) layouts, and
matching tokens across engine phases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparq import SparqConfig
from repro.models.cache import (CacheConfig, CachedTensor, CacheStore,
                                bytes_per_value, ctrl_bytes_per_value,
                                modeled_cache_bytes)

KEY = jax.random.PRNGKey(0)


class TestCachedTensor:
    def test_fp_append_read_exact(self):
        cc = CacheConfig.fp32()
        t = CachedTensor.init((2, 8, 4), cc)
        x = jax.random.normal(KEY, (2, 3, 4))
        t2 = t.append(x, jnp.int32(2))
        out = t2.read()
        np.testing.assert_array_equal(np.asarray(out[:, 2:5]), np.asarray(x))
        assert np.asarray(out[:, :2] == 0).all()

    def test_sparq_append_read_close(self):
        cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
        t = CachedTensor.init((2, 8, 4, 16), cc)
        x = jax.random.normal(KEY, (2, 4, 4, 16))
        t2 = t.append(x, jnp.int32(0))
        out = np.asarray(t2.read()[:, :4])
        rel = np.abs(out - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
        assert rel < 0.12            # 4-bit window + int8 grid error bound
        assert t2.data.dtype == jnp.int8 and t2.meta.dtype == jnp.int8

    def test_sparq_int8_roundtrip_is_grid_exact(self):
        """With SPARQ trimming disabled the cache is a plain int8 grid:
        writing a tensor already on the grid reads back exactly."""
        cc = CacheConfig.sparq_cache(SparqConfig(enabled=False, signed=True))
        t = CachedTensor.init((1, 4, 8), cc)
        codes = jax.random.randint(KEY, (1, 4, 8), -127, 128)
        codes = codes.at[0, 0, 0].set(127)  # pin the dynamic scale to 0.03
        scale = 0.03
        t2 = t.append(codes.astype(jnp.float32) * scale, jnp.int32(0))
        got = np.asarray(t2.read())
        np.testing.assert_allclose(
            got, np.asarray(codes, np.float32) * scale, rtol=1e-6, atol=1e-6)

    def test_scale_frozen_after_first_write(self):
        """Per-site scale calibrates on the first (prefill) write and stays
        frozen for decode writes — required for a fixed-point scan carry."""
        cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
        t = CachedTensor.init((1, 8, 8), cc)
        x0 = jax.random.normal(KEY, (1, 4, 8))
        t1 = t.append(x0, jnp.int32(0))
        s1 = float(t1.scale)
        assert s1 > 0
        t2 = t1.append(10.0 * x0[:, :1], jnp.int32(4))  # larger dyn range
        assert float(t2.scale) == s1

    def test_write_slots_ring(self):
        cc = CacheConfig.sparq_cache(SparqConfig(enabled=False, signed=True))
        t = CachedTensor.init((1, 4, 8), cc)
        x = jnp.ones((1, 2, 8)) * 0.5
        t2 = t.write_slots(x, jnp.asarray([3, 0]))     # wraparound slots
        out = np.asarray(t2.read())
        assert np.abs(out[0, 3] - 0.5).max() < 0.01
        assert np.abs(out[0, 0] - 0.5).max() < 0.01
        assert (out[0, 1:3] == 0).all()

    def test_odd_lane_count_rejected(self):
        cc = CacheConfig.sparq_cache()
        with pytest.raises(AssertionError):
            CachedTensor.init((2, 8, 7), cc)


class TestCacheStore:
    def test_update_advances_pos(self):
        st = CacheStore.init((2, 16, 2, 8), CacheConfig.fp32())
        k = jax.random.normal(KEY, (2, 5, 2, 8))
        st = st.update(k, k)
        st = st.update(k[:, :2], k[:, :2])
        assert int(st.pos) == 7

    def test_scan_carry_transparent(self):
        """CacheStore must round-trip a lax.scan carry (the decode loop)."""
        cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
        st = CacheStore.init((1, 8, 2, 8), cc)
        st = st.update(jnp.ones((1, 2, 2, 8)), jnp.ones((1, 2, 2, 8)))

        def step(c, _):
            c = c.update(jnp.ones((1, 1, 2, 8)), jnp.ones((1, 1, 2, 8)))
            return c, c.pos

        st, ps = jax.lax.scan(step, st, None, length=3)
        np.testing.assert_array_equal(np.asarray(ps), [3, 4, 5])


class TestFootprint:
    def test_bytes_per_value_presets(self):
        assert bytes_per_value(CacheConfig.fp32()) == 4.0
        assert bytes_per_value(CacheConfig.bf16()) == 2.0
        int8 = CacheConfig.sparq_cache(SparqConfig(enabled=False,
                                                   signed=True))
        assert bytes_per_value(int8) == 1.0
        opt5 = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
        # acceptance: 4-bit 5opt data plane <= 0.57 B/value
        assert bytes_per_value(opt5) <= 0.57
        assert ctrl_bytes_per_value(opt5) == pytest.approx(3 / 8)
        # total matches the §5.1 roofline figure in kernels.ops
        from repro.kernels.ops import bytes_per_value as roofline_bpv
        assert bytes_per_value(opt5) + ctrl_bytes_per_value(opt5) == \
            pytest.approx(roofline_bpv(SparqConfig.opt5(signed=True)))

    def test_modeled_cache_bytes_walk(self):
        cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
        st = CacheStore.init((2, 16, 2, 8), cc)
        tally = modeled_cache_bytes([st])
        n = 2 * 16 * 2 * 8 * 2          # two planes
        assert tally["values"] == n
        assert tally["data_bytes"] == pytest.approx(n * 0.5625)
        assert tally["ctrl_bytes"] == pytest.approx(n * 0.375)


# ----------------------------------------------------------------------
# end-to-end: scan-based decode engine over the cache layouts
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24),
                                          0, cfg.vocab_size)}
    return model, params, batch


def _engine_tokens(model, params, batch, cache_cfg, gen=12):
    from repro.launch.serve import DecodeEngine
    engine = DecodeEngine(model, cache_cfg)
    toks, stats = engine.generate(params, batch, gen, warmup=False)
    return np.asarray(toks), stats


def test_sparq_int8_layout_matches_fp_greedy(tiny_lm):
    """Acceptance: identical greedy tokens for the fp layout and the sparq
    layout with trimming disabled (lossless-on-the-grid int8 path)."""
    model, params, batch = tiny_lm
    t_fp, _ = _engine_tokens(model, params, batch, CacheConfig.fp32())
    t_i8, s = _engine_tokens(
        model, params, batch,
        CacheConfig.sparq_cache(SparqConfig(enabled=False, signed=True)))
    np.testing.assert_array_equal(t_fp, t_i8)
    assert s["cache_bytes_per_value"] == 1.0


def test_sparq_5opt_layout_close_logits(tiny_lm):
    """The full 4-bit 5opt codec: decode logits stay close to the fp cache
    (greedy tokens are NOT asserted equal — a randomly-initialized tiny LM
    has near-zero decision margins, so 4-bit trimming noise can flip
    argmax; the paper's premise is small *error*, which is what we check),
    and the modeled data plane hits the §5.1 footprint."""
    model, params, batch = tiny_lm

    def one_decode_logits(cache_cfg):
        caches = model.init_cache(2, 40, cache_cfg=cache_cfg)
        logits, caches = model.prefill(params, batch, caches)
        tok = jnp.argmax(logits, -1)[:, None]
        logits, _ = model.decode_step(params, tok, caches,
                                      jnp.asarray(24, jnp.int32))
        return np.asarray(logits)

    l_fp = one_decode_logits(CacheConfig.fp32())
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
    l_sq = one_decode_logits(cc)
    err = np.abs(l_sq - l_fp).mean() / (np.abs(l_fp).mean() + 1e-6)
    assert err < 0.25               # 4-bit window noise, not garbage
    assert bytes_per_value(cc) <= 0.57
    t_sq, s = _engine_tokens(model, params, batch, cc)
    assert ((t_sq >= 0) & (t_sq < model.cfg.vocab_size)).all()
    assert s["cache_bytes_per_value"] <= 0.57


def test_engine_matches_python_loop(tiny_lm):
    """The single-scan engine reproduces the step-by-step python loop."""
    model, params, batch = tiny_lm
    toks, _ = _engine_tokens(model, params, batch, CacheConfig.fp32(), gen=6)
    caches = model.init_cache(2, 24 + 6 + 8, cache_cfg=CacheConfig.fp32())
    logits, caches = model.prefill(params, batch, caches)
    tok = jnp.argmax(logits, -1)[:, None]
    got = [tok]
    for i in range(5):
        logits, caches = model.decode_step(
            params, tok, caches, jnp.asarray(24 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        got.append(tok)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(got, 1)), toks)


def test_make_cache_config_off_preset_is_plain_int8():
    """--sparq off + --kv-cache sparq must give the lossless int8 grid,
    not a default trimming codec."""
    from repro.launch.serve import make_cache_config
    cc = make_cache_config("sparq", None)
    assert cc.layout == "sparq" and not cc.sparq.enabled
    assert bytes_per_value(cc) == 1.0
    cc5 = make_cache_config("sparq", SparqConfig.opt5(signed=True))
    assert cc5.sparq.enabled and cc5.sparq.bits == 4


def test_serve_cli_sparq_cache():
    """CLI smoke: --kv-cache sparq + --impl reference end to end."""
    from repro.launch import serve as S
    stats = S.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                    "--prompt-len", "16", "--gen", "4", "--sparq", "5opt",
                    "--kv-cache", "sparq", "--impl", "reference",
                    "--calibrate", "1"])
    assert stats["decode_tok_s"] > 0
    assert stats["cache_bytes_per_value"] <= 0.57
    assert stats["compile_s"] > 0       # warmup pass reported separately


# ----------------------------------------------------------------------
# fused packed-cache decode path (no full-plane read on the hot path)
# ----------------------------------------------------------------------

def test_sparq_decode_never_reads_full_plane(tiny_lm, monkeypatch):
    """Acceptance: a decode step with the sparq layout must not call
    CachedTensor.read() (the full-plane dequantize) — the fused kernel
    consumes the raw packed planes. read() stays legal for fp planes and
    for prefill/debug."""
    model, params, batch = tiny_lm
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True))
    caches = model.init_cache(2, 40, cache_cfg=cc)
    logits, caches = model.prefill(params, batch, caches)
    tok = jnp.argmax(logits, -1)[:, None]

    read_layouts = []
    orig_read = CachedTensor.read

    def spy(self, dtype=None):
        read_layouts.append(self.layout)
        return orig_read(self, dtype)

    monkeypatch.setattr(CachedTensor, "read", spy)
    model.decode_step(params, tok, caches, jnp.asarray(24, jnp.int32))
    assert "sparq" not in read_layouts, \
        f"decode step dequantized a full sparq plane: {read_layouts}"


def test_packed_planes_never_decoded_statically():
    """Static counterpart of the read()-spy smoke above: the jaxpr
    auditor walks every registered hot program (both decode engines,
    the chunk program, every fused dispatcher) and proves no packed
    int8 plane is cast to float outside a pallas kernel (JX102) — the
    spy covers one dynamic path, this covers them all."""
    from repro.analysis import audit_all
    from repro.analysis.registry import default_programs
    findings, counters = audit_all(default_programs())
    assert not [f for f in findings if f.check == "JX102"], \
        [f.format() for f in findings]
    assert counters["programs_traced"] >= 10


def test_fused_decode_matches_dequant_path_greedy(tiny_lm, monkeypatch):
    """Acceptance: the fused decode path produces exactly the PR 1
    dequantize-path greedy tokens (int8 grid: bit-identical storage; 5opt:
    identical codes, attention differs only in f32 summation order)."""
    from repro.models import attention as attn_mod
    model, params, batch = tiny_lm
    for codec in (SparqConfig(enabled=False, signed=True),
                  SparqConfig.opt5(signed=True)):
        cc = CacheConfig.sparq_cache(codec)
        t_fused, _ = _engine_tokens(model, params, batch, cc, gen=8)
        with monkeypatch.context() as mp:
            mp.setattr(attn_mod, "decode_attention",
                       attn_mod.decode_attention_dequant)
            t_dequant, _ = _engine_tokens(model, params, batch, cc, gen=8)
        np.testing.assert_array_equal(t_fused, t_dequant)


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                  "recurrentgemma-9b"])
def test_fused_decode_nondense_archs_match_fp(arch):
    """The two non-dense fused read paths — absorbed-MLA tiled decode
    (deepseek latent cache) and the windowed ring kernel (recurrentgemma
    hybrid) — reproduce the fp-cache greedy tokens exactly on the lossless
    int8 grid, end to end through the DecodeEngine."""
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config(arch).replace(
        dtype=jnp.float32, remat=False, capacity_factor=1000.0)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 16),
                                          0, cfg.vocab_size)}
    t_fp, _ = _engine_tokens(model, params, batch, CacheConfig.fp32(),
                             gen=6)
    cc = CacheConfig.sparq_cache(SparqConfig(enabled=False, signed=True),
                                 impl="reference")
    t_i8, _ = _engine_tokens(model, params, batch, cc, gen=6)
    np.testing.assert_array_equal(t_fp, t_i8)


def test_mla_sparq_decode_matches_dequant_oracle():
    """Bit-level check of _sparq_mla_decode: the tiled fused latent decode
    equals the full-plane dequantize oracle (read + plain softmax) for the
    5opt codec, across a tile-straddling pos."""
    from repro.configs.base import get_reduced_config
    from repro.models import mla as mla_mod
    from repro.models.cache import CacheConfig
    cfg = get_reduced_config("deepseek-v2-lite-16b")
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    B, H, Tmax, pos = 2, cfg.n_heads, 24, 13
    cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                 impl="reference")
    cache = mla_mod.mla_cache_init(cfg, B, Tmax, cache_cfg=cc)
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    cache = mla_mod.MLACache(
        cache.c_kv.append(jax.random.normal(k1, (B, pos, r)), jnp.int32(0)),
        cache.k_pe.append(jax.random.normal(k2, (B, pos, dr)), jnp.int32(0)),
        jnp.asarray(pos, jnp.int32))
    q_lat = jax.random.normal(k3, (B, 1, H, r))
    q_pe = jax.random.normal(k4, (B, 1, H, dr))
    sm = (cfg.qk_nope_dim + dr) ** -0.5
    got = mla_mod._sparq_mla_decode(q_lat, q_pe, cache, sm_scale=sm,
                                    out_dtype=jnp.float32, bk=8)
    # oracle: full-plane read + plain softmax (the PR 1 path)
    c_full, pe_full = cache.c_kv.read(), cache.k_pe.read()
    s = (jnp.einsum("bthr,bsr->bhts", q_lat, c_full) +
         jnp.einsum("bthe,bse->bhts", q_pe, pe_full)) * sm
    kpos = jnp.arange(Tmax)
    s = jnp.where((kpos < cache.pos)[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhts,bsr->bthr", p, c_full)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_generate_capacity_check(tiny_lm):
    """DecodeEngine.generate raises host-side (before tracing) when prompt
    + generation would overflow the cache, instead of letting the traced
    dynamic_update_slice silently clamp."""
    from repro.launch.serve import DecodeEngine
    model, params, batch = tiny_lm
    engine = DecodeEngine(model, CacheConfig.fp32())
    with pytest.raises(ValueError, match="overflow"):
        engine.generate(params, batch, gen=12, max_len=30)  # needs 36


def test_append_overflow_silently_clamps():
    """Regression doc for the underlying hazard: appending past Tmax does
    NOT error — dynamic_update_slice_in_dim clamps the start index, so the
    write lands on (and overwrites) the newest slots. This is why the
    engine must check capacity host-side."""
    t = CachedTensor.init((1, 4, 8), CacheConfig.fp32())
    first = jnp.full((1, 4, 8), 1.0)
    t = t.append(first, jnp.int32(0))
    extra = jnp.full((1, 2, 8), 2.0)
    t2 = t.append(extra, jnp.int32(3))       # pos 3 + 2 new > Tmax=4
    out = np.asarray(t2.read())
    np.testing.assert_array_equal(out[0, :2], 1.0)   # oldest intact
    np.testing.assert_array_equal(out[0, 2:], 2.0)   # newest overwritten


def test_bytes_per_value_single_source_of_truth():
    """Acceptance: ops (roofline) and cache (report) accountings agree for
    every serving preset — data plane + ShiftCtrl side-band == combined
    roofline figure; MuxCtrl is charged only when vSPARQ is on."""
    from repro.kernels import ops
    from repro.launch.serve import SPARQ_PRESETS, make_cache_config
    for name, scfg in SPARQ_PRESETS.items():
        cc = make_cache_config("sparq", scfg)
        total = bytes_per_value(cc) + ctrl_bytes_per_value(cc)
        assert total == pytest.approx(ops.bytes_per_value(cc.sparq)), name
        # and the no-vsparq variant must not charge the 0.5-bit MuxCtrl
        if scfg is not None and scfg.enabled:
            novs = dataclasses.replace(scfg, vsparq=False)
            cc_novs = make_cache_config("sparq", novs)
            assert bytes_per_value(cc) - bytes_per_value(cc_novs) == \
                pytest.approx(0.5 / 8.0), name
            assert ops.bytes_per_value(novs) == \
                pytest.approx((novs.bits + 3) / 8.0), name
