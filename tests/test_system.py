"""End-to-end system tests: train loop with checkpoint/restart determinism,
serve loop with SPARQ, gradient compression in the loop."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_checkpoint_restart_exact():
    """Crash/restart must reproduce the uninterrupted run exactly
    (deterministic data pipeline + checkpointed params/opt state)."""
    from repro.launch import train as T
    with tempfile.TemporaryDirectory() as d1:
        full = T.main(["--arch", "tinyllama-1.1b", "--reduced",
                       "--steps", "8", "--lr-total", "8",
                       "--batch", "4", "--seq", "32",
                       "--checkpoint-dir", d1, "--checkpoint-every", "4",
                       "--log-every", "100"])
    with tempfile.TemporaryDirectory() as d2:
        T.main(["--arch", "tinyllama-1.1b", "--reduced",
                "--steps", "4", "--lr-total", "8", "--batch", "4", "--seq", "32",
                "--checkpoint-dir", d2, "--checkpoint-every", "4",
                "--log-every", "100"])
        resumed = T.main(["--arch", "tinyllama-1.1b", "--reduced",
                          "--steps", "8", "--lr-total", "8",
                          "--batch", "4", "--seq", "32",
                          "--checkpoint-dir", d2, "--checkpoint-every", "4",
                          "--restore", "--log-every", "100"])
    np.testing.assert_allclose(full[4:], resumed, rtol=2e-4, atol=2e-4)


def test_train_loss_decreases():
    from repro.launch import train as T
    losses = T.main(["--arch", "tinyllama-1.1b", "--reduced",
                     "--steps", "30", "--batch", "8", "--seq", "64",
                     "--lr", "2e-3", "--log-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_train_with_grad_compression_converges():
    """SPARQ-compressed gradients (error feedback) still train."""
    from repro.launch import train as T
    losses = T.main(["--arch", "tinyllama-1.1b", "--reduced",
                     "--steps", "30", "--batch", "8", "--seq", "64",
                     "--lr", "2e-3", "--compress-grads",
                     "--log-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_serve_quantized_runs():
    from repro.launch import serve as S
    stats = S.main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                    "--prompt-len", "16", "--gen", "4", "--sparq", "5opt",
                    "--calibrate", "1"])
    assert stats["decode_tok_s"] > 0


def test_serve_rwkv_constant_state():
    """Attention-free arch serves with O(1) state (long-context story)."""
    from repro.launch import serve as S
    stats = S.main(["--arch", "rwkv6-7b", "--reduced", "--batch", "2",
                    "--prompt-len", "16", "--gen", "4", "--sparq", "a8w8",
                    "--calibrate", "0"])
    assert stats["decode_tok_s"] > 0
