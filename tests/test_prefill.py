"""Chunked ragged prefill: kernel grid, direct-write path, engine
equality, compile-count guard, and the preemption cost model.

What "exact" means here, layer by layer:

  kernel     ref vs pallas-interpret agree to a couple of f32 ulps (XLA
             fuses the scanned oracle's multiply-add chain differently
             from the interpreter's op-by-op execution; the in-chunk
             stage alone is bitwise) and both match a dense float oracle;
             masking structure (padding rows, page bounds, windows) is
             asserted exactly.
  bytes      a prompt prefilled through one chunk writes bit-identical
             §5.1 page bytes, scales, and positions to the sequential
             contiguous-prefill + adopt_prefill path.
  tokens     greedy tokens are bit-identical between --prefill
             sequential and --prefill chunked whenever prompts fit one
             segment, for the plain-int8 grid and the 4-bit 5opt codec,
             across a ragged staggered-arrival trace; multi-segment
             prompts are *packing-invariant* (identical tokens under any
             chunk size / slot count / join pattern at a fixed segment
             quantum), which is what requeue-replay resume relies on.
  compiles   the chunk program traces exactly once across any mix of
             prompt lengths (the per-length-retrace regression guard).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparq import SparqConfig
from repro.models.cache import CacheConfig

KEY = jax.random.PRNGKey(0)
PS = 4                                  # page size for every engine test


def _cc(codec=None):
    codec = codec or SparqConfig.opt5(signed=True)
    return dataclasses.replace(
        CacheConfig.sparq_cache(codec, impl="reference"), attn_bk=PS)


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    return model, params


# ----------------------------------------------------------------------
# cost model: requeue-vs-swap crossover (SchedulerPolicy.estimate_cost)
# ----------------------------------------------------------------------

def test_cost_model_crossover_is_pinned():
    """Requeue cost grows with decode progress (sequential replay steps),
    swap cost is flat in progress (bytes only): the crossover sits where
    replay_tok_us * (generated-1) overtakes the byte cost, and --preempt
    auto must flip exactly there."""
    from repro.launch.serve import SchedulerPolicy
    pol = SchedulerPolicy(preempt="auto", prefill_tok_us=1.0,
                          replay_tok_us=100.0, swap_gb_s=10.0)
    L, swap_bytes = 50, 500_000
    # swap cost: 2 * 5e5 B / (10 GB/s) = 100 us, flat in `generated`
    req1, swap1 = pol.estimate_cost(L, 1, swap_bytes)
    reqN, swapN = pol.estimate_cost(L, 5, swap_bytes)
    assert swap1 == swapN == pytest.approx(100.0)
    assert req1 == pytest.approx(50.0) and reqN == pytest.approx(450.0)
    # crossover: requeue(g) = 50 + 100*(g-1) crosses 100 between g=1, g=2
    assert pol.resolve(L, 1, swap_bytes) == "requeue"
    assert pol.resolve(L, 2, swap_bytes) == "swap"
    # monotone in generated
    costs = [pol.estimate_cost(L, g, swap_bytes)[0] for g in range(1, 6)]
    assert costs == sorted(costs)
    # fixed modes ignore the model
    assert SchedulerPolicy(preempt="requeue").resolve(L, 99, 1) == "requeue"
    assert SchedulerPolicy(preempt="swap").resolve(L, 1, 10**9) == "swap"


# ----------------------------------------------------------------------
# kernel grid: ref vs pallas-interpret vs dense float oracle
# ----------------------------------------------------------------------

def _build_pool(rng, cfg, S, P, NB, ps, KV, hd, cached):
    """Quantize `cached[s]` float K/V through the §5.1 codec into pool
    pages (block-table rows in order), returning the packed planes, the
    per-slot scales/tables, and the dequantized float planes (what the
    meta-decode reconstructs) for the dense oracle."""
    from repro.kernels import ref as R
    from repro.kernels.ops import sparq_pack
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    planes = {n: np.zeros((P, ps, KV, hd), np.int8)
              for n in ("kd", "km", "vd", "vm")}
    scales = {n: np.zeros(S, np.float32) for n in ("k", "v")}
    bt = -np.ones((S, NB), np.int64)
    deq = {}
    next_page = 1                       # page 0 stays dead (clamp target)
    for s, (xk, xv) in cached.items():
        n_tok = xk.shape[0]
        npages = math.ceil(n_tok / ps)
        pad = npages * ps - n_tok
        xk = np.concatenate([xk, np.zeros((pad, KV, hd), np.float32)])
        xv = np.concatenate([xv, np.zeros((pad, KV, hd), np.float32)])
        deq[s] = {}
        for name, x in (("k", xk), ("v", xv)):
            sc = max(np.abs(x).max(), 1e-8) / cfg.max_val
            scales[name][s] = sc
            codes, meta = R.ref_sparq_quant(jnp.asarray(x), sc, **kw)
            data = np.asarray(sparq_pack(codes, meta))
            meta = np.asarray(meta)
            for b in range(npages):
                pg = next_page + b
                planes[name + "d"][pg] = data[b * ps:(b + 1) * ps]
                planes[name + "m"][pg] = meta[b * ps:(b + 1) * ps]
            deq[s][name] = (np.asarray(R.ref_sparq_dequant(
                jnp.asarray(data), jnp.asarray(meta))).astype(np.float32)
                * sc)[:n_tok]
        bt[s, :npages] = np.arange(next_page, next_page + npages)
        next_page += npages
    assert next_page <= P
    return planes, scales, bt, deq


def _dense_oracle(q, kc, vc, deq, seq_id, pos, hist, KV, G, hd, window):
    """Per-token full-softmax attention over dequantized pages below
    `hist` plus float chunk keys in [hist, pos]."""
    C = q.shape[0]
    out = np.zeros((C, KV, G, hd), np.float32)
    for i in range(C):
        s = seq_id[i]
        if s < 0:
            continue
        keys, vals, kp = [], [], []
        if s in deq:
            h = min(hist[i], deq[s]["k"].shape[0])
            keys.append(deq[s]["k"][:h])
            vals.append(deq[s]["v"][:h])
            kp.append(np.arange(h))
        m = (seq_id == s) & (pos <= pos[i]) & (pos >= hist[i])
        keys.append(kc[m])
        vals.append(vc[m])
        kp.append(pos[m])
        K = np.concatenate(keys)
        V = np.concatenate(vals)
        KP = np.concatenate(kp)
        if window:
            K, V = K[KP > pos[i] - window], V[KP > pos[i] - window]
        qi = q[i].reshape(KV, G, hd)
        s_ = np.einsum("kgh,tkh->kgt", qi, K) * hd ** -0.5
        p = np.exp(s_ - s_.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        out[i] = np.einsum("kgt,tkh->kgh", p, V)
    return out


@pytest.mark.parametrize("vsparq", [True, False], ids=["vsparq", "plain"])
@pytest.mark.parametrize("window", [0, 5], ids=["full", "win5"])
def test_chunked_prefill_kernel_grid(vsparq, window):
    """Ragged chunk over a §5.1 page pool: sequence continuing mid-page
    (run straddles a page boundary), a second sequence resuming at a
    segment boundary, a fresh sequence, and padding — ref vs interpret
    vs the dense dequantize-everything oracle."""
    from repro.kernels.ops import sparq_chunked_prefill_attention
    rng = np.random.default_rng(0)
    S, NB, ps, KV, G, hd = 3, 4, 4, 2, 2, 8
    P, C, bq = 8, 16, 4
    cfg = dataclasses.replace(SparqConfig.opt5(signed=True), vsparq=vsparq)
    # slot 0: 7 cached tokens (page boundary straddled at 4); slot 1: 4
    cached = {0: (rng.standard_normal((7, KV, hd)).astype(np.float32),
                  rng.standard_normal((7, KV, hd)).astype(np.float32)),
              1: (rng.standard_normal((4, KV, hd)).astype(np.float32),
                  rng.standard_normal((4, KV, hd)).astype(np.float32))}
    planes, scales, bt, deq = _build_pool(
        rng, cfg, S, P, NB, ps, KV, hd, cached)
    # stream: slot 0 continues at pos 7..12 (hist 7: cached history),
    # slot 1 at 4..7 (hist 4), slot 2 fresh 0..2 (hist 0), 1 pad tile
    seq_id = np.full(C, -1, np.int64)
    pos = np.zeros(C, np.int64)
    hist = np.zeros(C, np.int64)
    tile_seq = np.array([0, 0, 1, 2], np.int64)
    seq_id[0:6], pos[0:6], hist[0:6] = 0, np.arange(7, 13), 7
    seq_id[8:12], pos[8:12], hist[8:12] = 1, np.arange(4, 8), 4
    seq_id[12:15], pos[12:15], hist[12:15] = 2, np.arange(0, 3), 0
    tile_seq = np.array([0, 0, 1, 2], np.int64)
    q = rng.standard_normal((C, KV * G, hd)).astype(np.float32)
    kc = rng.standard_normal((C, KV, hd)).astype(np.float32)
    vc = rng.standard_normal((C, KV, hd)).astype(np.float32)

    def run(impl):
        return np.asarray(sparq_chunked_prefill_attention(
            jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray(planes["kd"]), jnp.asarray(planes["km"]),
            jnp.asarray(scales["k"]),
            jnp.asarray(planes["vd"]), jnp.asarray(planes["vm"]),
            jnp.asarray(scales["v"]),
            jnp.asarray(bt, jnp.int32), jnp.asarray(seq_id, jnp.int32),
            jnp.asarray(pos, jnp.int32), jnp.asarray(hist, jnp.int32),
            jnp.asarray(tile_seq, jnp.int32), window=window, impl=impl,
            bq=bq))

    o_ref, o_pal = run("reference"), run("pallas")
    # ref and interpret-mode pallas walk the same stage order and f32
    # update arithmetic; XLA's fusion of the scanned oracle reorders the
    # multiply-add chain by at most a couple of ulps
    np.testing.assert_allclose(o_ref, o_pal, atol=5e-6, rtol=1e-5)
    dense = _dense_oracle(q, kc, vc, deq, seq_id, pos, hist,
                          KV, G, hd, window).reshape(C, KV * G, hd)
    for o in (o_ref, o_pal):
        np.testing.assert_allclose(o, dense, atol=1e-4, rtol=1e-4)
        # masking structure is exact: padding rows are exactly zero
        assert (o[seq_id < 0] == 0).all()


def test_chunked_kernel_chunk_only_bitwise():
    """With no cached pages (hist == 0 everywhere) the kernel reduces to
    segment-masked causal attention over float K/V — there ref and
    interpret-mode pallas agree bit for bit."""
    from repro.kernels.ops import sparq_chunked_prefill_attention
    rng = np.random.default_rng(1)
    S, NB, ps, KV, G, hd = 3, 4, 4, 2, 2, 8
    P, C, bq = 6, 16, 4
    z8 = jnp.zeros((P, ps, KV, hd), jnp.int8)
    sc = jnp.full((S,), 0.01, jnp.float32)
    bt = jnp.full((S, NB), -1, jnp.int32)
    seq_id = np.repeat(np.arange(4), 4)
    seq_id[seq_id == 3] = -1
    pos = np.tile(np.arange(4), 4)
    tile_seq = np.array([0, 1, 2, -1])
    q = jnp.asarray(rng.standard_normal((C, KV * G, hd)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((C, KV, hd)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((C, KV, hd)).astype(np.float32))

    def run(impl):
        return np.asarray(sparq_chunked_prefill_attention(
            q, kc, vc, z8, z8, sc, z8, z8, sc, bt,
            jnp.asarray(seq_id, jnp.int32), jnp.asarray(pos, jnp.int32),
            jnp.zeros(C, jnp.int32), jnp.asarray(tile_seq, jnp.int32),
            impl=impl, bq=bq))

    a, b = run("reference"), run("pallas")
    np.testing.assert_array_equal(a, b)
    assert (a[seq_id < 0] == 0).all()


# ----------------------------------------------------------------------
# direct write path: one chunk == contiguous prefill + adopt, byte-level
# ----------------------------------------------------------------------

def test_write_chunk_bytes_match_adopt_prefill(tiny_lm):
    """A whole prompt through one chunk writes bit-identical page bytes,
    frozen scales, and positions to the sequential contiguous-prefill +
    adopt_prefill path, and emits the same greedy tok0 — the direct-write
    §5.1 path is a true replacement, not an approximation."""
    from repro.models import paging
    model, params = tiny_lm
    cfg = model.cfg
    cc = _cc()
    S, NPAGES, NB, L = 2, 8, 4, 11
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, (L,))
    nbp = math.ceil(L / PS)

    def stores():
        out = []
        for kind, count in model.groups_meta:
            one = paging.PagedCacheStore.init(
                S, NPAGES, PS, NB, cfg.n_kv_heads, cfg.head_dim, cc)
            out.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape).copy(),
                one))
        return out

    # sequential: contiguous prefill + page adoption
    caches_a = stores()
    tmp = model.init_cache(1, nbp * PS, cache_cfg=cc)
    logits, tmp = model.prefill(params, {"tokens": jnp.asarray(toks)[None]},
                                tmp)
    tok0_a = int(np.asarray(jnp.argmax(logits, -1))[0])
    pages = jnp.arange(nbp, dtype=jnp.int32)
    caches_a = [paging.adopt_prefill(c, t, jnp.int32(0), pages)
                for c, t in zip(caches_a, tmp)]

    # chunked: one chunk covering the prompt, written straight to pages
    C, bq = 16, 4
    stream = np.zeros(C, np.int64)
    stream[:L] = toks
    seq_id = np.full(C, -1, np.int64)
    seq_id[:L] = 0
    pos = np.zeros(C, np.int64)
    pos[:L] = np.arange(L)
    tile_seq = np.full(C // bq, -1, np.int64)
    tile_seq[:math.ceil(L / bq)] = 0
    caches_b = stores()
    bt = np.full((S, NB), -1, np.int64)
    bt[0, :nbp] = np.arange(nbp)
    bt_dev = jnp.asarray(bt, jnp.int32)
    caches_b = [dataclasses.replace(
        c, block_table=jnp.broadcast_to(bt_dev, c.block_table.shape))
        for c in caches_b]
    meta = paging.ChunkMeta(
        seq_id=jnp.asarray(seq_id, jnp.int32),
        pos=jnp.asarray(pos, jnp.int32),
        hist=jnp.zeros(C, jnp.int32),
        tile_seq=jnp.asarray(tile_seq, jnp.int32),
        seq_pos_after=jnp.asarray([L, -1], jnp.int32))
    tok0_b, caches_b = model.prefill_chunk(
        params, jnp.asarray(stream)[None], caches_b, meta,
        jnp.asarray([L - 1, -1], jnp.int32))

    assert tok0_a == int(np.asarray(tok0_b)[0])
    for ca, cb in zip(caches_a, caches_b):
        for name in ("k_data", "k_meta", "v_data", "v_meta"):
            a = np.asarray(getattr(ca, name))[:, :nbp]
            b = np.asarray(getattr(cb, name))[:, :nbp]
            # only rows < L are logical; rows past the prompt are zero
            # init on both paths
            np.testing.assert_array_equal(
                a.reshape(a.shape[0], nbp * PS, *a.shape[3:])[:, :L],
                b.reshape(b.shape[0], nbp * PS, *b.shape[3:])[:, :L],
                err_msg=name)
        for name in ("k_scale", "v_scale"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ca, name))[:, 0],
                np.asarray(getattr(cb, name))[:, 0], err_msg=name)
        np.testing.assert_array_equal(np.asarray(ca.seq_pos),
                                      np.asarray(cb.seq_pos))


# ----------------------------------------------------------------------
# engine: chunked == sequential tokens; packing invariance; compile guard
# ----------------------------------------------------------------------

def _trace(model, seed=7):
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    lens = [5, 11, 3, 9, 14, 6]
    gens = [7, 5, 9, 6, 4, 8]
    arr = [0, 0, 2, 3, 5, 7]
    return [Request(rng.integers(0, model.cfg.vocab_size, (L,)), g,
                    arrive_at=a) for L, g, a in zip(lens, gens, arr)]


@pytest.mark.parametrize("codec", ["a8w8", "5opt"])
def test_chunked_prefill_token_equality(tiny_lm, codec):
    """Acceptance: greedy tokens bit-identical between --prefill
    sequential and --prefill chunked across a ragged staggered-arrival
    trace, for the plain-int8 grid and the 4-bit 5opt codec. Chunk size
    16 >= every prompt (single-segment regime: the guaranteed-exact
    window); runs straddle page boundaries (PS=4) throughout."""
    from repro.launch.serve import ContinuousBatchingEngine
    model, params = tiny_lm
    cc = _cc(SparqConfig(enabled=False, signed=True) if codec == "a8w8"
             else None)
    reqs = _trace(model)
    res_seq, _ = ContinuousBatchingEngine(
        model, cc, page_size=PS, n_pages=24, max_active=3,
        max_seq_len=24).run(params, reqs)
    res_ch, stats = ContinuousBatchingEngine(
        model, cc, page_size=PS, n_pages=24, max_active=3, max_seq_len=24,
        prefill="chunked", chunk_size=16, chunk_align=4).run(params, reqs)
    for rid in res_seq:
        np.testing.assert_array_equal(res_seq[rid], res_ch[rid])
    assert stats["prefill_chunks"] > 0
    assert stats["prefill_compile_count"] == 1


def test_multi_segment_prompts_are_packing_invariant(tiny_lm):
    """Prompts longer than the segment quantum attend their earlier
    segments through packed pages. Whole-segment packing makes the
    float-vs-packed split a function of (prompt, seg) only, so tokens
    must be identical under different chunk sizes, slot counts, and the
    resulting completely different stream packings."""
    from repro.launch.serve import ContinuousBatchingEngine
    model, params = tiny_lm
    reqs = _trace(model)
    outs = []
    for max_active, chunk in ((3, 16), (1, 16), (2, 24)):
        res, stats = ContinuousBatchingEngine(
            model, _cc(), page_size=PS, n_pages=24, max_active=max_active,
            max_seq_len=24, prefill="chunked", chunk_size=chunk,
            chunk_align=4, chunk_seg=8).run(params, reqs)
        assert stats["prefill_compile_count"] == 1
        outs.append(res)
    for res in outs[1:]:
        for rid in outs[0]:
            np.testing.assert_array_equal(outs[0][rid], res[rid])


def test_scale_freezes_from_first_segment_not_first_chunk(tiny_lm):
    """Regression (found in review): one 3-segment prompt, chunk sizes
    that place one / two / all three of its segments into the first
    chunk. The frozen quantization scale must come from the FIRST
    SEGMENT's dynamic range only — were it taken from whatever tokens
    share the first chunk (as an earlier draft did), the cache bytes and
    greedy tokens would differ across these packings."""
    from repro.launch.serve import ContinuousBatchingEngine, Request
    model, params = tiny_lm
    rng = np.random.default_rng(7)
    req = [Request(rng.integers(0, model.cfg.vocab_size, (12,)), 6)]
    outs = []
    for chunk in (8, 12, 16):           # 2 / 3 / 3 segments per chunk
        res, _ = ContinuousBatchingEngine(
            model, _cc(), page_size=PS, n_pages=24, max_active=2,
            max_seq_len=24, prefill="chunked", chunk_size=chunk,
            chunk_align=4, chunk_seg=4).run(params, req)
        outs.append(res[0])
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_compile_count_regression_guard(tiny_lm):
    """One jitted chunk program for every prompt-length mix. The dynamic
    smoke runs one ragged trace and checks the live jit cache; the
    second-trace sweep this test used to run is now the jaxpr auditor's
    job — it drives the real packer over a ragged mix abstractly and
    pins one signature (JX106), so the static check covers every length
    mix at a fraction of the cost. The sequential path's per-length
    retraces must never silently return."""
    from repro.launch.serve import ContinuousBatchingEngine, Request
    model, params = tiny_lm
    rng = np.random.default_rng(11)
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=24, max_active=3,
        max_seq_len=24, prefill="chunked", chunk_size=16, chunk_align=4)
    mk = lambda L, g: Request(rng.integers(0, model.cfg.vocab_size, (L,)), g)
    _, st1 = eng.run(params, [mk(3, 4), mk(7, 3), mk(11, 2), mk(5, 3)])
    assert st1["prefill_compile_count"] == 1
    # static counterpart: abstract trace of the registry's ragged mix
    from repro.analysis import audit_all
    from repro.analysis.registry import default_programs
    findings, counters = audit_all(default_programs())
    assert counters["jaxprs_per_program"]["prefill_chunk"] == 1
    assert not [f for f in findings if f.check == "JX106"], \
        "chunked prefill retraced for a new prompt-length mix"
    # the sequential path, by contrast, is shape-specialized per length:
    # its admission prefill jit accumulates one entry per unique shape
    eng_seq = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=24, max_active=3,
        max_seq_len=24)
    eng_seq.run(params, [mk(3, 2), mk(7, 2), mk(11, 2)])
    assert eng_seq._prefill._cache_size() >= 3


# ----------------------------------------------------------------------
# chunked prefill x preemption: requeue replays through the chunked path
# ----------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["requeue", "swap", "auto"])
def test_chunked_prefill_with_preemption(tiny_lm, mode):
    """Oversubscribed pool with chunked admission: victims drop or swap
    pages mid-flight (including mid-prefill and mid-replay victims, which
    force requeue) and every request still reproduces the uncontended
    contiguous tokens exactly — requeue re-prefills through the chunked
    path and replays its recorded tokens in-band through the regular
    decode steps."""
    from repro.launch.serve import (ContinuousBatchingEngine, DecodeEngine,
                                    Request, SchedulerPolicy)
    model, params = tiny_lm
    rng = np.random.default_rng(0)
    lens = [5, 7, 3, 6, 8, 4]
    gens = [12, 8, 9, 10, 6, 11]
    arr = [0, 0, 2, 3, 5, 7]
    reqs = [Request(rng.integers(0, model.cfg.vocab_size, (L,)), g,
                    arrive_at=a) for L, g, a in zip(lens, gens, arr)]
    contig = DecodeEngine(model, _cc())
    oracle = {}
    for rid, r in enumerate(reqs):
        t, _ = contig.generate(
            params, {"tokens": jnp.asarray(r.tokens)[None]}, r.gen,
            warmup=False)
        oracle[rid] = np.asarray(t)[0]
    eng = ContinuousBatchingEngine(
        model, _cc(), page_size=PS, n_pages=6, max_active=3,
        max_seq_len=24, prefill="chunked", chunk_size=16, chunk_align=4,
        chunk_seg=8, policy=SchedulerPolicy(preempt=mode))
    results, stats = eng.run(params, reqs)
    assert stats["preemptions"] > 0
    if mode == "requeue":
        assert stats["replay_steps"] > 0
        assert stats["swap_bytes_out"] == 0
    for rid in oracle:
        np.testing.assert_array_equal(results[rid], oracle[rid])
