"""Async streaming front-end over the paged engine (launch.frontend).

What is proven here:
  * streamed tokens are BIT-identical to a synchronous `engine.run`
    batch over the same requests — int8 (a8w8) and 4-bit 5opt codecs,
    chunked prefill, prefix cache on, requeue and swap preemption under
    a tight pool, with arrivals spread over wall time (the engine's
    exactness contract survives the asyncio/threading path end-to-end);
  * cancellation conserves pages: mid-prefill cancels drop the
    PrefillScheduler job and every granted page, mid-decode cancels run
    the eviction/release path (shared prefix pages refcount-released),
    both under the scheduler-trace `InvariantChecker` with the pool
    drained to empty afterwards;
  * `engine.reset_stats()` draws a clean warmup/measure boundary in a
    live serve-forever run: counters, prefix stats, and the page-pool
    peak watermark reflect only the traffic after the reset (regression
    for warmed-engine benchmark runs inheriting warmup state);
  * the idle fast-forward admits interleaved arrivals in arrival order
    (regression: the old fast-forward jumped the clock to the head of
    the *initial* queue, skipping requests submitted mid-run with
    earlier arrival times);
  * a TP=2 engine streams the same tokens (subprocess row reusing the
    test_tp_serving self-provisioning pattern).
"""
import asyncio
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparq import SparqConfig
from repro.launch import frontend
from repro.launch.serve import (ContinuousBatchingEngine, Request,
                                SchedulerPolicy)
from repro.models.cache import CacheConfig

from test_scheduler import InvariantChecker

KEY = jax.random.PRNGKey(0)
PS = 4
MAX_SEQ_LEN = 24

CODECS = {
    "a8w8": lambda: SparqConfig(enabled=False, signed=True),
    "5opt": lambda: SparqConfig.opt5(signed=True),
}


def _cc(codec_name: str) -> CacheConfig:
    return dataclasses.replace(
        CacheConfig.sparq_cache(CODECS[codec_name](), impl="reference"),
        attn_bk=PS)


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    return model, params


def _engine(model, codec_name="5opt", policy_mode="requeue", n_pages=10,
            mesh=None, **kw):
    kw.setdefault("prefill", "chunked")
    if kw["prefill"] == "chunked":
        kw.setdefault("chunk_size", 16)
        kw.setdefault("chunk_align", 4)
        kw.setdefault("chunk_seg", 2)
        kw.setdefault("prefix_cache", True)
    return ContinuousBatchingEngine(
        model, _cc(codec_name), page_size=PS, n_pages=n_pages,
        max_active=3, max_seq_len=MAX_SEQ_LEN,
        policy=SchedulerPolicy(preempt=policy_mode, victim="last_joined"),
        mesh=mesh, **kw)


def _shared_trace(model, seed=7):
    """Shared 2-page preamble + ragged tails + one exact duplicate:
    prefix hits and CoW happen while requests overlap in wall time."""
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab_size
    preamble = rng.integers(0, vocab, (8,))
    rows = []
    for i in range(4):
        tail = rng.integers(0, vocab,
                            (4 if i == 0 else int(rng.integers(1, 5)),))
        rows.append((np.concatenate([preamble, tail]),
                     int(rng.integers(6, 11)), 0.03 * i))
    rows.append((rows[0][0].copy(), 7, 0.05))   # duplicate of row 0
    return rows


def _drained_pool(eng):
    """Post-run page accounting: every page back on the free list."""
    al = eng._debug_state["allocator"]
    al.assert_consistent()
    assert al.used_count == 0, "run left pages allocated"


# ----------------------------------------------------------------------
# streamed tokens == synchronous batch tokens
# ----------------------------------------------------------------------

@pytest.mark.parametrize("codec_name,policy_mode",
                         [("a8w8", "requeue"), ("a8w8", "swap"),
                          ("5opt", "requeue"), ("5opt", "swap")],
                         ids=["a8w8-requeue", "a8w8-swap",
                              "5opt-requeue", "5opt-swap"])
def test_streamed_tokens_match_batch(tiny_lm, codec_name, policy_mode):
    """play_trace over wall-clock arrivals streams exactly the tokens a
    synchronous engine.run emits for the same requests: scheduling,
    arrival jitter, preemption, and the prefix cache never change
    tokens, and neither may the asyncio/threading path."""
    model, params = tiny_lm
    rows = _shared_trace(model)
    eng = _engine(model, codec_name, policy_mode)
    oracle, ostats = eng.run(
        params, [Request(t, g) for t, g, _ in rows])
    check = InvariantChecker(ps=PS)
    out, slo, stats = frontend.play_trace(eng, params, rows,
                                          trace_hook=check)
    for i in range(len(rows)):
        np.testing.assert_array_equal(out[i], oracle[i])
        assert out[i].shape == (rows[i][1],)
    assert slo["requests"] == len(rows)
    assert stats["clock_mode"] == "wall"
    assert stats["cancelled"] == 0
    # SLO accounting is well-formed: TTFT per request, one ITL sample
    # per follow-on token
    assert slo["ttft"]["n"] == len(rows)
    assert slo["itl"]["n"] == sum(g - 1 for _, g, _ in rows)
    assert slo["ttft"]["p50_ms"] > 0
    _drained_pool(eng)


def test_stream_events_are_ordered_and_final(tiny_lm):
    """Every stream carries monotone timestamps and exactly one final
    event, and the async iterator protocol terminates cleanly."""
    model, params = tiny_lm
    rows = _shared_trace(model)[:3]
    eng = _engine(model)

    async def main():
        fe = frontend.AsyncFrontend(eng, params)
        await fe.start()
        handles = [fe.submit(t, g, at=at) for t, g, at in rows]
        for h in handles:
            await h.drain()
        await fe.stop()
        return handles

    handles = asyncio.run(main())
    for h, (_, g, _) in zip(handles, rows):
        assert len(h.events) == g
        assert [e.final for e in h.events] == [False] * (g - 1) + [True]
        ts = [e.t for e in h.events]
        assert ts == sorted(ts)
    _drained_pool(eng)


# ----------------------------------------------------------------------
# cancellation maps onto eviction/release and conserves pages
# ----------------------------------------------------------------------

def test_cancel_mid_prefill_conserves_pages(tiny_lm):
    """Cancelling a request whose chunked prefill is still streaming
    drops its PrefillScheduler job and every granted page; other
    requests are untouched."""
    model, params = tiny_lm
    rng = np.random.default_rng(11)
    vocab = model.cfg.vocab_size
    # rid 0 decodes from step 0; rid 1's 16-token prompt prefills in
    # 2-token segments while rid 0 decodes, so the first decode steps
    # see it mid-prefill
    reqs = [Request(rng.integers(0, vocab, (4,)), 12, arrive_at=0),
            Request(rng.integers(0, vocab, (16,)), 8, arrive_at=1)]
    eng = _engine(model, n_pages=12, chunk_size=4, prefix_cache=False)
    oracle, _ = eng.run(params, [Request(reqs[0].tokens, reqs[0].gen)])

    check = InvariantChecker(ps=PS)
    state = {"cancelled_mid_prefill": False}

    def hook(snap):
        check(snap)
        pre = snap.get("prefilling", ())
        if pre and not state["cancelled_mid_prefill"]:
            state["cancelled_mid_prefill"] = True
            eng.cancel(1)

    results, stats = eng.run(params, reqs, trace_hook=hook)
    assert state["cancelled_mid_prefill"], \
        "setup failed: rid 1 was never observed mid-prefill"
    assert stats["cancelled"] == 1
    np.testing.assert_array_equal(results[0], oracle[0])
    assert 1 not in results
    _drained_pool(eng)


def test_cancel_mid_decode_releases_shared_pages(tiny_lm):
    """Mid-decode cancellation through the async API: the victim's
    stream closes with its partial tokens, survivors stream to
    completion bit-identically, and the victim's pages — including
    refcounted shared-prefix pages — return to the pool."""
    import time as _time
    model, params = tiny_lm
    rows = _shared_trace(model)
    victim_i = 4                        # the duplicate: shares pages
    toks_v, _, at_v = rows[victim_i]
    rows[victim_i] = (toks_v, 12, at_v)     # long budget: cancel bites
    eng = _engine(model)
    oracle, _ = eng.run(params, [Request(t, g) for t, g, _ in rows])
    check = InvariantChecker(ps=PS)

    def hook(snap):
        check(snap)
        # pace the engine so the event loop's cancel deterministically
        # lands while the victim is still decoding (not a busy-wait:
        # ~5 ms per step against a ~µs cancel round-trip)
        _time.sleep(0.005)

    async def main():
        fe = frontend.AsyncFrontend(eng, params, trace_hook=hook)
        await fe.start()
        handles = [fe.submit(t, g, at=at) for t, g, at in rows]
        victim = handles[victim_i]
        async for ev in victim:
            if len(victim.events) >= 2:
                victim.cancel()
        for i, h in enumerate(handles):
            if i != victim_i:
                await h.drain()
        _, stats = await fe.stop()
        return handles, stats

    handles, stats = asyncio.run(main())
    victim = handles[victim_i]
    assert victim.cancelled
    assert stats["cancelled"] == 1
    assert 2 <= len(victim.events) < rows[victim_i][1]
    # the tokens it did stream are a prefix of the oracle's
    np.testing.assert_array_equal(
        victim.tokens, oracle[victim_i][:len(victim.events)])
    for i, h in enumerate(handles):
        if i != victim_i:
            np.testing.assert_array_equal(h.tokens, oracle[i])
    assert eng._live is None            # loop exited
    _drained_pool(eng)


def test_cancel_queued_request_never_admits(tiny_lm):
    """Cancelling a request still waiting in the arrival queue removes
    it without it ever touching a slot."""
    model, params = tiny_lm
    rng = np.random.default_rng(3)
    vocab = model.cfg.vocab_size
    reqs = [Request(rng.integers(0, vocab, (4,)), 6, arrive_at=0),
            Request(rng.integers(0, vocab, (4,)), 6, arrive_at=100)]
    eng = _engine(model, prefix_cache=False)
    seen = set()

    def hook(snap):
        for info in snap["slots"].values():
            seen.add(info["rid"])
        eng.cancel(1)                   # idempotent; rid 1 still queued

    results, stats = eng.run(params, reqs, trace_hook=hook)
    assert stats["cancelled"] == 1
    assert seen == {0} and 1 not in results
    _drained_pool(eng)


# ----------------------------------------------------------------------
# reset_stats: the warmup/measure boundary (regression)
# ----------------------------------------------------------------------

def test_warmup_does_not_pollute_measured_stats(tiny_lm):
    """A warmed serve-forever run reports only the timed trace: without
    the reset_stats() boundary the stats would inherit the warmup's
    prefix hits, decode steps, and the pool-peak watermark (this test
    fails if play_trace stops calling engine.reset_stats)."""
    model, params = tiny_lm
    rng = np.random.default_rng(5)
    vocab = model.cfg.vocab_size
    # warmup: 4 concurrent copies of one prompt -> prefix hits, high
    # concurrent page peak
    warm_prompt = rng.integers(0, vocab, (8,))
    warmup = [(warm_prompt, 6) for _ in range(4)]
    # timed trace: two DISTINCT prompts far apart in wall time -> zero
    # hits, peak = one resident request
    rows = [(rng.integers(0, vocab, (8,)), 5, 0.0),
            (rng.integers(0, vocab, (8,)), 5, 0.8)]
    eng = _engine(model, n_pages=16)
    out, slo, stats = frontend.play_trace(eng, params, rows,
                                          warmup=warmup)
    # prefix stats: the warmup's hits/misses are erased; the timed rows
    # are distinct fresh prompts (warmup pages were refcount-released,
    # so they cannot hit either)
    assert stats["prefix_hits"] == 0
    assert stats["prefix_misses"] == 2
    # pool peak: one request needs ceil((8+5-1)/4)=3 pages; the warmup's
    # 4 concurrent requests held >= 8. The watermark must be the trace's.
    assert stats["peak_pages_used"] <= 4, \
        f"peak {stats['peak_pages_used']} inherited from warmup"
    # timings/counters restart at the boundary
    assert stats["cancelled"] == 0
    assert stats["decode_steps"] <= sum(g for _, g, _ in rows)
    for i in range(len(rows)):
        assert out[i].shape == (rows[i][1],)
    _drained_pool(eng)


# ----------------------------------------------------------------------
# TP=2: streamed tokens match the sync engine on a sharded mesh
# ----------------------------------------------------------------------

@pytest.mark.skipif(
    os.environ.get("REPRO_TP_FRONTEND_SUBPROCESS") != "1"
    and len(jax.devices()) < 2,
    reason="needs >= 2 devices (see subprocess wrapper below)")
def test_tp2_streamed_matches_sync():
    """The async front-end over a TP=2 engine: same threading model, but
    every decode step now runs a shard_map program over the mesh — the
    per-step batched device_get and the streamed tokens must be
    unchanged."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 forced devices")
    from repro.configs.base import get_reduced_config
    from repro.launch.mesh import make_tp_mesh
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False, n_heads=16, n_kv_heads=8)
    model = Model(cfg)
    params = model.init_params(KEY)
    rows = _shared_trace(model)[:3]
    eng = _engine(model, mesh=make_tp_mesh(2))
    oracle, ostats = eng.run(params, [Request(t, g) for t, g, _ in rows])
    assert ostats["tp"] == 2
    out, slo, stats = frontend.play_trace(eng, params, rows)
    for i in range(len(rows)):
        np.testing.assert_array_equal(out[i], oracle[i])
    assert stats["tp"] == 2
    _drained_pool(eng)


@pytest.mark.skipif(
    len(jax.devices()) >= 2,
    reason="in-process TP frontend test already ran on this mesh")
@pytest.mark.skipif(
    os.environ.get("REPRO_TP_FRONTEND_SUBPROCESS") == "1",
    reason="already inside the forced-device subprocess")
def test_tp2_frontend_in_forced_device_subprocess():
    """Single-device runs still cover the TP=2 streaming row: re-spawn
    pytest on this file with forced CPU devices (the test_tp_serving
    self-provisioning pattern)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_TP_FRONTEND_SUBPROCESS"] = "1"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", os.path.abspath(__file__), "-q",
         "-p", "no:cacheprovider", "-k", "tp2_streamed_matches_sync"],
        cwd=root, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"TP frontend subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    assert "1 passed" in proc.stdout, proc.stdout
