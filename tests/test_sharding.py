"""Unit tests for distributed/sharding.py spec fitting and the
error-feedback gradient compressor.

`fit_spec` only ever touches `mesh.shape` (a name->size mapping), so a
duck-typed FakeMesh lets the whole grid run on a single CPU device with
arbitrary pretend topologies. The property tests follow the repo's
hypothesis-optional convention: hypothesis drives them when installed,
and a deterministic sweep covers the same invariants when it is not.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import GradCompressor, sparq_compress
from repro.distributed.sharding import (fit_spec, paged_pool_pspecs,
                                        pool_plane_pspec)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    HAVE_HYPOTHESIS = False


@dataclasses.dataclass
class FakeMesh:
    """fit_spec/_axis_size only read mesh.shape[name]."""
    shape: dict


MESH = FakeMesh({"pod": 2, "data": 8, "model": 16})


# ----------------------------------------------------------------------
# fit_spec: axis dropping, tuple-suffix fallback, shape/spec zip edges
# ----------------------------------------------------------------------

class TestFitSpec:
    @pytest.mark.parametrize("shape,spec,want", [
        # divisible: spec survives untouched
        ((256, 1024), P("data", "model"), P("data", "model")),
        # 51865 % 16 != 0: the model axis is dropped, not rounded
        ((51865, 768), P("model", "data"), P(None, "data")),
        # both axes non-divisible
        ((7, 9), P("data", "model"), P(None, None)),
        # None entries pass through
        ((64, 100, 32), P("data", None, "model"), P("data", None, "model")),
    ])
    def test_axis_dropping_grid(self, shape, spec, want):
        assert fit_spec(shape, spec, MESH) == want

    @pytest.mark.parametrize("dim,want", [
        (512, ("pod", "data", "model")),   # 2*8*16=256 divides 512
        (256, ("pod", "data", "model")),
        (128, ("data", "model")),          # 256 no, 8*16=128 yes
        (16, "model"),                     # only the last singleton fits
        (8, None),                         # nothing fits -> replicate
    ])
    def test_tuple_suffix_dp_fallback(self, dim, want):
        """Merged DP groups degrade suffix-by-suffix instead of jumping
        straight to replication; a single-name suffix is unwrapped from
        its tuple."""
        spec = fit_spec((dim, 64), P(("pod", "data", "model"), None), MESH)
        assert spec == P(want, None)

    def test_spec_shorter_than_shape_pads_none(self):
        assert fit_spec((64, 32, 16, 8), P("data"), MESH) == \
            P("data", None, None, None)

    def test_empty_spec_on_any_rank(self):
        assert fit_spec((3, 4, 5), P(), MESH) == P(None, None, None)

    def test_zero_dim_never_sharded(self):
        # dim > 0 guard: 0 % n == 0 numerically, but an empty dim must
        # not claim a mesh axis
        assert fit_spec((0, 64), P("data", "model"), MESH) == \
            P(None, "model")
        assert fit_spec((0,), P(("pod", "data"),), MESH) == P(None)

    if HAVE_HYPOTHESIS:
        @given(dim=st.integers(0, 4096),
               axes=st.lists(st.sampled_from(["pod", "data", "model"]),
                             min_size=1, max_size=3, unique=True))
        @settings(max_examples=200, deadline=None)
        def test_property_fitted_spec_always_divides(self, dim, axes):
            self._check_divides(dim, tuple(axes))
    else:
        def test_property_fitted_spec_always_divides_fallback(self):
            """Deterministic sweep mirroring the hypothesis property."""
            groups = [("pod",), ("data",), ("model",),
                      ("pod", "data"), ("data", "model"),
                      ("pod", "data", "model")]
            for dim in list(range(0, 64)) + [100, 128, 255, 256, 51865]:
                for axes in groups:
                    self._check_divides(dim, axes)

    @staticmethod
    def _check_divides(dim, axes):
        spec = fit_spec((dim,), P(axes), MESH)
        fitted = spec[0]
        if fitted is None:
            return
        names = fitted if isinstance(fitted, tuple) else (fitted,)
        size = 1
        for a in names:
            size *= MESH.shape[a]
        assert dim > 0 and dim % size == 0
        # the fitted group is always a suffix of the requested one
        assert tuple(names) == tuple(axes[len(axes) - len(names):])


# ----------------------------------------------------------------------
# paged-pool specs (TP serving)
# ----------------------------------------------------------------------

class TestPoolSpecs:
    def test_plane_pspec_targets_kv_head_axis(self):
        # packed plane [P, ps, KV, 2*hd] and stacked [L, P, ps, KV, hd]
        assert pool_plane_pspec(4) == P(None, None, "model", None)
        assert pool_plane_pspec(5) == P(None, None, None, "model", None)

    def test_store_tree_pools_shard_bookkeeping_replicated(self):
        from repro.launch.serve import ContinuousBatchingEngine  # noqa: F401
        from repro.models.paging import PagedCacheStore
        from repro.models.cache import CacheConfig
        from repro.core.sparq import SparqConfig

        cc = CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                     impl="reference")
        store = jax.eval_shape(
            lambda: PagedCacheStore.init(
                n_seqs=2, n_pages=8, page_size=4, n_blocks=4,
                kv_heads=2, head_dim=16, cc=cc))
        specs = paged_pool_pspecs(store)
        for name in ("k_data", "k_meta", "v_data", "v_meta"):
            plane = getattr(store, name)
            spec = getattr(specs, name)
            assert spec[plane.ndim - 2] == "model"
            assert all(s is None for i, s in enumerate(spec)
                       if i != plane.ndim - 2)
        for name in ("k_scale", "v_scale", "block_table", "seq_pos"):
            assert getattr(specs, name) == P()


# ----------------------------------------------------------------------
# GradCompressor: error feedback
# ----------------------------------------------------------------------

def _grads():
    k = jax.random.PRNGKey(0)
    return {
        "big": jax.random.normal(k, (128, 64), jnp.float32),   # 8192 elems
        "tiny": jnp.arange(8, dtype=jnp.float32) - 3.5,        # < min_size
    }


class TestGradCompressor:
    def test_residual_carries_quantization_error(self):
        comp = GradCompressor(bits=4, min_size=4096)
        g = _grads()
        state = comp.init(g)
        assert jnp.all(state["big"] == 0) and jnp.all(state["tiny"] == 0)
        c, resid = comp.compress(g, state)
        # compressed + residual reconstructs the target exactly
        assert jnp.allclose(c["big"] + resid["big"], g["big"],
                            atol=1e-6)
        # the compressor really did quantize (lossy on gaussian data)
        assert float(jnp.max(jnp.abs(resid["big"]))) > 0

    def test_small_leaf_exact_with_zero_residual(self):
        comp = GradCompressor(bits=4, min_size=4096)
        g = _grads()
        c, resid = comp.compress(g, comp.init(g))
        assert jnp.array_equal(c["tiny"], g["tiny"])
        assert jnp.all(resid["tiny"] == 0)

    def test_error_feedback_is_unbiased_over_steps(self):
        """Feeding the residual back makes the *sum* of transmitted
        gradients track the sum of true gradients: after N identical
        steps, sum(compressed) + final_residual == N * g."""
        comp = GradCompressor(bits=4, min_size=4096)
        g = _grads()
        state = comp.init(g)
        total = jnp.zeros_like(g["big"])
        for _ in range(5):
            c, state = comp.compress(g, state)
            total = total + c["big"]
        assert jnp.allclose(total + state["big"], 5.0 * g["big"],
                            atol=1e-4)

    def test_residual_matches_sparq_compress_directly(self):
        comp = GradCompressor(bits=4, min_size=4096)
        g = _grads()
        state = comp.init(g)
        # second step: target = g + residual, residual = target - Q(target)
        _, state = comp.compress(g, state)
        c2, resid2 = comp.compress(g, state)
        target = g["big"] + state["big"]
        want = sparq_compress(target, 4)
        assert jnp.allclose(c2["big"], want, atol=1e-6)
        assert jnp.allclose(resid2["big"], target - want, atol=1e-6)
