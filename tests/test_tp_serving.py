"""Tensor-parallel paged serving: the multi-device acceptance harness.

The in-process tests need >= 8 devices, which CPU-only CI gets from
`XLA_FLAGS=--xla_force_host_platform_device_count=8` (the CI multidevice
job sets it; so does the subprocess wrapper at the bottom, which lets a
plain single-device `pytest` run still exercise a bounded TP slice by
re-spawning itself with the flag).

What is proven here:
  * greedy tokens are BIT-identical between TP=1 and TP in {2,4,8} for
    int8 (a8w8) and 4-bit 5opt codecs, with chunked prefill, the prefix
    cache on, and both preemption policies under a deliberately tight
    pool — sharding the packed pools by KV head must not change a single
    sampled token (see docs/sharding.md for why this holds exactly);
  * per-device pool bytes are global_data_ctrl/TP + replicated
    bookkeeping, and the planes are physically sharded on the mesh;
  * the scheduler-trace `InvariantChecker` from test_scheduler replays
    cleanly against a sharded engine (host-global allocator contract).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparq import SparqConfig
from repro.models import paging
from repro.models.cache import CacheConfig

from test_scheduler import InvariantChecker, _make_shared_trace

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

KEY = jax.random.PRNGKey(0)
PS = 4
N_PAGES = 8             # tight: the shared trace wants ~30 pages at peak
MAX_ACTIVE = 3
MAX_SEQ_LEN = 24

CODECS = {
    "a8w8": lambda: SparqConfig(enabled=False, signed=True),
    "5opt": lambda: SparqConfig.opt5(signed=True),
}


def _cc(codec_name: str) -> CacheConfig:
    return dataclasses.replace(
        CacheConfig.sparq_cache(CODECS[codec_name](), impl="reference"),
        attn_bk=PS)


@pytest.fixture(scope="module")
def tp_lm():
    """Reduced tinyllama widened to 8 KV heads so one model serves every
    TP degree in {2,4,8} (8 % tp == 0; head groups of G=2 never split)."""
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False, n_heads=16, n_kv_heads=8)
    model = Model(cfg)
    params = model.init_params(KEY)
    return model, params


def _trace(model):
    """test_scheduler's shared-system-prompt trace: a common 2-page
    preamble, ragged tails, two exact duplicates, staggered arrivals —
    the proven recipe for real prefix hits + CoW under a tight pool."""
    return _make_shared_trace(seed=7, vocab=model.cfg.vocab_size)


def _engine(model, codec_name, policy_mode, tp):
    from repro.launch.mesh import make_tp_mesh
    from repro.launch.serve import ContinuousBatchingEngine, SchedulerPolicy
    return ContinuousBatchingEngine(
        model, _cc(codec_name), page_size=PS, n_pages=N_PAGES,
        max_active=MAX_ACTIVE, max_seq_len=MAX_SEQ_LEN,
        policy=SchedulerPolicy(preempt=policy_mode, victim="last_joined"),
        prefill="chunked", chunk_size=16, chunk_align=4, chunk_seg=2,
        prefix_cache=True, mesh=make_tp_mesh(tp) if tp > 1 else None)


_BASELINE = {}


def _baseline(tp_lm, codec_name):
    """TP=1 greedy tokens for one codec, computed once per module run."""
    if codec_name not in _BASELINE:
        model, params = tp_lm
        eng = _engine(model, codec_name, "requeue", tp=1)
        results, stats = eng.run(params, _trace(model))
        assert stats["tp"] == 1
        _BASELINE[codec_name] = results
    return _BASELINE[codec_name]


# ----------------------------------------------------------------------
# bit-identical tokens TP=1 vs TP in {2,4,8}, both codecs, both policies
# ----------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("tp,codec_name,policy_mode", [
    (2, "a8w8", "requeue"),
    (2, "5opt", "swap"),
    (4, "a8w8", "swap"),
    (4, "5opt", "requeue"),
    (8, "a8w8", "requeue"),
    (8, "5opt", "swap"),
], ids=["tp2-a8w8-requeue", "tp2-5opt-swap", "tp4-a8w8-swap",
        "tp4-5opt-requeue", "tp8-a8w8-requeue", "tp8-5opt-swap"])
def test_tp_token_equality(tp_lm, tp, codec_name, policy_mode):
    model, params = tp_lm
    eng = _engine(model, codec_name, policy_mode, tp)
    check = InvariantChecker(ps=PS)     # scheduler-trace replay, sharded
    results, stats = eng.run(params, _trace(model), trace_hook=check)
    assert stats["tp"] == tp
    assert check.steps == stats["decode_steps"] > 0
    # the run really exercised the contended paths it claims to cover
    assert stats["preemptions"] > 0, "pool not tight enough"
    assert stats["prefix_hits"] > 0 and stats["prefix_shared_pages"] > 0
    if policy_mode == "swap":
        assert stats["swap_bytes_out"] > 0
    base = _baseline(tp_lm, codec_name)
    assert set(results) == set(base)
    for rid in base:
        np.testing.assert_array_equal(results[rid], base[rid])


# ----------------------------------------------------------------------
# per-device pool accounting + physical plane sharding
# ----------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("tp", [1, 2, 4, 8])
def test_per_device_pool_accounting(tp_lm, tp):
    model, _ = tp_lm
    eng = _engine(model, "5opt", "requeue", tp)
    stores = jax.eval_shape(eng._init_stores)
    glob = paging.modeled_pool_bytes(stores)
    per = paging.modeled_pool_bytes_per_device(stores)
    assert per["tp"] == tp
    # packed data + ShiftCtrl side-band shard 1/tp; bookkeeping is global
    assert per["data_bytes"] == glob["data_bytes"] / tp
    assert per["ctrl_bytes"] == glob["ctrl_bytes"] / tp
    assert per["other_bytes"] == glob["other_bytes"]
    assert per["total_bytes"] == pytest.approx(
        (glob["data_bytes"] + glob["ctrl_bytes"]) / tp + glob["other_bytes"])
    if tp == 1:
        assert per["total_bytes"] == glob["total_bytes"]


@needs8
def test_pool_planes_physically_sharded(tp_lm):
    model, _ = tp_lm
    eng = _engine(model, "5opt", "requeue", tp=4)
    stores = eng._init_stores()
    first = jax.tree.leaves(
        jax.tree.map(lambda s: s, stores,
                     is_leaf=lambda n: isinstance(n, paging.PagedCacheStore)),
        is_leaf=lambda n: isinstance(n, paging.PagedCacheStore))[0]
    for name in ("k_data", "k_meta", "v_data", "v_meta"):
        plane = getattr(first, name)
        shard = plane.sharding.shard_shape(plane.shape)
        kv_ax = plane.ndim - 2
        assert shard[kv_ax] == plane.shape[kv_ax] // 4, name
        assert all(shard[i] == plane.shape[i]
                   for i in range(plane.ndim) if i != kv_ax), name
    # bookkeeping stays replicated on every device
    for name in ("k_scale", "v_scale", "block_table", "seq_pos"):
        arr = getattr(first, name)
        assert arr.sharding.shard_shape(arr.shape) == arr.shape, name


@needs8
def test_kv_head_divisibility_guard(tp_lm):
    """TP that would split a head group is rejected up front."""
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    model = Model(get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False))          # n_kv_heads=2
    with pytest.raises(ValueError, match="n_kv_heads"):
        _engine(model, "5opt", "requeue", tp=8)


# ----------------------------------------------------------------------
# self-provisioning wrapper: one bounded TP slice under plain tier-1
# ----------------------------------------------------------------------

@pytest.mark.skipif(
    N_DEV >= 8, reason="in-process TP tests already ran on this mesh")
@pytest.mark.skipif(
    os.environ.get("REPRO_TP_SUBPROCESS") == "1",
    reason="already inside the forced-device subprocess")
def test_tp_slice_in_forced_device_subprocess():
    """Single-device runs still get TP coverage: re-spawn pytest on this
    file with the forced 8-device CPU flag and a bounded `-k` slice (one
    token-equality cell + the accounting grid + the guard). The full
    matrix runs in CI's multidevice job."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_TP_SUBPROCESS"] = "1"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", os.path.abspath(__file__), "-q",
         "-p", "no:cacheprovider",
         "-k", ("tp2-a8w8-requeue or per_device_pool_accounting "
                "or divisibility_guard")],
        cwd=root, env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"TP subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    # the -k slice selects 6 tests; none may be skipped for device count
    assert "6 passed" in proc.stdout, proc.stdout
