"""vSPARQ pairing semantics (paper §3.2, Eq. 2) + STC grouped path (§5.3).

Property-based tests need `hypothesis`; when it is absent they are skipped
(the worked examples and the deterministic smoke sweep still run, so the
module always tests something)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare CI images
    HAVE_HYPOTHESIS = False

from repro.core.bsparq import bsparq_recon, shifts_for
from repro.core.vsparq import vsparq_recon, vsparq_recon_signed, vsparq_recon_grouped
from repro.core.pruning import prune_2_4, keep_indices, sparsity

SH = shifts_for(4, 5)


class TestEq2:
    def test_partner_zero_keeps_full_precision(self):
        # (x, 0): x keeps 8 bits even if not representable in 4-bit window
        x = jnp.asarray([27, 0, 0, 91])
        r = np.asarray(vsparq_recon(x, 4, SH, False))
        np.testing.assert_array_equal(r, [27, 0, 0, 91])

    def test_both_nonzero_both_trimmed(self):
        x = jnp.asarray([27, 91])  # both non-zero -> both bSPARQ'd
        r = np.asarray(vsparq_recon(x, 4, SH, False))
        expect = np.asarray(bsparq_recon(x, 4, SH, False))
        np.testing.assert_array_equal(r, expect)
        assert r[0] == 26  # paper example value

    def test_mixed_pairs(self):
        x = jnp.asarray([[27, 91, 27, 0],
                         [0, 255, 13, 13]])
        r = np.asarray(vsparq_recon(x, 4, SH, False))
        np.testing.assert_array_equal(r[0], [26, 88, 27, 0])
        np.testing.assert_array_equal(r[1], [0, 255, 13, 13])

    def test_error_never_above_bsparq_smoke(self):
        """Deterministic version of the hypothesis property below: vSPARQ
        only ever *upgrades* precision vs plain bSPARQ (Eq. 2), swept over
        every (even, odd) uint8 pair built from a stride-7 lattice plus all
        pairs containing a zero."""
        a = np.arange(0, 256, 7)
        pairs = np.stack(np.meshgrid(a, a), -1).reshape(-1, 2)
        zeros = np.stack([np.arange(256), np.zeros(256, int)], -1)
        x = np.concatenate([pairs, zeros, zeros[:, ::-1]]).reshape(-1)
        rv = np.asarray(vsparq_recon(jnp.asarray(x), 4, SH, True))
        rb = np.asarray(bsparq_recon(jnp.asarray(x), 4, SH, True))
        assert (np.abs(x - rv) <= np.abs(x - rb)).all()


if HAVE_HYPOTHESIS:
    class TestEq2Properties:
        @given(st.lists(st.integers(0, 255), min_size=2, max_size=128)
               .filter(lambda v: len(v) % 2 == 0))
        @settings(max_examples=100, deadline=None)
        def test_error_never_above_bsparq(self, xs):
            """vSPARQ only ever *upgrades* precision vs bSPARQ (Eq. 2)."""
            x = np.asarray(xs)
            rv = np.asarray(vsparq_recon(jnp.asarray(x), 4, SH, True))
            rb = np.asarray(bsparq_recon(jnp.asarray(x), 4, SH, True))
            assert (np.abs(x - rv) <= np.abs(x - rb)).all()

        @given(st.lists(st.integers(-127, 127), min_size=2, max_size=64)
               .filter(lambda v: len(v) % 2 == 0))
        @settings(max_examples=50, deadline=None)
        def test_signed_pairing(self, xs):
            x = np.asarray(xs)
            r = np.asarray(vsparq_recon_signed(jnp.asarray(x), 4, SH, True))
            # zero-partner lanes are exact
            pairs = x.reshape(-1, 2)
            rp = r.reshape(-1, 2)
            zero_partner = pairs == 0
            keeps = zero_partner[:, ::-1]  # lane keeps precision if partner zero
            np.testing.assert_array_equal(rp[keeps], pairs[keeps])


class TestSparseTensorCore:
    def test_prune_2_4_sparsity(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        pw = prune_2_4(w, axis=0)
        assert abs(sparsity(pw) - 0.5) < 1e-6
        # surviving weights are the larger-magnitude half of each group
        g = np.abs(np.asarray(w)).T.reshape(32, -1, 4)
        pg = np.asarray(pw).T.reshape(32, -1, 4)
        kept_mag = np.where(pg != 0, g, 0).sum(-1)
        top2 = np.sort(g, axis=-1)[..., 2:].sum(-1)
        np.testing.assert_allclose(kept_mag, top2, rtol=1e-6)

    def test_keep_indices_match_pruned(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        idx = np.asarray(keep_indices(w, axis=0))  # [8, 4, 2]
        pw = np.asarray(prune_2_4(w, axis=0))
        for o in range(8):
            for gidx in range(4):
                nz = np.nonzero(pw[gidx * 4:(gidx + 1) * 4, o])[0]
                np.testing.assert_array_equal(np.sort(nz), np.sort(idx[o, gidx]))

    def test_grouped_recon_pairs_selected_lanes(self):
        # group of 4 with keep_idx selecting lanes 1,3; lane1=0 -> lane3 full
        x = jnp.asarray([5, 0, 7, 91])
        keep = jnp.asarray([[1, 3]])
        r = np.asarray(vsparq_recon_grouped(x, keep, 4, SH, False))
        assert r[3] == 91  # full precision: partner (lane 1) is zero
        assert r[1] == 0
        # unselected lanes pass through
        assert r[0] == 5 and r[2] == 7

    def test_grouped_recon_both_nonzero(self):
        x = jnp.asarray([5, 33, 7, 91])
        keep = jnp.asarray([[1, 3]])
        r = np.asarray(vsparq_recon_grouped(x, keep, 4, SH, False))
        expect = np.asarray(bsparq_recon(jnp.asarray([33, 91]), 4, SH, False))
        assert r[1] == expect[0] and r[3] == expect[1]
