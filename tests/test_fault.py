"""Simulated-cluster tests for distributed/fault.py.

Everything runs on injected clocks (`now=` params) and synthetic step
times — no `time.time()` in any assertion, so the suite is deterministic
on arbitrarily loaded CI hosts. The scenario test at the bottom drives a
whole simulated fleet through warmup, a straggling host, a silent death,
and the elastic remesh + checkpoint-restore decision that follows.
"""
import dataclasses

import pytest

from repro.distributed.fault import (ElasticCoordinator, HeartbeatMonitor,
                                     RemeshPlan, StragglerDetector,
                                     plan_remesh)


# ----------------------------------------------------------------------
# HeartbeatMonitor
# ----------------------------------------------------------------------

def test_heartbeat_dead_after_timeout():
    mon = HeartbeatMonitor(timeout_s=10.0)
    mon.beat(0, step=1, now=100.0)
    mon.beat(1, step=1, now=100.0)
    assert mon.dead_workers(now=105.0) == []
    assert sorted(mon.alive(now=105.0)) == [0, 1]
    # worker 1 goes silent; worker 0 keeps beating
    mon.beat(0, step=2, now=109.0)
    assert mon.dead_workers(now=111.0) == [1]
    assert mon.alive(now=111.0) == [0]


def test_heartbeat_exactly_at_timeout_is_alive():
    # the contract is strict: dead means silent *past* timeout_s
    mon = HeartbeatMonitor(timeout_s=10.0)
    mon.beat(7, step=3, now=50.0)
    assert mon.dead_workers(now=60.0) == []
    assert mon.dead_workers(now=60.0001) == [7]


def test_heartbeat_revival_clears_death():
    mon = HeartbeatMonitor(timeout_s=5.0)
    mon.beat(2, step=1, now=0.0)
    assert mon.dead_workers(now=20.0) == [2]
    mon.beat(2, step=2, now=20.0)           # the host came back
    assert mon.dead_workers(now=21.0) == []
    assert mon.last_step[2] == 2


# ----------------------------------------------------------------------
# StragglerDetector
# ----------------------------------------------------------------------

def test_straggler_needs_fleet_of_four():
    det = StragglerDetector()
    for w in range(3):
        det.record(w, 1.0)
    det.record(2, 100.0)                    # huge, but only 3 workers
    assert det.stragglers() == []


def test_straggler_flags_slow_worker():
    det = StragglerDetector(alpha=0.5, z_threshold=1.5)
    # 7 healthy workers at ~1s, one worker consistently 10x slower
    for _ in range(20):
        for w in range(7):
            det.record(w, 1.0)
        det.record(7, 10.0)
    assert det.stragglers() == [7]


def test_straggler_uniform_fleet_is_clean():
    det = StragglerDetector()
    for _ in range(10):
        for w in range(8):
            det.record(w, 1.0)
    assert det.stragglers() == []


def test_straggler_ewma_forgets_one_hiccup():
    """One slow step must not brand a worker; a persistent slowdown
    must. That's the point of the EWMA over raw step times. Healthy
    workers carry a little deterministic jitter so the fleet std is
    realistic (the z-score is scale-invariant, so against a perfectly
    uniform fleet any residual would trip it)."""
    det = StragglerDetector(alpha=0.2, z_threshold=3.0)
    base = lambda w: 1.0 + 0.05 * (w % 4)
    for w in range(16):
        det.record(w, base(w))
    det.record(3, 30.0)                     # single GC pause / retry
    for _ in range(40):
        for w in range(16):
            det.record(w, base(w))
    assert det.stragglers() == []           # hiccup decayed into the noise
    for _ in range(40):
        for w in range(16):
            det.record(w, 8.0 if w == 3 else base(w))
    assert det.stragglers() == [3]


# ----------------------------------------------------------------------
# plan_remesh
# ----------------------------------------------------------------------

def test_remesh_raises_below_tp_degree():
    with pytest.raises(ValueError, match="need >= 16"):
        plan_remesh(15, model_parallel=16)


@pytest.mark.parametrize("n_avail,want_shape,want_axes", [
    # data axis snaps DOWN to a power of two; model axis never changes
    (256, (16, 16), ("data", "model")),
    (255, (8, 16), ("data", "model")),      # 15 -> 8
    (48, (2, 16), ("data", "model")),
    (16, (1, 16), ("data", "model")),
    # >= 512 chips and even data axis: split off the pod axis
    (512, (2, 16, 16), ("pod", "data", "model")),
    (1024, (2, 32, 16), ("pod", "data", "model")),
])
def test_remesh_grid_policy(n_avail, want_shape, want_axes):
    plan = plan_remesh(n_avail, model_parallel=16)
    assert plan.mesh_shape == want_shape
    assert plan.axis_names == want_axes
    # the planned grid always fits the surviving devices
    n = 1
    for d in plan.mesh_shape:
        n *= d
    assert n <= n_avail


def test_remesh_records_dropped_and_restore_step():
    plan = plan_remesh(48, model_parallel=16, dropped=(3, 9),
                       restore_step=1200)
    assert plan == RemeshPlan((2, 16), ("data", "model"), (3, 9), 1200)
    # frozen: a plan is a decision record, not mutable state
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.restore_step = 0


# ----------------------------------------------------------------------
# ElasticCoordinator: the simulated cluster
# ----------------------------------------------------------------------

def test_coordinator_healthy_fleet_never_remeshes():
    coord = ElasticCoordinator(n_workers=32, model_parallel=16,
                               monitor=HeartbeatMonitor(timeout_s=30.0))
    t = 0.0
    for step in range(50):
        for w in range(32):
            coord.step_report(w, step, step_time=1.0, now=t)
        t += 1.0
        assert coord.maybe_remesh(now=t) is None


def test_coordinator_death_triggers_power_of_two_shrink():
    """32 workers, one dies silently mid-run: the remesh keeps TP=16 and
    shrinks the data axis to the largest power of two the 31 survivors
    support (1), recording the victim and the restore step."""
    coord = ElasticCoordinator(n_workers=32, model_parallel=16,
                               monitor=HeartbeatMonitor(timeout_s=30.0))
    t = 0.0
    for step in range(10):                  # warmup, all healthy
        for w in range(32):
            coord.step_report(w, step, step_time=1.0, now=t)
        t += 1.0
    for step in range(10, 50):              # worker 13 goes silent
        for w in range(32):
            if w != 13:
                coord.step_report(w, step, step_time=1.0, now=t)
        t += 1.0
    plan = coord.maybe_remesh(restore_step=48, now=t)
    assert plan is not None
    assert plan.dropped_workers == (13,)
    assert plan.mesh_shape == (1, 16)       # 31 // 16 = 1
    assert plan.restore_step == 48
    # a straggler alone (alive, just slow) never forces a remesh
    # (one outlier among n uniform workers has z = sqrt(n-1) = sqrt(7),
    # so the threshold must sit below 2.64 for 8 workers to flag it)
    coord2 = ElasticCoordinator(n_workers=8, model_parallel=4,
                                monitor=HeartbeatMonitor(timeout_s=30.0),
                                detector=StragglerDetector(z_threshold=2.0))
    t = 0.0
    for step in range(30):
        for w in range(8):
            coord2.step_report(w, step,
                               step_time=9.0 if w == 5 else 1.0, now=t)
        t += 1.0
    assert coord2.detector.stragglers() == [5]
    assert coord2.maybe_remesh(now=t) is None
