"""Offline serving-weight quantization (models/quantize.py): structure,
roundtrip error, and end-to-end equivalence with on-the-fly quantization."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_reduced_config
from repro.core.sparq import SparqConfig
from repro.models.common import QuantCtx
from repro.models.model import Model
from repro.models.quantize import as_weight, is_qweight, quantize_params

KEY = jax.random.PRNGKey(0)


def test_structure_and_roundtrip():
    cfg = get_reduced_config("tinyllama-1.1b").replace(dtype=jnp.float32)
    model = Model(cfg)
    params = model.init_params(KEY)
    qp = quantize_params(params)
    # matmul weights became {"q","s"}; norms/embeddings untouched
    blk = qp["blocks"][0]
    assert is_qweight(blk["attn"]["wq"]) and is_qweight(blk["ffn"]["w_up"])
    assert not is_qweight(qp["embed"])
    assert blk["attn"]["wq"]["q"].dtype == jnp.int8
    # per-layer per-channel scales for stacked [L, din, dout]
    L, _, dout = params["blocks"][0]["attn"]["wq"].shape
    assert blk["attn"]["wq"]["s"].shape == (L, dout)
    # dequantized weights close to originals (8-bit per-channel)
    w = np.asarray(params["blocks"][0]["ffn"]["w_up"])
    wd = np.asarray(as_weight(blk["ffn"]["w_up"], jnp.float32))
    rel = np.abs(w - wd).max() / (np.abs(w).max() + 1e-9)
    assert rel < 1.0 / 127


def test_serving_equivalence_prequantized_vs_inline():
    """dense() must produce identical results from pre-quantized codes and
    from quantize-at-use (same scales, same integer arithmetic)."""
    cfg = get_reduced_config("tinyllama-1.1b").replace(dtype=jnp.float32)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    ctx = QuantCtx(mode="quantized", cfg=SparqConfig.opt5(signed=True))
    ref = model.logits(params, batch, ctx)
    got = model.logits(quantize_params(params), batch, ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_unquantized_forward_with_qweights_close():
    """off-mode forward through dequantized int8 weights stays close to the
    float model (INT8 weight roundtrip only)."""
    cfg = get_reduced_config("granite-20b").replace(dtype=jnp.float32)
    model = Model(cfg)
    params = model.init_params(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    a = np.asarray(model.logits(params, batch))
    b = np.asarray(model.logits(quantize_params(params), batch))
    denom = np.abs(a).mean() + 1e-9
    assert np.abs(a - b).mean() / denom < 0.05
