"""Unified telemetry subsystem (repro.obs).

What is proven here:
  * registry semantics — counter monotonicity, gauge set/set_max,
    histogram bucketing + exact raw-reservoir percentiles, label-series
    isolation, one-meaning-per-name registration errors, and `reset()`
    zeroing values while keeping metric objects and pre-bound series
    handles alive (the engine's warmup/measure boundary contract);
  * Prometheus text exposition — a golden rendering (HELP/TYPE headers,
    labeled samples, cumulative `_bucket{le}` / `_sum` / `_count`) and a
    parse round-trip, plus a live `GET /metrics` scrape through the
    asyncio `MetricsServer`;
  * trace-event schema — spans balance (every B has its E, per tid),
    X events carry non-negative durations, chunk ordinals count up, and
    `run_end` closes stragglers so a trace always loads in Perfetto;
  * engine integration — a traced run's request-span tid set matches
    the emitted results exactly, every request shows first_token and
    finished marks, scheduler step spans carry the four phase children,
    and the stats dict the engine returns is value-identical to direct
    registry reads (back-compat: the old `counters`/`pstats` keys now
    have exactly one source of truth);
  * purity — greedy tokens are BIT-identical with tracing on vs
    telemetry off, under both requeue and swap preemption on a tight
    pool, and back-to-back runs of one engine report fresh per-run
    stats (the registry reset at run start works).
"""
import asyncio
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparq import SparqConfig
from repro.launch import frontend
from repro.launch.serve import (ContinuousBatchingEngine, Request,
                                SchedulerPolicy)
from repro.models.cache import CacheConfig
from repro.obs import (EngineSpans, MetricsRegistry, Telemetry, Tracer,
                       export, summary_ms)

KEY = jax.random.PRNGKey(0)
PS = 4
MAX_SEQ_LEN = 24


# ----------------------------------------------------------------------
# registry semantics (pure host, no engine)
# ----------------------------------------------------------------------

def test_counter_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", help="h")
    s = c.series()
    s.inc()
    s.inc(2.5)
    assert s.value() == 3.5
    with pytest.raises(ValueError):
        s.inc(-1)
    lc = reg.counter("tok_total", labelnames=("kind",))
    lc.inc(3, kind="a")
    lc.inc(4, kind="b")
    assert lc.value(kind="a") == 3 and lc.value(kind="b") == 4
    assert lc.total() == 7


def test_gauge_semantics():
    g = MetricsRegistry().gauge("pages").series()
    g.set(5)
    g.set_max(3)            # no-op: below current
    assert g.value() == 5
    g.set_max(9)
    assert g.value() == 9
    g.inc(2)
    g.dec(1)
    assert g.value() == 10


def test_histogram_semantics():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    s = h.series()
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        s.observe(v)
    assert s.counts == [1, 2, 1, 1]          # per-bucket (+Inf last)
    assert s.cumulative_counts() == [1, 3, 4, 5]
    assert s.count == 5 and s.sum == pytest.approx(56.05)
    raw = [0.05, 0.5, 0.5, 5.0, 50.0]
    assert s.percentile(50) == float(np.percentile(np.asarray(raw), 50))
    assert s.mean() == pytest.approx(np.mean(raw))
    assert s.max() == 50.0
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 0.5))


def test_label_isolation_and_registration_errors():
    reg = MetricsRegistry()
    h = reg.histogram("phase_s", labelnames=("phase",))
    h.series(phase="admit").observe(1.0)
    assert h.series(phase="decode").count == 0
    with pytest.raises(ValueError):                 # wrong label set
        h.series(stage="admit")
    assert reg.histogram("phase_s", labelnames=("phase",)) is h
    with pytest.raises(TypeError):                  # kind mismatch
        reg.counter("phase_s")
    with pytest.raises(ValueError):                 # labelnames mismatch
        reg.histogram("phase_s", labelnames=("other",))


def test_reset_keeps_series_handles_alive():
    """The engine pre-binds series once and holds them across
    `reset_stats()`; reset must zero values without replacing objects."""
    reg = MetricsRegistry()
    c = reg.counter("c").series()
    g = reg.gauge("g").series()
    h = reg.histogram("h").series()
    c.inc(3)
    g.set(7)
    h.observe(0.5)
    reg.reset()
    assert c.value() == 0 and g.value() == 0
    assert h.count == 0 and h.raw == [] and sum(h.counts) == 0
    assert reg.counter("c").series() is c       # same objects survive
    c.inc()                                     # old handle still live
    assert reg.counter("c").value() == 1


def test_summary_ms_matches_legacy_pctl():
    """BENCH_slo percentiles must not move across the refactor: the
    histogram-backed summary is the same numpy math as the front-end's
    legacy `_pctl` over the same samples."""
    xs = [0.011, 0.002, 0.5, 0.033, 0.07]
    s = MetricsRegistry().histogram("ttft").series()
    for v in xs:
        s.observe(v)
    assert summary_ms(s) == frontend._pctl(xs)
    empty = MetricsRegistry().histogram("e").series()
    assert summary_ms(empty) == frontend._pctl([])


# ----------------------------------------------------------------------
# Prometheus exposition
# ----------------------------------------------------------------------

def test_prometheus_golden():
    reg = MetricsRegistry()
    reg.counter("requests_total", help="requests served").series().inc(3)
    c = reg.counter("tokens_total", help="tokens", labelnames=("kind",))
    c.inc(5, kind="prefill")
    c.inc(2, kind="decode")
    reg.gauge("pool_pages", help="pages in use").series().set(7)
    h = reg.histogram("latency_seconds", help="lat", buckets=(0.1, 1.0))
    s = h.series()
    for v in (0.05, 0.5, 5.0):
        s.observe(v)
    assert export.prometheus_text(reg) == (
        "# HELP requests_total requests served\n"
        "# TYPE requests_total counter\n"
        "requests_total 3\n"
        "# HELP tokens_total tokens\n"
        "# TYPE tokens_total counter\n"
        'tokens_total{kind="prefill"} 5\n'
        'tokens_total{kind="decode"} 2\n'
        "# HELP pool_pages pages in use\n"
        "# TYPE pool_pages gauge\n"
        "pool_pages 7\n"
        "# HELP latency_seconds lat\n"
        "# TYPE latency_seconds histogram\n"
        'latency_seconds_bucket{le="0.1"} 1\n'
        'latency_seconds_bucket{le="1"} 2\n'
        'latency_seconds_bucket{le="+Inf"} 3\n'
        "latency_seconds_sum 5.55\n"
        "latency_seconds_count 3\n")


def test_prometheus_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("a_total", labelnames=("x",)).inc(2, x="v")
    reg.gauge("b").series().set(1.5)
    h = reg.histogram("c_seconds", buckets=(1.0,)).series()
    h.observe(0.5)
    h.observe(2.0)
    parsed = export.parse_prometheus(export.prometheus_text(reg))
    assert parsed[("a_total", 'x="v"')] == 2
    assert parsed[("b", "")] == 1.5
    assert parsed[("c_seconds_bucket", 'le="1"')] == 1
    assert parsed[("c_seconds_bucket", 'le="+Inf"')] == 2
    assert parsed[("c_seconds_sum", "")] == 2.5
    assert parsed[("c_seconds_count", "")] == 2


def test_metrics_server_scrape():
    reg = MetricsRegistry()
    reg.counter("scraped_total", help="h").series().inc(3)

    async def go():
        srv = await export.MetricsServer(reg).start()
        try:
            async def fetch(path):
                r, w = await asyncio.open_connection("127.0.0.1", srv.port)
                w.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
                await w.drain()
                data = await r.read()
                w.close()
                return data
            return await fetch("/metrics"), await fetch("/other")
        finally:
            await srv.stop()

    ok, notfound = asyncio.run(go())
    head, _, body = ok.partition(b"\r\n\r\n")
    assert b"200 OK" in head and b"version=0.0.4" in head
    assert export.parse_prometheus(body.decode())[("scraped_total", "")] == 3
    assert b"404" in notfound


# ----------------------------------------------------------------------
# trace-event schema (driven by hand)
# ----------------------------------------------------------------------

def _check_balanced(events):
    open_spans = {}
    for e in events:
        assert e["ph"] in ("B", "E", "X", "i", "C", "M")
        if e["ph"] != "M":
            assert e["ts"] >= 0
        if e["ph"] == "B":
            open_spans.setdefault(e["tid"], []).append(e["name"])
        elif e["ph"] == "E":
            assert open_spans.get(e["tid"]), "E without matching B"
            open_spans[e["tid"]].pop()
        elif e["ph"] == "X":
            assert e["dur"] >= 0
    leftovers = {k: v for k, v in open_spans.items() if v}
    assert not leftovers, f"unclosed spans: {leftovers}"


def test_span_lifecycle_balances():
    tr = Tracer()
    sp = EngineSpans(tr)
    assert sp.on
    sp.run_begin(0.0)
    sp.submitted(1, 0.001)
    sp.admitted(1, 0.002, mode="chunked")
    sp.chunk(1, 0.002, 0.003, tokens=16)
    sp.chunk(1, 0.003, 0.004, tokens=8)
    sp.first_token(1, 0.005)
    sp.preempted(1, 0.006, mode="swap")
    sp.swap(1, 0.006, 0.0065, "out", nbytes=1024)
    sp.resume_work(1, 0.007, 0.008, mode="swap")
    sp.resumed(1, 0.008)
    sp.token(1, 0.009)
    sp.finished(1, 0.010)
    sp.step(0.0, 0.01, phases=(("retire", 0.0, 0.001),
                               ("decode", 0.001, 0.01)), active=1)
    sp.snapshot({"pages_in_use": 3, "free_pages": 7,
                 "active": 1, "queued": 0, "swapped": 0}, 0.01)
    sp.run_end(0.011)
    evs = tr.events()
    json.dumps(evs)                     # serializable
    _check_balanced(evs)
    x_names = [e["name"] for e in evs if e["ph"] == "X"]
    assert "prefill_chunk[0]" in x_names and "prefill_chunk[1]" in x_names
    assert "swap_out" in x_names and "resume" in x_names
    inames = [e["name"] for e in evs if e["ph"] == "i"]
    assert inames.count("first_token") == 1 and "finished" in inames
    assert {e["name"] for e in evs if e["ph"] == "C"} == {"pool", "load"}


def test_run_end_closes_stragglers():
    tr = Tracer()
    sp = EngineSpans(tr)
    sp.run_begin(0.0)
    sp.submitted(0, 0.001)
    sp.admitted(1, 0.002)               # two requests left open
    sp.run_end(0.01)
    _check_balanced(tr.events())


def test_spans_noop_without_tracer():
    sp = EngineSpans(None)
    assert not sp.on
    sp.run_begin()
    sp.submitted(0)
    sp.chunk(0, 0.0, 1.0)
    sp.step(0.0, 1.0)
    sp.finished(0)
    sp.run_end()                        # nothing raises, nothing recorded


# ----------------------------------------------------------------------
# engine integration: one traced + one plain run per preemption mode
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs.base import get_reduced_config
    from repro.models.model import Model
    cfg = get_reduced_config("tinyllama-1.1b").replace(
        dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = model.init_params(KEY)
    return model, params


def _mk_reqs(model, seed=7, shared=True):
    """Ragged requests that preempt under a tight pool x 3 slots. With
    `shared`, an 8-token preamble gives prefix hits and CoW; without it
    every page is exclusively owned, so swap-policy preemptions really
    swap (the swap path refuses victims holding shared pages)."""
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab_size
    pre = rng.integers(0, vocab, (8,))
    reqs = []
    for _ in range(5):
        tail = rng.integers(0, vocab, (int(rng.integers(2, 6)),))
        toks = np.concatenate([pre, tail]) if shared \
            else rng.integers(0, vocab, (8 + tail.size,))
        reqs.append(Request(toks.astype(np.int32),
                            int(rng.integers(6, 11))))
    return reqs


def _engine(model, mode, tel):
    cc = dataclasses.replace(
        CacheConfig.sparq_cache(SparqConfig.opt5(signed=True),
                                impl="reference"), attn_bk=PS)
    # 8 pages starves the swap workload enough to actually swap; the
    # shared-preamble requeue workload preempts at 10
    return ContinuousBatchingEngine(
        model, cc, page_size=PS, n_pages=10 if mode == "requeue" else 8,
        max_active=3, max_seq_len=MAX_SEQ_LEN,
        policy=SchedulerPolicy(preempt=mode, victim="last_joined"),
        prefill="chunked", chunk_size=16, chunk_align=4, chunk_seg=2,
        prefix_cache=True, telemetry=tel)


@pytest.fixture(scope="module")
def runs(tiny_lm):
    """Per preemption mode: a traced engine run twice (second run checks
    per-run stat freshness + tracer reset) and a telemetry-off run."""
    model, params = tiny_lm
    out = {}
    for mode in ("requeue", "swap"):
        reqs = _mk_reqs(model, shared=(mode == "requeue"))
        tel = Telemetry.tracing()
        eng = _engine(model, mode, tel)
        _, stats_first = eng.run(params, reqs)
        res, stats = eng.run(params, reqs)
        res0, stats0 = _engine(model, mode, None).run(params, reqs)
        out[mode] = dict(tel=tel, res=res, stats=stats,
                         stats_first=stats_first, res0=res0, stats0=stats0)
    return out


def test_bit_identity_on_vs_off(runs):
    for mode, r in runs.items():
        assert set(r["res"]) == set(r["res0"])
        for rid in r["res"]:
            np.testing.assert_array_equal(r["res"][rid], r["res0"][rid])
        assert r["stats"]["preemptions"] >= 1, \
            f"{mode}: workload must actually preempt"
        if mode == "swap":
            assert r["stats"]["preempt_swap"] >= 1


def test_stats_keys_and_values_match_registry(runs):
    for mode, r in runs.items():
        stats, stats0 = r["stats"], r["stats0"]
        # on/off runs expose the identical stats surface
        assert set(stats) == set(stats0)
        # the keys benchmarks consume are all still there
        assert {"decode_tok_s", "decode_steps", "prefill_chunks",
                "prefill_s", "resume_s", "preemptions", "preempt_requeue",
                "preempt_swap", "resumes", "replay_steps", "cancelled",
                "swap_bytes_out", "swap_bytes_in", "swap_peak_bytes",
                "peak_pages_used", "peak_pool_utilization", "pool_slots",
                "prefix_hits", "prefix_misses",
                "prefix_hit_tokens"} <= set(stats)
        # one source of truth: stats values ARE registry reads
        reg = r["tel"].registry
        assert stats["decode_steps"] == \
            reg.get("engine_decode_steps_total").total()
        assert stats["prefill_chunks"] == \
            reg.get("engine_prefill_chunks_total").total()
        assert stats["preempt_requeue"] == \
            reg.get("engine_preemptions_total").value(mode="requeue")
        assert stats["preempt_swap"] == \
            reg.get("engine_preemptions_total").value(mode="swap")
        assert stats["resumes"] == reg.get("engine_resumes_total").total()
        assert stats["replay_steps"] == \
            reg.get("engine_replay_steps_total").total()
        assert stats["cancelled"] == \
            reg.get("engine_cancelled_total").total()
        assert stats["swap_bytes_out"] == \
            reg.get("swap_bytes_total").value(dir="out")
        assert stats["swap_bytes_in"] == \
            reg.get("swap_bytes_total").value(dir="in")
        assert stats["peak_pages_used"] == \
            reg.get("pool_pages_peak").value()
        assert stats["prefix_hits"] == \
            reg.get("prefix_cache_hits_total").total()
        # chunked prefill observed its fill-ratio histogram per chunk
        fill = reg.get("prefill_chunk_fill_ratio").series()
        assert fill.count == stats["prefill_chunks"]
        assert all(0 < v <= 1.0 for v in fill.raw)


def test_second_run_reports_fresh_stats(runs):
    """The registry resets at run start: back-to-back runs of one warm
    engine must report per-run counts, not accumulate."""
    for r in runs.values():
        for k in ("decode_steps", "prefill_chunks", "preemptions",
                  "resumes", "swap_bytes_out", "total_tokens_served"):
            assert r["stats"][k] == r["stats_first"][k], k


def test_engine_trace_schema(runs):
    for r in runs.values():
        tel = r["tel"]
        blob = json.loads(json.dumps(export.trace_json(tel.tracer)))
        assert set(blob) == {"traceEvents", "displayTimeUnit"}
        evs = blob["traceEvents"]
        _check_balanced(evs)
        # the tracer reset at run start: exactly one run in the buffer
        run_marks = [e["name"] for e in evs if e["ph"] == "i"
                     and e["tid"] == 0 and e["name"].startswith("run_")]
        assert run_marks.count("run_begin") == 1
        assert run_marks.count("run_end") == 1
        steps = [e for e in evs if e["ph"] == "X" and e["tid"] == 0
                 and e["name"].startswith("step[")]
        assert steps and steps[0]["name"] == "step[0]"
        phase_names = {e["name"] for e in evs
                       if e["ph"] == "X" and e["tid"] == 0
                       and not e["name"].startswith("step[")}
        assert {"retire", "admit", "prefill", "decode"} <= phase_names
        # request span set == emitted requests, each with a full arc
        rid_tids = {e["tid"] for e in evs
                    if e["ph"] in ("B", "E", "X", "i") and e["tid"] != 0}
        assert rid_tids == {rid + 1 for rid in r["res"]}
        for rid in r["res"]:
            names = [e.get("name") for e in evs if e["tid"] == rid + 1]
            assert "queued" in names and "first_token" in names
            assert "finished" in names


def test_engine_prometheus_dump(runs):
    for r in runs.items():
        mode, r = r
        reg = r["tel"].registry
        parsed = export.parse_prometheus(export.prometheus_text(reg))
        assert parsed[("engine_decode_steps_total", "")] == \
            r["stats"]["decode_steps"]
        assert parsed[("engine_step_phase_seconds_count",
                       'phase="decode"')] > 0
        if mode == "swap":
            assert parsed[("swap_bytes_total", 'dir="out"')] == \
                r["stats"]["swap_bytes_out"]
