"""Pallas kernel validation: interpret=True vs pure-jnp oracle, shape/dtype
sweeps, and agreement with the core fake-quant semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import act_scale_from_stats, quantize_weight
from repro.core.sparq import SparqConfig, sparq_fake_quant
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.sparq_dequant import sparq_dequant_pallas
from repro.kernels.sparq_matmul import sparq_matmul_pallas
from repro.kernels.sparq_quant import sparq_quant_pallas

KEY = jax.random.PRNGKey(0)

CONFIGS = [
    SparqConfig.opt5(signed=True),
    SparqConfig.opt3(signed=True, rounding=False),
    SparqConfig.opt2(signed=True),
    SparqConfig.opt6(signed=True),
    SparqConfig.opt7(signed=True, vsparq=False),
    SparqConfig.opt5(signed=False),        # paper's unsigned mode
    SparqConfig.opt3(signed=False, vsparq=False),
    SparqConfig(enabled=False, signed=True),  # plain A8W8
]


def _mk_inputs(m, k, n, signed, dtype=jnp.float32, sparsity=0.3):
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), dtype=jnp.float32)
    if not signed:
        x = jnp.maximum(x, 0.0)
    # inject exact zeros so vSPARQ's pair path is exercised
    mask = jax.random.uniform(jax.random.PRNGKey(2), (m, k)) < sparsity
    x = jnp.where(mask, 0.0, x).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) / np.sqrt(k)
    w_codes, wqs = quantize_weight(w, 8)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))) or 1.0, bits=8,
                              signed=signed)
    return x, w_codes.astype(jnp.int8), qs, wqs.scale


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
def test_matmul_kernel_matches_oracle(cfg):
    m, k, n = 128, 512, 128
    x, w_codes, qs, cscale = _mk_inputs(m, k, n, cfg.signed)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    got = sparq_matmul_pallas(x, w_codes, jnp.float32(qs.scale), cscale,
                              bm=64, bn=64, bk=128, interpret=True, **kw)
    want = kref.ref_sparq_matmul(x, w_codes, qs.scale, cscale, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(64, 256, 64), (128, 128, 256),
                                   (256, 1024, 32)])
def test_matmul_kernel_shape_sweep(shape):
    m, k, n = shape
    cfg = SparqConfig.opt3(signed=True)
    x, w_codes, qs, cscale = _mk_inputs(m, k, n, True)
    got = sparq_matmul_pallas(
        x, w_codes, jnp.float32(qs.scale), cscale, bm=64, bn=32, bk=128,
        interpret=True, bits=cfg.bits, opts_shifts=cfg.shifts,
        rounding=cfg.rounding, vsparq=cfg.vsparq, signed=True,
        max_val=cfg.max_val, enabled=True)
    want = kref.ref_sparq_matmul(
        x, w_codes, qs.scale, cscale, bits=cfg.bits, opts_shifts=cfg.shifts,
        rounding=cfg.rounding, vsparq=cfg.vsparq, signed=True,
        max_val=cfg.max_val, enabled=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_dtypes(dtype):
    cfg = SparqConfig.opt5(signed=True)
    x, w_codes, qs, cscale = _mk_inputs(64, 128, 64, True, dtype=dtype)
    got = sparq_matmul_pallas(
        x, w_codes, jnp.float32(qs.scale), cscale, bm=64, bn=64, bk=128,
        interpret=True, bits=4, opts_shifts=cfg.shifts, rounding=True,
        vsparq=True, signed=True, max_val=127, enabled=True)
    want = kref.ref_sparq_matmul(
        x.astype(jnp.float32), w_codes, qs.scale, cscale, bits=4,
        opts_shifts=cfg.shifts, rounding=True, vsparq=True, signed=True,
        max_val=127, enabled=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_wrapper_pads_and_unpads():
    cfg = SparqConfig.opt5(signed=True)
    x = jax.random.normal(KEY, (10, 6, 130))  # ragged everything
    w = jax.random.normal(jax.random.PRNGKey(9), (130, 50)) * 0.1
    w_codes, wqs = quantize_weight(w, 8)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
    got = ops.quantized_matmul(x, w_codes.astype(jnp.int8), qs, wqs.scale,
                               cfg, impl="pallas", block=(64, 64, 128))
    want = ops.quantized_matmul(x, w_codes.astype(jnp.int8), qs, wqs.scale,
                                cfg, impl="reference")
    assert got.shape == (10, 6, 50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cfg", [SparqConfig.opt5(signed=True),
                                 SparqConfig.opt3(signed=True),
                                 SparqConfig.opt6(signed=True)],
                         ids=lambda c: c.name)
def test_quant_kernel_matches_oracle(cfg):
    x = jax.random.normal(KEY, (256, 128))
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(5), x.shape) < 0.4,
                  0.0, x)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=True, max_val=127)
    codes_k, meta_k = sparq_quant_pallas(
        x, jnp.float32(qs.scale), bm=128, interpret=True, **kw)
    codes_r, meta_r = kref.ref_sparq_quant(x, qs.scale, **kw)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(meta_k), np.asarray(meta_r))


def test_quant_codes_match_fake_quant():
    """codes * scale == the core fake-quant reconstruction."""
    cfg = SparqConfig.opt5(signed=True)
    x = jax.random.normal(KEY, (128, 64))
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
    codes, _ = ops.sparq_quantize(x, qs, cfg, impl="reference")
    recon = codes.astype(jnp.float32) * qs.scale
    want = sparq_fake_quant(x, qs, cfg)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_meta_bits_roundtrip():
    """Meta byte + data nibble reconstructs the trimmed value (storage
    format sanity: decode(q, shift) == codes when not mux'd)."""
    cfg = SparqConfig.opt5(signed=True, rounding=True)
    x = jnp.abs(jax.random.normal(KEY, (64, 32))) + 0.1  # no zeros -> no mux
    qs = act_scale_from_stats(float(jnp.max(x)), bits=8, signed=True)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    codes = np.asarray(codes, np.int32)
    meta = np.asarray(meta, np.int32)
    s_even, s_odd = (meta >> 3) & 7, meta & 7
    mux = (meta >> 6) & 1
    assert (mux == 0).all()
    shift = np.where(np.arange(32)[None, :] % 2 == 0, s_even, s_odd)
    assert ((np.abs(codes) >> shift) << shift == np.abs(codes)).all()
    assert (np.abs(codes) >> shift < (1 << cfg.bits)).all()


@pytest.mark.parametrize("vsparq", [True, False], ids=["vS", "no-vS"])
@pytest.mark.parametrize("signed", [True, False], ids=["signed", "unsigned"])
def test_meta_byte_unpack_reproduces_codes(vsparq, signed):
    """§5.1 storage round trip straight off the Pallas quant kernel: unpack
    [mux | shift_hi | shift_lo] from the meta byte, window the codes down to
    data nibbles, and reproduce the reconstructed codes exactly."""
    cfg = SparqConfig.opt5(signed=signed, vsparq=vsparq)
    x = jax.random.normal(KEY, (128, 32))
    if not signed:
        x = jnp.abs(x)
    # exact zeros exercise the vSPARQ mux path
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(7), x.shape) < 0.35,
                  0.0, x)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8,
                              signed=signed)
    codes, meta = sparq_quant_pallas(
        x, jnp.float32(qs.scale), bm=128, interpret=True, bits=cfg.bits,
        opts_shifts=cfg.shifts, rounding=cfg.rounding, vsparq=vsparq,
        signed=signed, max_val=cfg.max_val)
    # unsigned codes occupy the full 8-bit range; the int8 output is a bit
    # reinterpretation, so recover the magnitude via a uint8 view
    codes = np.asarray(codes, np.int8)
    mag = np.abs(codes.astype(np.int32)) if signed \
        else codes.view(np.uint8).astype(np.int32)
    sign = np.sign(codes.astype(np.int32)) if signed else 1
    meta = np.asarray(meta, np.int32)
    mux = (meta >> 6) & 1
    s_even, s_odd = (meta >> 3) & 7, meta & 7
    shift = np.where(np.arange(32)[None, :] % 2 == 0, s_even, s_odd)
    nibble = mag >> shift                         # the stored data field
    # decode: nibble << shift with the sign restored == reconstructed codes
    np.testing.assert_array_equal(
        (sign * (nibble << shift)).astype(np.int8), codes)
    # non-mux'd lanes fit the n-bit window; mux is only raised by vSPARQ
    assert (nibble[mux == 0] < (1 << cfg.bits)).all()
    if not vsparq:
        assert (mux == 0).all()


@pytest.mark.parametrize("cfg", [SparqConfig.opt5(signed=True),
                                 SparqConfig.opt3(signed=True,
                                                  rounding=False),
                                 SparqConfig.opt6(signed=True, vsparq=False),
                                 SparqConfig.opt5(signed=False)],
                         ids=lambda c: c.name)
def test_dequant_kernel_matches_ref(cfg):
    """sparq_dequant_pallas (interpret) is bit-exact against
    ref_sparq_dequant, and both invert sparq_pack back to the codes."""
    x = jax.random.normal(KEY, (256, 64))
    if not cfg.signed:
        x = jnp.abs(x)
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(3), x.shape) < 0.3,
                  0.0, x)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8,
                              signed=cfg.signed)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    store = ops.sparq_pack(codes, meta)
    want = kref.ref_sparq_dequant(store, meta)
    got = sparq_dequant_pallas(store, meta, bm=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(codes))


def test_dequant_wrapper_pads_and_unpads():
    cfg = SparqConfig.opt5(signed=True)
    x = jax.random.normal(KEY, (5, 7, 10))        # ragged rows
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8,
                              signed=True)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    store = ops.sparq_pack(codes, meta)
    got = ops.sparq_dequantize(store, meta, impl="pallas", bm=64)
    assert got.shape == x.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(codes))


# ----------------------------------------------------------------------
# fused packed-cache decode attention (§5.1 meta-decode inside the kernel)
# ----------------------------------------------------------------------

def _mk_cache_planes(cfg, B=2, Tmax=24, KV=2, hd=16, pos=13, seed=0):
    """Quantize random K/V up to `pos` into packed (data, meta, scale)
    planes via the CachedTensor write path; slots >= pos stay zeroed."""
    from repro.models.cache import CacheConfig, CacheStore
    cc = CacheConfig(layout="sparq", sparq=cfg)
    st = CacheStore.init((B, Tmax, KV, hd), cc)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(k1, (B, pos, KV, hd))
    v = jax.random.normal(k2, (B, pos, KV, hd))
    if not cfg.signed:
        k, v = jnp.abs(k), jnp.abs(v)
    st = st.update(k, v)
    q = jax.random.normal(k3, (B, 1, 2 * KV, hd))  # H=2*KV -> GQA groups
    return q, st


DECODE_CODECS = [
    SparqConfig.opt5(signed=True),                    # vsparq + signed
    SparqConfig.opt5(signed=True, vsparq=False),      # no vsparq
    SparqConfig.opt6(signed=True),                    # 3-bit window
    # unsigned magnitudes at act_bits=7 so codes (<=127) still fit int8
    SparqConfig.opt5(signed=False, act_bits=7),
    SparqConfig.opt5(signed=False, vsparq=False, act_bits=7),
    SparqConfig(enabled=False, signed=True),          # lossless int8 grid
]


@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("cfg", DECODE_CODECS, ids=lambda c: c.name)
def test_decode_attn_ref_vs_pallas_vs_dequant_oracle(cfg, window):
    """Bit-exactness of the fused decode path: the tiled jnp oracle
    (ref_sparq_decode_attn) and the Pallas kernel (interpret mode) agree
    bit for bit, and both match the dequantize-then-attend oracle
    (decode_attention_dequant) to f32 rounding."""
    from repro.models.attention import decode_attention_dequant
    B, Tmax = 2, 24
    pos = 13                                          # non-multiple of bk
    q, st = _mk_cache_planes(cfg, B=B, Tmax=Tmax, pos=pos)
    kpos = jnp.broadcast_to(jnp.arange(Tmax, dtype=jnp.int32)[None],
                            (B, Tmax))
    args = (q, st.k.data, st.k.meta, st.k.scale,
            st.v.data, st.v.meta, st.v.scale, kpos, st.pos - 1)
    ref = ops.sparq_decode_attention(*args, window=window,
                                     impl="reference", bk=8)
    pal = ops.sparq_decode_attention(*args, window=window,
                                     impl="pallas", bk=8)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    oracle = decode_attention_dequant(q, st, window=window)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("pos", [1, 7, 16, 23])
def test_decode_attn_ragged_pos_and_tiles(pos):
    """Length masking from `pos` across tile boundaries: every fill level
    (including tile-straddling and full cache) matches the oracle, with a
    tile size that does NOT divide Tmax (dispatcher pads with kpos=-1)."""
    from repro.models.attention import decode_attention_dequant
    cfg = SparqConfig.opt5(signed=True)
    B, Tmax = 2, 24
    q, st = _mk_cache_planes(cfg, B=B, Tmax=Tmax, pos=pos)
    kpos = jnp.broadcast_to(jnp.arange(Tmax, dtype=jnp.int32)[None],
                            (B, Tmax))
    args = (q, st.k.data, st.k.meta, st.k.scale,
            st.v.data, st.v.meta, st.v.scale, kpos, st.pos - 1)
    ref = ops.sparq_decode_attention(*args, impl="reference", bk=7)
    pal = ops.sparq_decode_attention(*args, impl="pallas", bk=7)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    oracle = decode_attention_dequant(q, st)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


def test_decode_attn_ring_slot_positions():
    """The windowed variant with ring-ordered slot positions (kpos is the
    rotated slot_pos array, not arange) masks by absolute position."""
    from repro.models.cache import CacheConfig, CacheStore
    cfg = SparqConfig(enabled=False, signed=True)     # exact grid
    B, W, KV, hd = 2, 8, 2, 16
    window = 6
    cc = CacheConfig(layout="sparq", sparq=cfg)
    st = CacheStore.init((B, W, KV, hd), cc)
    kv = jax.random.normal(KEY, (B, W, KV, hd))
    st = st.update(kv, kv)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, 1, 2 * KV, hd))
    # ring state: slots hold absolute positions 8..15 rotated by 3
    slot_pos = jnp.broadcast_to(
        jnp.roll(jnp.arange(8, 16, dtype=jnp.int32), 3)[None], (B, W))
    cur = jnp.asarray(15, jnp.int32)
    out = ops.sparq_decode_attention(
        q, st.k.data, st.k.meta, st.k.scale,
        st.v.data, st.v.meta, st.v.scale, slot_pos, cur,
        window=window, impl="pallas", bk=4)
    # oracle: dense attention over the dequantized ring with the same mask
    kf = st.k.read()
    ok = (slot_pos <= cur) & (slot_pos > cur - window)
    G = 2
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, kf) * hd ** -0.5
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bkgs,bskh->bkgh", p, st.v.read()).reshape(
        B, 1, 2 * KV, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
