"""Pallas kernel validation: interpret=True vs pure-jnp oracle, shape/dtype
sweeps, and agreement with the core fake-quant semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import act_scale_from_stats, quantize_weight
from repro.core.sparq import SparqConfig, sparq_fake_quant
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.sparq_dequant import sparq_dequant_pallas
from repro.kernels.sparq_matmul import sparq_matmul_pallas
from repro.kernels.sparq_quant import sparq_quant_pallas

KEY = jax.random.PRNGKey(0)

CONFIGS = [
    SparqConfig.opt5(signed=True),
    SparqConfig.opt3(signed=True, rounding=False),
    SparqConfig.opt2(signed=True),
    SparqConfig.opt6(signed=True),
    SparqConfig.opt7(signed=True, vsparq=False),
    SparqConfig.opt5(signed=False),        # paper's unsigned mode
    SparqConfig.opt3(signed=False, vsparq=False),
    SparqConfig(enabled=False, signed=True),  # plain A8W8
]


def _mk_inputs(m, k, n, signed, dtype=jnp.float32, sparsity=0.3):
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k), dtype=jnp.float32)
    if not signed:
        x = jnp.maximum(x, 0.0)
    # inject exact zeros so vSPARQ's pair path is exercised
    mask = jax.random.uniform(jax.random.PRNGKey(2), (m, k)) < sparsity
    x = jnp.where(mask, 0.0, x).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) / np.sqrt(k)
    w_codes, wqs = quantize_weight(w, 8)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))) or 1.0, bits=8,
                              signed=signed)
    return x, w_codes.astype(jnp.int8), qs, wqs.scale


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.name)
def test_matmul_kernel_matches_oracle(cfg):
    m, k, n = 128, 512, 128
    x, w_codes, qs, cscale = _mk_inputs(m, k, n, cfg.signed)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=cfg.signed, max_val=cfg.max_val,
              enabled=cfg.enabled)
    got = sparq_matmul_pallas(x, w_codes, jnp.float32(qs.scale), cscale,
                              bm=64, bn=64, bk=128, interpret=True, **kw)
    want = kref.ref_sparq_matmul(x, w_codes, qs.scale, cscale, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(64, 256, 64), (128, 128, 256),
                                   (256, 1024, 32)])
def test_matmul_kernel_shape_sweep(shape):
    m, k, n = shape
    cfg = SparqConfig.opt3(signed=True)
    x, w_codes, qs, cscale = _mk_inputs(m, k, n, True)
    got = sparq_matmul_pallas(
        x, w_codes, jnp.float32(qs.scale), cscale, bm=64, bn=32, bk=128,
        interpret=True, bits=cfg.bits, opts_shifts=cfg.shifts,
        rounding=cfg.rounding, vsparq=cfg.vsparq, signed=True,
        max_val=cfg.max_val, enabled=True)
    want = kref.ref_sparq_matmul(
        x, w_codes, qs.scale, cscale, bits=cfg.bits, opts_shifts=cfg.shifts,
        rounding=cfg.rounding, vsparq=cfg.vsparq, signed=True,
        max_val=cfg.max_val, enabled=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_dtypes(dtype):
    cfg = SparqConfig.opt5(signed=True)
    x, w_codes, qs, cscale = _mk_inputs(64, 128, 64, True, dtype=dtype)
    got = sparq_matmul_pallas(
        x, w_codes, jnp.float32(qs.scale), cscale, bm=64, bn=64, bk=128,
        interpret=True, bits=4, opts_shifts=cfg.shifts, rounding=True,
        vsparq=True, signed=True, max_val=127, enabled=True)
    want = kref.ref_sparq_matmul(
        x.astype(jnp.float32), w_codes, qs.scale, cscale, bits=4,
        opts_shifts=cfg.shifts, rounding=True, vsparq=True, signed=True,
        max_val=127, enabled=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_wrapper_pads_and_unpads():
    cfg = SparqConfig.opt5(signed=True)
    x = jax.random.normal(KEY, (10, 6, 130))  # ragged everything
    w = jax.random.normal(jax.random.PRNGKey(9), (130, 50)) * 0.1
    w_codes, wqs = quantize_weight(w, 8)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
    got = ops.quantized_matmul(x, w_codes.astype(jnp.int8), qs, wqs.scale,
                               cfg, impl="pallas", block=(64, 64, 128))
    want = ops.quantized_matmul(x, w_codes.astype(jnp.int8), qs, wqs.scale,
                                cfg, impl="reference")
    assert got.shape == (10, 6, 50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cfg", [SparqConfig.opt5(signed=True),
                                 SparqConfig.opt3(signed=True),
                                 SparqConfig.opt6(signed=True)],
                         ids=lambda c: c.name)
def test_quant_kernel_matches_oracle(cfg):
    x = jax.random.normal(KEY, (256, 128))
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(5), x.shape) < 0.4,
                  0.0, x)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
    kw = dict(bits=cfg.bits, opts_shifts=cfg.shifts, rounding=cfg.rounding,
              vsparq=cfg.vsparq, signed=True, max_val=127)
    codes_k, meta_k = sparq_quant_pallas(
        x, jnp.float32(qs.scale), bm=128, interpret=True, **kw)
    codes_r, meta_r = kref.ref_sparq_quant(x, qs.scale, **kw)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(meta_k), np.asarray(meta_r))


def test_quant_codes_match_fake_quant():
    """codes * scale == the core fake-quant reconstruction."""
    cfg = SparqConfig.opt5(signed=True)
    x = jax.random.normal(KEY, (128, 64))
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
    codes, _ = ops.sparq_quantize(x, qs, cfg, impl="reference")
    recon = codes.astype(jnp.float32) * qs.scale
    want = sparq_fake_quant(x, qs, cfg)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_meta_bits_roundtrip():
    """Meta byte + data nibble reconstructs the trimmed value (storage
    format sanity: decode(q, shift) == codes when not mux'd)."""
    cfg = SparqConfig.opt5(signed=True, rounding=True)
    x = jnp.abs(jax.random.normal(KEY, (64, 32))) + 0.1  # no zeros -> no mux
    qs = act_scale_from_stats(float(jnp.max(x)), bits=8, signed=True)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    codes = np.asarray(codes, np.int32)
    meta = np.asarray(meta, np.int32)
    s_even, s_odd = (meta >> 3) & 7, meta & 7
    mux = (meta >> 6) & 1
    assert (mux == 0).all()
    shift = np.where(np.arange(32)[None, :] % 2 == 0, s_even, s_odd)
    assert ((np.abs(codes) >> shift) << shift == np.abs(codes)).all()
    assert (np.abs(codes) >> shift < (1 << cfg.bits)).all()


@pytest.mark.parametrize("vsparq", [True, False], ids=["vS", "no-vS"])
@pytest.mark.parametrize("signed", [True, False], ids=["signed", "unsigned"])
def test_meta_byte_unpack_reproduces_codes(vsparq, signed):
    """§5.1 storage round trip straight off the Pallas quant kernel: unpack
    [mux | shift_hi | shift_lo] from the meta byte, window the codes down to
    data nibbles, and reproduce the reconstructed codes exactly."""
    cfg = SparqConfig.opt5(signed=signed, vsparq=vsparq)
    x = jax.random.normal(KEY, (128, 32))
    if not signed:
        x = jnp.abs(x)
    # exact zeros exercise the vSPARQ mux path
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(7), x.shape) < 0.35,
                  0.0, x)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8,
                              signed=signed)
    codes, meta = sparq_quant_pallas(
        x, jnp.float32(qs.scale), bm=128, interpret=True, bits=cfg.bits,
        opts_shifts=cfg.shifts, rounding=cfg.rounding, vsparq=vsparq,
        signed=signed, max_val=cfg.max_val)
    # unsigned codes occupy the full 8-bit range; the int8 output is a bit
    # reinterpretation, so recover the magnitude via a uint8 view
    codes = np.asarray(codes, np.int8)
    mag = np.abs(codes.astype(np.int32)) if signed \
        else codes.view(np.uint8).astype(np.int32)
    sign = np.sign(codes.astype(np.int32)) if signed else 1
    meta = np.asarray(meta, np.int32)
    mux = (meta >> 6) & 1
    s_even, s_odd = (meta >> 3) & 7, meta & 7
    shift = np.where(np.arange(32)[None, :] % 2 == 0, s_even, s_odd)
    nibble = mag >> shift                         # the stored data field
    # decode: nibble << shift with the sign restored == reconstructed codes
    np.testing.assert_array_equal(
        (sign * (nibble << shift)).astype(np.int8), codes)
    # non-mux'd lanes fit the n-bit window; mux is only raised by vSPARQ
    assert (nibble[mux == 0] < (1 << cfg.bits)).all()
    if not vsparq:
        assert (mux == 0).all()


@pytest.mark.parametrize("cfg", [SparqConfig.opt5(signed=True),
                                 SparqConfig.opt3(signed=True,
                                                  rounding=False),
                                 SparqConfig.opt6(signed=True, vsparq=False),
                                 SparqConfig.opt5(signed=False)],
                         ids=lambda c: c.name)
def test_dequant_kernel_matches_ref(cfg):
    """sparq_dequant_pallas (interpret) is bit-exact against
    ref_sparq_dequant, and both invert sparq_pack back to the codes."""
    x = jax.random.normal(KEY, (256, 64))
    if not cfg.signed:
        x = jnp.abs(x)
    x = jnp.where(jax.random.uniform(jax.random.PRNGKey(3), x.shape) < 0.3,
                  0.0, x)
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8,
                              signed=cfg.signed)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    store = ops.sparq_pack(codes, meta)
    want = kref.ref_sparq_dequant(store, meta)
    got = sparq_dequant_pallas(store, meta, bm=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(codes))


def test_dequant_wrapper_pads_and_unpads():
    cfg = SparqConfig.opt5(signed=True)
    x = jax.random.normal(KEY, (5, 7, 10))        # ragged rows
    qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8,
                              signed=True)
    codes, meta = ops.sparq_quantize(x, qs, cfg, impl="reference")
    store = ops.sparq_pack(codes, meta)
    got = ops.sparq_dequantize(store, meta, impl="pallas", bm=64)
    assert got.shape == x.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(codes))
