"""Min-max PTQ, ACIQ baseline, SQNR orderings (paper §1/§5.1 premises)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizer import (
    act_scale_from_stats, weight_scale, quantize, dequantize, fake_quant,
    quantize_weight, MinMaxObserver)
from repro.core.aciq import aciq_fake_quant
from repro.core.sparq import SparqConfig, sparq_fake_quant, sparq_dot


def sqnr(x, xq):
    x, xq = np.asarray(x, np.float64), np.asarray(xq, np.float64)
    return 10 * np.log10((x ** 2).sum() / ((x - xq) ** 2).sum() + 1e-30)


class TestQuantizer:
    def test_roundtrip_unsigned(self):
        x = jnp.linspace(0, 10, 1000)
        qs = act_scale_from_stats(10.0, bits=8, signed=False)
        err = np.abs(np.asarray(fake_quant(x, qs) - x))
        assert err.max() <= float(qs.scale) / 2 + 1e-6

    def test_roundtrip_signed(self):
        x = jnp.linspace(-3, 3, 1000)
        qs = act_scale_from_stats(3.0, bits=8, signed=True)
        err = np.abs(np.asarray(fake_quant(x, qs) - x))
        assert err.max() <= float(qs.scale) / 2 + 1e-6

    def test_per_channel_weight(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 16)) * \
            jnp.arange(1, 17)[None, :]
        wq, qs = quantize_weight(w, bits=8)
        assert qs.scale.shape == (16,)
        err = np.abs(np.asarray(dequantize(wq, qs) - w))
        assert (err.max(axis=0) <= np.asarray(qs.scale) / 2 + 1e-6).all()

    def test_observer(self):
        obs = MinMaxObserver()
        obs = obs.update(jnp.asarray([1.0, 5.0]))
        obs = obs.update(jnp.asarray([-2.0, 3.0]))
        assert obs.max_val == 5.0 and obs.min_val == -2.0
        qs = obs.scale(bits=8)
        assert qs.signed


class TestSQNROrderings:
    """The paper's qualitative claims on bell-shaped data (§5.1, Table 2/4)."""

    @pytest.fixture
    def relu_gaussian(self):
        # post-ReLU half-gaussian with ~55% zeros: the paper's CNN activation model
        x = jax.random.normal(jax.random.PRNGKey(42), (1 << 14,))
        return jnp.maximum(x - 0.1, 0.0) * 4.0

    def _fq(self, x, cfg):
        qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))),
                                  bits=8, signed=cfg.signed)
        return sparq_fake_quant(x, qs, cfg)

    def test_more_opts_better(self, relu_gaussian):
        x = relu_gaussian
        s = {o: sqnr(x, self._fq(x, SparqConfig(bits=4, opts=o, rounding=False)))
             for o in (5, 3, 2)}
        assert s[5] >= s[3] >= s[2]

    def test_rounding_helps(self, relu_gaussian):
        x = relu_gaussian
        for o in (5, 3, 2):
            plus = sqnr(x, self._fq(x, SparqConfig(bits=4, opts=o, rounding=True)))
            minus = sqnr(x, self._fq(x, SparqConfig(bits=4, opts=o, rounding=False)))
            assert plus >= minus

    def test_vsparq_helps_with_sparsity(self, relu_gaussian):
        x = relu_gaussian
        with_v = sqnr(x, self._fq(x, SparqConfig(bits=4, opts=2, vsparq=True)))
        no_v = sqnr(x, self._fq(x, SparqConfig(bits=4, opts=2, vsparq=False)))
        assert with_v > no_v

    def test_vsparq_gain_grows_as_bits_shrink(self, relu_gaussian):
        """Paper §5.1: 'vSPARQ impact is more significant in lower bit-widths'."""
        x = relu_gaussian
        gains = {}
        for bits, opts in [(4, 5), (3, 6), (2, 7)]:
            wv = sqnr(x, self._fq(x, SparqConfig(bits=bits, opts=opts, vsparq=True)))
            nv = sqnr(x, self._fq(x, SparqConfig(bits=bits, opts=opts, vsparq=False)))
            gains[bits] = wv - nv
        assert gains[2] > gains[4]

    def test_sparq_beats_static_4bit(self, relu_gaussian):
        """Dynamic windowing beats static uniform 4-bit (the A4W8 column)."""
        x = relu_gaussian
        sparq = sqnr(x, self._fq(x, SparqConfig.opt5()))
        qs4 = act_scale_from_stats(float(jnp.max(x)), bits=4, signed=False)
        static4 = sqnr(x, fake_quant(x, qs4))
        assert sparq > static4

    def test_aciq_clip_beats_minmax_at_4bit(self, relu_gaussian):
        x = relu_gaussian * (1 + 10 * (jax.random.uniform(
            jax.random.PRNGKey(7), relu_gaussian.shape) > 0.999))  # outliers
        aciq = sqnr(x, aciq_fake_quant(x, bits=4, signed=False))
        qs = act_scale_from_stats(float(jnp.max(x)), bits=4, signed=False)
        minmax = sqnr(x, fake_quant(x, qs))
        assert aciq > minmax


class TestSparqDot:
    def test_matches_manual(self):
        key = jax.random.PRNGKey(0)
        x = jnp.maximum(jax.random.normal(key, (8, 64)), 0)
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        cfg = SparqConfig.opt5()
        qs = act_scale_from_stats(float(jnp.max(x)), bits=8, signed=False)
        wq, wqs = quantize_weight(w, 8)
        y = sparq_dot(x, wq, qs, wqs, cfg)
        # reference: fake-quant activations, dequant weights, float matmul
        xr = sparq_fake_quant(x, qs, cfg)
        ref = xr @ dequantize(wq, wqs)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5,
                                   atol=1e-5)

    def test_a8w8_close_to_fp(self):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (16, 128))
        w = jax.random.normal(jax.random.PRNGKey(4), (128, 64)) / 11.3
        cfg = SparqConfig(enabled=False, signed=True)
        qs = act_scale_from_stats(float(jnp.max(jnp.abs(x))), bits=8, signed=True)
        wq, wqs = quantize_weight(w, 8)
        y = np.asarray(sparq_dot(x, wq, qs, wqs, cfg))
        ref = np.asarray(x @ w)
        assert sqnr(ref, y) > 30  # INT8 dot should be ~clean
