"""Quickstart: SPARQ in 30 lines — quantize a matmul's activations
dynamically to 4 bits and compare against FP32 and plain A4W8.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SparqConfig, act_scale_from_stats, fake_quant,
                        quantize_weight, sparq_dot)

key = jax.random.PRNGKey(0)
# post-ReLU activations: bell-shaped, ~50% zeros (the paper's setting)
x = jnp.maximum(jax.random.normal(key, (64, 512)) - 0.2, 0.0)
w = jax.random.normal(jax.random.PRNGKey(1), (512, 128)) / 512 ** 0.5

y_fp32 = x @ w

w_codes, w_qs = quantize_weight(w, bits=8)
act_qs = act_scale_from_stats(float(x.max()), bits=8, signed=False)

def err(y):
    return float(jnp.linalg.norm(y - y_fp32) / jnp.linalg.norm(y_fp32))

# SPARQ 4-bit (5opt, rounding, vSPARQ) on top of A8W8
y_sparq = sparq_dot(x, w_codes, act_qs, w_qs, SparqConfig.opt5())
# plain static 4-bit activations
qs4 = act_scale_from_stats(float(x.max()), bits=4, signed=False)
y_a4w8 = fake_quant(x, qs4) @ (w_codes * w_qs.scale)

print(f"relative error vs FP32:")
print(f"  A8W8 + SPARQ 4b (5opt) : {err(y_sparq):.4%}")
print(f"  static A4W8            : {err(y_a4w8):.4%}")
print("SPARQ's dynamic windowing recovers most of the 8-bit accuracy "
      "at a 4-bit budget.")
