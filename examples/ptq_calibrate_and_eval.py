"""End-to-end PTQ driver (the paper's workflow, §5):
train FP32 -> calibrate (min-max + BN recalibration) -> PTQ with SPARQ ->
report the accuracy-degradation table.

  PYTHONPATH=src:. python examples/ptq_calibrate_and_eval.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common
from repro.core.sparq import SparqConfig

print("training mini-ResNet on the synthetic task (cached after first run)")
model = common.train_cnn()
print("calibrating (min-max activation stats + BN recalibration)")
scales = common.calibrate_cnn(model)

fp32 = common.cnn_accuracy(model)
print(f"\nFP32 top-1: {fp32:.4f}\n")
print(f"{'config':24s} {'top-1 delta':>12s}")
for name, cfg in [
    ("A8W8", SparqConfig(enabled=False)),
    ("SPARQ 4b 5opt", SparqConfig.opt5()),
    ("SPARQ 4b 3opt", SparqConfig.opt3()),
    ("SPARQ 4b 2opt (SySMT)", SparqConfig.opt2()),
    ("SPARQ 3b 6opt", SparqConfig.opt6()),
    ("SPARQ 2b 7opt", SparqConfig.opt7()),
    ("static A4W8", SparqConfig(enabled=False, act_bits=4)),
]:
    acc = common.cnn_accuracy(model, common.quant_ctx(scales, cfg))
    print(f"{name:24s} {acc - fp32:+12.4f}")
