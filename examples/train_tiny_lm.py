"""End-to-end training driver: train a reduced LM for a few hundred steps
with checkpoint/restart, then PTQ-evaluate perplexity deltas with SPARQ.

  PYTHONPATH=src python examples/train_tiny_lm.py [--arch tinyllama-1.1b]
"""
import argparse
import math
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced_config
from repro.core.sparq import SparqConfig
from repro.data.pipeline import Batcher, DataConfig
from repro.launch import train as train_mod
from repro.models.common import QuantCtx
from repro.models.model import Model

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="tiny_lm_")
    losses = train_mod.main([
        "--arch", args.arch, "--reduced", "--steps", str(args.steps),
        "--batch", "16", "--seq", "128", "--lr", "1e-3",
        "--checkpoint-dir", ckpt_dir, "--checkpoint-every", "100"])
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ckpts in {ckpt_dir})")

    # PTQ eval: loss deltas under SPARQ (signed mode for transformer acts)
    cfg = get_reduced_config(args.arch)
    model = Model(cfg)
    from repro.checkpoint import manager as ckpt
    step = ckpt.latest_step(ckpt_dir)
    params = model.init_params(jax.random.PRNGKey(0))
    state = ckpt.restore(ckpt_dir, step, {"params": params})
    params = state["params"]

    data = Batcher(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                              global_batch=16))
    batches = [data.global_batch(10_000 + i) for i in range(4)]
    scales = model.calibrate(params, data.calib_batches(2, batch=8))

    def eval_loss(ctx, scales_groups=None):
        tot = 0.0
        for b in batches:
            l, _ = model.loss(params, b, ctx, scales_groups)
            tot += float(l)
        return tot / len(batches)

    base = eval_loss(None)
    print(f"\n{'config':18s} {'loss':>8s} {'ppl delta':>10s}")
    print(f"{'fp32':18s} {base:8.4f} {'-':>10s}")
    for name, scfg in [("a8w8", SparqConfig(enabled=False, signed=True)),
                       ("sparq-4b-5opt", SparqConfig.opt5(signed=True)),
                       ("sparq-4b-2opt", SparqConfig.opt2(signed=True))]:
        ctx = QuantCtx(mode="quantized", cfg=scfg)
        l = eval_loss(ctx, scales)
        print(f"{name:18s} {l:8.4f} {math.exp(l) - math.exp(base):+10.4f}")
