"""Continuous batching over the paged SPARQ KV-cache, with preemption.

Eight requests with ragged prompt lengths and staggered completion times
are served through four sequence slots backed by one shared page pool
(`ContinuousBatchingEngine`): sequences join as slots free up, pages are
allocated as sequences grow and recycled on eviction. Every request's
greedy tokens are then checked for exact equality against the contiguous
scan engine (`DecodeEngine`) serving the same request alone — the paged
path is a different memory layout, not a different computation (the
contiguous run tile-aligns its fused decode kernel to the page size so
even the f32 summation order matches).

The same workload is then replayed through a pool *half* that size —
more admitted demand than capacity. With a `SchedulerPolicy` the engine
preempts victims on decode-time exhaustion (requeue-and-replay, or
packed-page swap to the host `SwapStore`) and resumes them bit-exactly:
the oversubscribed runs must emit the very same tokens.

A shared-prefix trace (six prompts with a common 32-token preamble plus
one exact duplicate) then runs with `prefix_cache=True`: admissions
adopt the cached prefix's refcounted pages and the donor's frozen
scales, copy-on-write handles the duplicate's mid-page resume, and the
tokens stay bit-identical to the cache-off run.

  PYTHONPATH=src python examples/serve_batched.py [--arch tinyllama-1.1b]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_reduced_config
from repro.core.sparq import SparqConfig
from repro.launch.serve import (ContinuousBatchingEngine, DecodeEngine,
                                Request, SchedulerPolicy)
from repro.models.cache import CacheConfig
from repro.models.model import Model

PAGE, POOL, SLOTS = 16, 24, 4
POOL_OVER = 7                   # deliberately < the workload's working set


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--sparq", choices=("a8w8", "5opt"), default="5opt",
                    help="cache codec: plain int8 grid or 4-bit 5opt")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch).replace(dtype=jnp.float32,
                                                remat=False)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)

    lens = [24, 9, 31, 17, 40, 12, 28, 20]
    gens = [20, 6, 14, 25, 9, 18, 11, 16]
    reqs = [Request(rng.integers(0, cfg.vocab_size, (L,)), g)
            for L, g in zip(lens, gens)]

    codec = SparqConfig.opt5(signed=True) if args.sparq == "5opt" \
        else SparqConfig(enabled=False, signed=True)
    # attn_bk = page size: contiguous fused decode uses the same Tk tiling
    # as the paged kernel, making the two engines bit-identical
    cc = dataclasses.replace(
        CacheConfig.sparq_cache(codec, impl="reference"), attn_bk=PAGE)

    engine = ContinuousBatchingEngine(model, cc, page_size=PAGE,
                                      n_pages=POOL, max_active=SLOTS,
                                      max_seq_len=80)
    results, stats = engine.run(params, reqs, progress=True)
    print(f"paged: {stats['decode_tok_s']:.1f} tok/s over "
          f"{stats['decode_steps']} steps, peak pool "
          f"{stats['peak_pages_used']}/{stats['pool_pages']} pages, "
          f"{stats['total_tokens_served']} tokens total")

    contiguous = DecodeEngine(model, cc)
    for rid, req in enumerate(reqs):
        toks, _ = contiguous.generate(
            params, {"tokens": jnp.asarray(req.tokens)[None]}, req.gen,
            warmup=False)
        np.testing.assert_array_equal(results[rid], np.asarray(toks)[0])
        print(f"rid={rid} prompt={len(req.tokens):3d} gen={req.gen:3d} "
              f"tokens match contiguous: {results[rid][:8]}...")
    print("all requests token-identical to the contiguous engine")

    # ---- chunked ragged prefill: the same workload admitted through
    # the fixed-shape chunk program (one jit for all eight distinct
    # prompt lengths, §5.1 pages written directly). Prompts fit one
    # segment here, so the tokens must be bit-identical to the
    # sequential-admission run above.
    engine_c = ContinuousBatchingEngine(
        model, cc, page_size=PAGE, n_pages=POOL, max_active=SLOTS,
        max_seq_len=80, prefill="chunked", chunk_size=48, chunk_align=8)
    results_c, stats_c = engine_c.run(params, reqs)
    for rid in results:
        np.testing.assert_array_equal(results_c[rid], results[rid])
    print(f"chunked prefill: {stats_c['prefill_chunks']} chunks, "
          f"{stats_c['prefill_compile_count']} compiled program(s) for "
          f"{len(set(lens))} distinct prompt lengths — tokens identical")

    # ---- oversubscribed: same workload, half the pool, both policies.
    # Preemption must be invisible in the tokens — only in the stats.
    for mode in ("requeue", "swap"):
        engine_o = ContinuousBatchingEngine(
            model, cc, page_size=PAGE, n_pages=POOL_OVER,
            max_active=SLOTS, max_seq_len=80,
            policy=SchedulerPolicy(preempt=mode, victim="last_joined"))
        results_o, stats_o = engine_o.run(params, reqs)
        assert stats_o["preemptions"] > 0, "pool did not oversubscribe"
        for rid in results:
            np.testing.assert_array_equal(results_o[rid], results[rid])
        print(f"oversubscribed ({POOL_OVER}/{POOL} pages, {mode}): "
              f"{stats_o['preemptions']} preemptions, "
              f"{stats_o['resumes']} resumes, "
              f"{stats_o['replay_steps']} replay steps, "
              f"swap {stats_o['swap_bytes_out']/1e3:.1f} kB out — "
              f"tokens identical")
    print("preemption is token-invisible under both policies")

    # ---- shared-prefix page reuse: few-shot-style traffic — six
    # prompts sharing a 32-token prefix (distinct 16-token tails) plus
    # one exact duplicate, arriving staggered. With --prefix-cache the
    # engine refcounts pages, adopts the cached prefix (and the donor's
    # frozen scales) on admission, copy-on-writes the duplicate's
    # mid-page resume point, and chunk-prefills only the tails. Tokens
    # must be bit-identical to the cache-off run of the same trace.
    shared = rng.integers(0, cfg.vocab_size, (32,))
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, (16,))])
               for _ in range(6)]
    prompts.insert(1, prompts[0].copy())        # duplicate, donor live
    reqs_p = [Request(p, 12, arrive_at=2 * i)
              for i, p in enumerate(prompts)]
    runs = {}
    for prefix in (False, True):
        eng = ContinuousBatchingEngine(
            model, cc, page_size=PAGE, n_pages=POOL, max_active=SLOTS,
            max_seq_len=80, prefill="chunked", chunk_size=48,
            chunk_align=8, chunk_seg=8, prefix_cache=prefix)
        runs[prefix] = eng.run(params, reqs_p)
    results_p, stats_p = runs[True]
    for rid in runs[False][0]:
        np.testing.assert_array_equal(results_p[rid], runs[False][0][rid])
    assert stats_p["prefix_hits"] > 0 and stats_p["cow_copies"] > 0
    print(f"prefix cache: {stats_p['prefix_hits']} hits / "
          f"{stats_p['prefix_misses']} misses, "
          f"{stats_p['prefix_hit_tokens']} prompt tokens adopted, "
          f"{stats_p['prefix_shared_pages']} pages shared, "
          f"{stats_p['cow_copies']} CoW copies, peak pool "
          f"{stats_p['peak_pages_used']} vs "
          f"{runs[False][1]['peak_pages_used']} pages — tokens identical")

    # ---- everything at once: chunked admission over an oversubscribed
    # pool with the per-victim cost model picking requeue vs swap.
    engine_a = ContinuousBatchingEngine(
        model, cc, page_size=PAGE, n_pages=POOL_OVER, max_active=SLOTS,
        max_seq_len=80, prefill="chunked", chunk_size=48, chunk_align=8,
        policy=SchedulerPolicy(preempt="auto"))
    results_a, stats_a = engine_a.run(params, reqs)
    assert stats_a["preemptions"] > 0
    for rid in results:
        np.testing.assert_array_equal(results_a[rid], results[rid])
    print(f"chunked + oversubscribed + auto policy: "
          f"{stats_a['preempt_requeue']} requeues / "
          f"{stats_a['preempt_swap']} swaps chosen by the cost model — "
          f"tokens still identical")


if __name__ == "__main__":
    main()
