"""Batched serving with SPARQ-quantized matmuls: prefill a batch of
synthetic prompts, decode greedily, compare SPARQ presets.

  PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-7b]
"""
import argparse

from repro.launch import serve

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()
    for preset in ("off", "a8w8", "5opt", "2opt"):
        print(f"--- sparq={preset} ---")
        serve.main(["--arch", args.arch, "--reduced", "--batch", "4",
                    "--prompt-len", "48", "--gen", "16", "--sparq", preset])
